"""Paper Table 3 (mechanism): Transformer-tiny seq2seq across formats.

Enc-dec transformer (2+2 layers, d=128, ff=512 — the paper's tiny config)
on the reversal task; Adam, as in §4.3.

    PYTHONPATH=src python examples/train_transformer_tiny.py --steps 150
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import encdec
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step


def run(mode, steps, seed=0, loss_scale=100.0):
    cfg = get_config("transformer_tiny").replace(vocab=256)
    pol = make_policy(mode, loss_scale=loss_scale)
    params = encdec.init_encdec(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw()
    sched = schedules.cosine(2e-3, warmup=10, total=steps)

    def loss_fn(p, b, pol_):
        return encdec.loss_fn(p, b["enc_tokens"], b["dec_tokens"],
                              b["dec_labels"], cfg, pol_)

    step = jax.jit(make_train_step(loss_fn, opt, sched, pol))
    opt_state = opt.init(params)
    losses = []
    for s in range(steps):
        b = synthetic.seq2seq_batch(seed, s, 16, 16, 16, cfg.vocab)
        params, opt_state, m = step(params, opt_state, b, jnp.int32(s))
        losses.append(float(m["nll"]))

    # token accuracy on a held-out batch (proxy for BLEU direction)
    b = synthetic.seq2seq_batch(seed + 1, 10_000, 32, 16, 16, cfg.vocab)
    enc = encdec.encode(params, b["enc_tokens"], cfg, pol)
    ekv = encdec.cross_kv(params, enc, cfg, pol)
    logits, _ = encdec.decode_stack(params, b["dec_tokens"], ekv, cfg, pol)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == b["dec_labels"])))
    return losses[-1], acc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    print(f"{'format':>12} {'final_nll':>10} {'tok_acc':>8}")
    for mode in ["fp32", "s2fp8", "fp8", "fp8_ls"]:
        nll, acc = run(mode, args.steps)
        label = "fp8_ls(100)" if mode == "fp8_ls" else mode
        print(f"{label:>12} {nll:10.4f} {acc:8.3f}")
