"""Quickstart: train a tiny LM with S2FP8 and watch it track FP32.

The fourth column trains with the jit-carried StatsBank (core/statsbank.py):
per-site (alpha, beta) are carried across steps and the Eq. 3-4 stats
reduction only runs every ``refresh_every`` steps inside jit — the delayed
stats recipe, converging on top of the exact-stats curve.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step

STEPS = 60
cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False, vocab=64)
table = synthetic.make_markov_table(0, cfg.vocab)


def loss_fn(params, batch, pol):
    return tlm.loss_fn(params, batch["tokens"], batch["labels"], cfg, pol)


def run(mode, stats_refresh_every=0):
    pol = make_policy(mode, loss_scale=100.0)
    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw()
    stats_cfg = bank = None
    if stats_refresh_every:
        stats_cfg = statsbank.StatsConfig(refresh_every=stats_refresh_every)
        batch0 = synthetic.lm_batch(0, 0, 8, 64, cfg.vocab, table)
        bank = statsbank.init_bank(loss_fn, params, batch0, pol, stats_cfg)
    step = jax.jit(make_train_step(loss_fn, opt, schedules.constant(3e-3),
                                   pol, stats=stats_cfg))
    state = opt.init(params)
    losses = []
    for s in range(STEPS):
        batch = synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)
        if bank is None:
            params, state, m = step(params, state, batch, jnp.int32(s))
        else:
            params, state, bank, m = step(params, state, bank, batch,
                                          jnp.int32(s))
        losses.append(float(m["loss"]))
    return losses


if __name__ == "__main__":
    print(f"{'step':>6} {'fp32':>8} {'s2fp8':>8} {'fp8':>8} {'s2fp8+bank':>10}")
    curves = {m: run(m) for m in ["fp32", "s2fp8", "fp8"]}
    curves["bank"] = run("s2fp8", stats_refresh_every=8)
    for s in range(0, STEPS, 10):
        print(f"{s:6d} {curves['fp32'][s]:8.4f} {curves['s2fp8'][s]:8.4f} "
              f"{curves['fp8'][s]:8.4f} {curves['bank'][s]:10.4f}")
    print(f"{'final':>6} {curves['fp32'][-1]:8.4f} {curves['s2fp8'][-1]:8.4f} "
          f"{curves['fp8'][-1]:8.4f} {curves['bank'][-1]:10.4f}")
    print("\nS2FP8 tracks FP32 out-of-the-box; raw FP8 does not (paper's "
          "claim).\nThe StatsBank column amortizes the stats reduction "
          "8x with no convergence cost.")
