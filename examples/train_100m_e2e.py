"""End-to-end driver: train a ~134M-param LM for a few hundred steps with
S2FP8, checkpointing + auto-resume, on whatever devices exist.

    PYTHONPATH=src python examples/train_100m_e2e.py --steps 300

This is the deliverable-(b) driver: full stack (config -> model -> policy ->
optimizer/schedule -> data pipeline -> TrainLoop with watchdog/checkpoints).
"""
import argparse
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.checkpoint.manager import CheckpointManager
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import TrainLoop, make_train_step

CFG = ArchConfig(
    name="lm-134m", family="dense",
    n_layers=12, d_model=768, n_heads=12, kv_heads=4, d_ff=2048,
    vocab=32_000, head_dim=64, activation="silu_glu", tie_embeddings=True,
    remat=False, attn_impl="flash",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policy", default="s2fp8")
    ap.add_argument("--ckpt-dir", default="/tmp/ckpt_100m")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_params = CFG.n_params()
    print(f"[e2e] {CFG.name}: {n_params/1e6:.0f}M params, policy={args.policy}")

    pol = make_policy(args.policy)
    params = tlm.init_lm(CFG, jax.random.PRNGKey(args.seed))
    opt = optimizers.adamw(weight_decay=0.01)
    sched = schedules.cosine(3e-4 * 8, warmup=20, total=args.steps)

    def loss_fn(p, batch, pol_):
        return tlm.loss_fn(p, batch["tokens"], batch["labels"], CFG, pol_)

    step_fn = make_train_step(loss_fn, opt, sched, pol, track_stats=False)
    table = synthetic.make_markov_table(args.seed, CFG.vocab)

    def data_fn(s):
        return synthetic.lm_batch(args.seed, s, args.batch, args.seq,
                                  CFG.vocab, table)

    ck = CheckpointManager(args.ckpt_dir, keep=2)
    loop = TrainLoop(step_fn, params, opt.init(params), data_fn,
                     ckpt_manager=ck, ckpt_every=100, log_every=10)
    loop.maybe_resume()
    hist = loop.run(args.steps)
    first = hist[0]["loss"] if loop.start_step == 0 else float("nan")
    print(f"[e2e] done: start-loss {first if first == first else 'resumed'}"
          f" final-loss {hist[-1]['loss']:.4f} over {len(hist)} steps "
          f"(ln V = {math.log(CFG.vocab):.2f})")


if __name__ == "__main__":
    main()
