"""End-to-end driver: train a ~134M-param LM for a few hundred steps with
S2FP8, checkpointing + auto-resume, on whatever devices exist.

    PYTHONPATH=src python examples/train_100m_e2e.py --steps 300

Mesh-native (ISSUE 5): ``--mesh host`` runs the shard_map train step over
every visible device (batch data-parallel, grads synced per
``--grad-sync``); ``--host-devices 8`` forces an 8-way CPU host platform
for smoke runs.  Checkpoints gather sharded leaves to host, so a run
checkpointed on 8 devices resumes on 1 (and vice versa):

    # 8-way sharded run, compressed grad sync, checkpoint every 100 steps
    # (--batch must divide the data-axis size or the batch silently
    # replicates — the driver warns)
    PYTHONPATH=src python examples/train_100m_e2e.py --steps 200 --batch 8 \
        --host-devices 8 --mesh host --grad-sync s2fp8
    # resume the SAME checkpoint single-device
    PYTHONPATH=src python examples/train_100m_e2e.py --steps 300 --batch 8 \
        --host-devices 1 --mesh none

Quantized FSDP (ISSUE 9): ``--shard-params fsdp`` shards param/optimizer
leaves over the data axis (ZeRO-3) with just-in-time f32 all-gathers;
``--shard-params fsdp_q`` gathers the S2FP8 *payloads* (1 byte/element on
the wire) straight into the banked GEMMs:

    PYTHONPATH=src python examples/train_100m_e2e.py --steps 200 --batch 8 \
        --host-devices 8 --mesh host --shard-params fsdp_q

This is the deliverable-(b) driver: full stack (config -> model -> policy ->
optimizer/schedule -> data pipeline -> TrainLoop with watchdog/checkpoints).
"""
import argparse
import math
import os


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policy", default="s2fp8")
    ap.add_argument("--ckpt-dir", default="/tmp/ckpt_100m")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="host",
                    help="'host' (all devices on the data axis), a 'DxT' "
                         "spec like '8x1', or 'none' for the meshless step")
    ap.add_argument("--grad-sync", default="f32", choices=["f32", "s2fp8"],
                    help="cross-shard gradient sync: plain f32 psum or the "
                         "S2FP8-compressed reduce-scatter/all-gather")
    ap.add_argument("--grad-sync-min-size", type=int, default=1 << 16,
                    help="element floor below which leaves keep the exact "
                         "f32 sync even under s2fp8 (and the floor for the "
                         "FSDP compressed grad-scatter leg)")
    ap.add_argument("--shard-params", default="replicated",
                    choices=["replicated", "fsdp", "fsdp_q"],
                    help="param/opt placement: replicated, ZeRO-3 fsdp "
                         "(f32 just-in-time gather), or fsdp_q (S2FP8 "
                         "payload gather straight into the banked GEMMs; "
                         "needs an s2fp8 policy + --stats-refresh-every)")
    ap.add_argument("--stats-refresh-every", type=int, default=16,
                    help="StatsBank refresh cadence for s2fp8 policies "
                         "(0 = exact stats every truncation)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host-platform devices (CPU smoke runs); "
                         "must be set before jax initializes")
    ap.add_argument("--metrics-sink", default=None,
                    help="route loop records and per-site FP8 health "
                         "telemetry to a sink: jsonl:<path>, csv:<path>, "
                         "console (telemetry rides the StatsBank refresh "
                         "when --stats-refresh-every > 0)")
    ap.add_argument("--guard", action="store_true",
                    help="arm the in-step StepGuard + the TrainLoop "
                         "escalation ladder (training/guard.py): bad steps "
                         "are rejected in-trace and escalate skip -> "
                         "forced refresh -> snapshot rollback -> restore")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="with --guard: push the train carry onto the "
                         "in-memory snapshot ring every K clean steps "
                         "(the ladder's rollback target)")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={args.host_devices}"

    # late imports: --host-devices must land in XLA_FLAGS before jax
    # touches the backend (device count locks on first init)
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.data import synthetic
    from repro.launch.mesh import make_host_mesh, make_mesh_from_spec
    from repro.models import transformer as tlm
    from repro.optim import optimizers, schedules
    from repro.training.trainer import TrainLoop, make_train_step

    CFG = ArchConfig(
        name="lm-134m", family="dense",
        n_layers=12, d_model=768, n_heads=12, kv_heads=4, d_ff=2048,
        vocab=32_000, head_dim=64, activation="silu_glu", tie_embeddings=True,
        remat=False, attn_impl="flash",
    )

    if args.mesh == "none":
        mesh = None
    elif args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_mesh_from_spec(args.mesh)

    if mesh is not None:
        from repro.parallel import sharding as shd
        n_shards = shd.mesh_batch_size(mesh)
        if args.batch % n_shards != 0:
            print(f"[e2e] WARNING: --batch {args.batch} does not divide "
                  f"the {n_shards}-way data axis — the batch will be "
                  f"REPLICATED (every device computes the full batch)")

    n_params = CFG.n_params()
    print(f"[e2e] {CFG.name}: {n_params/1e6:.0f}M params, "
          f"policy={args.policy}, devices={len(jax.devices())}, "
          f"mesh={'none' if mesh is None else dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"grad-sync={args.grad_sync}")

    # fsdp_q hands gathered payloads straight to qdot_train, so the GEMMs
    # must take the payload route even off the pallas engines
    pol = make_policy(args.policy,
                      gemm_mode=("payload" if args.shard_params == "fsdp_q"
                                 else "auto"))
    params = tlm.init_lm(CFG, jax.random.PRNGKey(args.seed))
    opt = optimizers.adamw(weight_decay=0.01)
    sched = schedules.cosine(3e-4 * 8, warmup=20, total=args.steps)

    def loss_fn(p, batch, pol_):
        return tlm.loss_fn(p, batch["tokens"], batch["labels"], CFG, pol_)

    stats_cfg = None
    bank = None
    table = synthetic.make_markov_table(args.seed, CFG.vocab)

    def data_fn(s):
        return synthetic.lm_batch(args.seed, s, args.batch, args.seq,
                                  CFG.vocab, table)

    from repro import obs
    sink = obs.make_sink(args.metrics_sink) if args.metrics_sink else None
    telemetry = None
    if args.policy in ("s2fp8", "s2fp8_e4m3") and args.stats_refresh_every:
        stats_cfg = statsbank.StatsConfig(
            refresh_every=args.stats_refresh_every,
            telemetry=sink is not None)
        bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol,
                                   stats_cfg)
        print(f"[e2e] statsbank: {len(bank)} sites, refresh every "
              f"{stats_cfg.refresh_every} steps"
              + (" (global under the mesh)" if mesh is not None else "")
              + (", telemetry on" if stats_cfg.telemetry else ""))
        if sink is not None:
            telemetry = obs.Telemetry(sink, every=args.stats_refresh_every)

    guard_cfg = None
    guard_state = None
    if args.guard:
        from repro.training import guard as guard_mod
        guard_cfg = guard_mod.GuardConfig()
        guard_state = guard_mod.init_state()
        print("[e2e] stepguard armed"
              + (f", snapshot ring every {args.snapshot_every}"
                 if args.snapshot_every else ""))

    if args.shard_params != "replicated":
        if mesh is None:
            raise SystemExit("--shard-params needs a mesh (--mesh != none)")
        if args.shard_params == "fsdp_q" and stats_cfg is None:
            raise SystemExit("--shard-params fsdp_q needs an s2fp8 policy "
                             "with --stats-refresh-every > 0")
        print(f"[e2e] params {args.shard_params}: opt/param leaves shard "
              f"dim 0 over the data axis (ZeRO-3)")
    step_fn = make_train_step(loss_fn, opt, sched, pol, stats=stats_cfg,
                              mesh=mesh, grad_sync_mode=args.grad_sync,
                              grad_sync_min_size=args.grad_sync_min_size,
                              telemetry=telemetry, guard=guard_cfg,
                              param_sharding=args.shard_params)

    # event_fn surfaces checkpoint_quarantined through the same sink the
    # ladder's intervention events use
    ck = CheckpointManager(args.ckpt_dir, keep=2,
                           event_fn=sink.emit if sink is not None else None)
    loop = TrainLoop(step_fn, params, opt.init(params), data_fn,
                     ckpt_manager=ck, ckpt_every=100, log_every=10,
                     stats_bank=bank, sink=sink, guard_state=guard_state,
                     snapshot_every=args.snapshot_every)
    loop.maybe_resume()
    hist = loop.run(args.steps)
    if sink is not None:
        sink.close()
    first = hist[0]["loss"] if loop.start_step == 0 else float("nan")
    print(f"[e2e] done: start-loss {first if first == first else 'resumed'}"
          f" final-loss {hist[-1]['loss']:.4f} over {len(hist)} steps "
          f"(ln V = {math.log(CFG.vocab):.2f})")


if __name__ == "__main__":
    main()
