"""End-to-end serving demo: dense engine, then the paged-payload engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.launch import api
from repro.serving import bank as sbank
from repro.serving.engine import LMServer, PayloadLMServer, Request


def run(server, reqs, label):
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    ticks = server.run_to_completion()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"[{label}] served {len(reqs)} requests / {tok} tokens in "
          f"{ticks} ticks, {dt:.2f}s ({tok/dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:2]):
        print(f"  req{i}: {list(r.prompt[:4])}... -> {r.out}")


def make_reqs(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, 12, dtype=np.int32),
                    max_new_tokens=12) for _ in range(n)]


# Dense engine: handles any block pattern (gemma's sliding-window mix).
cfg = get_reduced_config("gemma3_1b").replace(remat=False)
params = api.init_params(cfg, jax.random.PRNGKey(0))
server = LMServer(cfg, params, make_policy("s2fp8"), slots=4, max_len=96)
run(server, make_reqs(cfg, 10), "dense/gemma3_1b")

# Payload engine: global attention only; KV stored as S2FP8 payload blocks
# with (alpha, beta) frozen at export — decode runs zero stats reductions.
cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False)
pol = make_policy("s2fp8", gemm_mode="payload")
params = api.init_params(cfg, jax.random.PRNGKey(0))
bank = sbank.export_serving_bank(params, cfg, pol, prompt_len=12, passes=1)
server = PayloadLMServer(cfg, params, pol, bank=bank, slots=4, max_len=96,
                         block=16, cache_fmt="e5m2")
pool_b, stats_b = server.cache_bytes()
print(f"[payload] paged cache: {pool_b/1e6:.2f} MB pool (1 B/elt) + "
      f"{stats_b} B frozen stats")
run(server, make_reqs(cfg, 10, seed=1), "payload/minicpm_2b")
