"""End-to-end serving demo: batched requests through the slot engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.launch import api
from repro.serving.engine import LMServer, Request

cfg = get_reduced_config("gemma3_1b").replace(remat=False)
params = api.init_params(cfg, jax.random.PRNGKey(0))
server = LMServer(cfg, params, make_policy("s2fp8"), slots=4, max_len=96)

rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(0, cfg.vocab, 12, dtype=np.int32),
                max_new_tokens=12) for _ in range(10)]
for r in reqs:
    server.submit(r)
t0 = time.perf_counter()
ticks = server.run_to_completion()
dt = time.perf_counter() - t0
tok = sum(len(r.out) for r in reqs)
print(f"served {len(reqs)} requests / {tok} tokens in {ticks} ticks, "
      f"{dt:.2f}s ({tok/dt:.1f} tok/s, sliding-window + global attention mix)")
for i, r in enumerate(reqs[:3]):
    print(f"req{i}: {list(r.prompt[:4])}... -> {r.out}")
