"""Paper Table 1 (mechanism): ResNet-20 / CIFAR-shape task across formats.

FP32 vs S2FP8 vs FP8 vs FP8+LS(100), SGD momentum 0.9 + step decay — the
paper's §4.2 recipe at synthetic-data scale (DESIGN.md §6).

    PYTHONPATH=src python examples/train_resnet_cifar.py --steps 80
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import resnet
from repro.optim import optimizers, schedules


def run(mode, steps, depth=20, batch=16, seed=0, loss_scale=100.0):
    pol = make_policy(mode, loss_scale=loss_scale)
    params, bn_state = resnet.init_resnet(jax.random.PRNGKey(seed), depth)
    opt = optimizers.sgd_momentum(momentum=0.9, weight_decay=1e-4)
    sched = schedules.step_decay(0.05, [int(steps * 0.6), int(steps * 0.85)])
    scale = loss_scale if mode == "fp8_ls" else 1.0

    @jax.jit
    def step(params, bn_state, opt_state, batch_, s):
        def lf(p):
            loss, (metrics, new_bn) = resnet.loss_fn(p, bn_state, batch_, pol)
            return loss * scale, (metrics, new_bn)

        (loss, (metrics, new_bn)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if scale != 1.0:
            grads = jax.tree_util.tree_map(lambda g: g / scale, grads)
        new_params, new_opt = opt.update(grads, opt_state, params, sched(s))
        return new_params, new_bn, new_opt, metrics

    opt_state = opt.init(params)
    accs, losses = [], []
    for s in range(steps):
        b = synthetic.cifar_batch(seed, s, batch)
        params, bn_state, opt_state, m = step(params, bn_state, opt_state,
                                              b, jnp.int32(s))
        losses.append(float(m["nll"]))
        accs.append(float(m["acc"]))
    tail = max(1, len(accs) // 10)
    return sum(accs[-tail:]) / tail, losses[-1]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    print(f"{'format':>12} {'final_acc':>10} {'final_loss':>11}")
    for mode in ["fp32", "s2fp8", "fp8", "fp8_ls"]:
        acc, loss = run(mode, args.steps)
        label = "fp8_ls(100)" if mode == "fp8_ls" else mode
        print(f"{label:>12} {acc:10.3f} {loss:11.4f}")
