"""Paper Table 4 (mechanism): NCF on a synthetic MovieLens-scale task.

NeuMF, Adam lr=5e-4 batch 1024, 8 predictive factors — the paper's §4.4
recipe.  Reports HR@10 (the paper's metric).

    PYTHONPATH=src python examples/train_ncf.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import ncf
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step

N_USERS, N_ITEMS = 1024, 512


def run(mode, steps, seed=0):
    pol = make_policy(mode)
    params = ncf.init_ncf(jax.random.PRNGKey(seed), N_USERS, N_ITEMS, factors=8)
    opt = optimizers.adamw()
    step = jax.jit(make_train_step(ncf.loss_fn, opt,
                                   schedules.constant(5e-4 * 4), pol))
    opt_state = opt.init(params)
    for s in range(steps):
        b = synthetic.ncf_batch(seed, s, 1024, N_USERS, N_ITEMS)
        params, opt_state, m = step(params, opt_state, b, jnp.int32(s))

    # HR@10 against 99 negatives
    rng = np.random.default_rng(seed + 1)
    users = jnp.asarray(rng.integers(0, N_USERS, 256))
    b = synthetic.ncf_batch(seed, 10_000, 256, N_USERS, N_ITEMS)
    pos = b["items"]
    neg = jnp.asarray(rng.integers(0, N_ITEMS, (256, 99)))
    hr = float(ncf.hit_ratio(params, b["users"], pos, neg, pol))
    return hr, float(m["loss"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    print(f"{'format':>8} {'HR@10':>7} {'loss':>8}")
    for mode in ["fp32", "s2fp8", "fp8"]:
        hr, loss = run(mode, args.steps)
        print(f"{mode:>8} {hr:7.3f} {loss:8.4f}")
