"""Benchmark harness: one section per paper table + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter convergence runs")
    ap.add_argument("--only", default=None,
                    choices=[None, "kernels", "convergence", "roofline"])
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.only in (None, "kernels"):
        from benchmarks import kernel_bench
        kernel_bench.main()
    if args.only in (None, "convergence"):
        from benchmarks import convergence_bench
        if args.fast:
            convergence_bench.table1_resnet(steps=30)
            convergence_bench.table3_transformer(steps=40)
            convergence_bench.table4_ncf(steps=50)
            convergence_bench.fig5_stats(steps=20)
        else:
            convergence_bench.main()
    if args.only in (None, "roofline"):
        from benchmarks import roofline_bench
        roofline_bench.main()


if __name__ == "__main__":
    main()
