"""Microbenchmarks for the S2FP8 numeric layer (paper §5 cost discussion).

Two lanes:

  * the original CSV rows (jnp reference path — the CPU-executable oracle;
    the Pallas kernels are the TPU target and validate in interpret mode
    in tests/);
  * the backend comparison the dispatch refactor is about: the
    pre-refactor truncate (eager ``s2fp8.truncate_value`` — every jnp op
    its own dispatch, ~five passes over the tensor, which is what
    non-jitted ``Policy`` callers paid per tensor) vs the backend's fused
    truncate (two compiled programs: stats reduction + fused
    apply->RNE->inverse) and the delayed-stats path (one elementwise
    program, no reduction).  A jitted four-program staged lane is also
    recorded as the compiled-vs-compiled baseline.  Results land in
    ``BENCH_kernels.json``.

On TPU the same entry points route to the compiled Pallas kernels; the
interpreter is debug-grade, so off-TPU the fused lane times the ref
backend (identical op graph, XLA-fused).
"""
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.bench_util import emit, time_jitted
from repro.core import backend as nbackend
from repro.core import fp8, s2fp8
from repro.kernels import ref

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def bench_truncate(results):
    key = jax.random.PRNGKey(0)
    be = nbackend.get_backend()           # platform default backend
    stats_j = jax.jit(s2fp8.compute_stats)
    fwd_j = jax.jit(s2fp8._forward_map)
    rne_j = jax.jit(fp8.truncate_e5m2)
    inv_j = jax.jit(s2fp8._inverse_map)

    def eager_ref(v):
        # pre-refactor execution: op-by-op dispatch, ~5 tensor passes
        return s2fp8.truncate_value(v)

    def staged_jit(v):
        # compiled-vs-compiled baseline: each Eq. 5 stage its own program
        a, b = stats_j(v)
        return inv_j(rne_j(fwd_j(v, a, b)), a, b)

    def fused(v):
        # the backend path: stats program + fused apply program
        return be.truncate(v)

    for n in [1 << 16, 1 << 20, 1 << 22]:
        x = jax.random.normal(key, (n,)) * 1e-5
        ref_us = time_jitted(eager_ref, x)
        staged_us = time_jitted(staged_jit, x)
        fused_us = time_jitted(fused, x)
        stats = be.compute_stats(x)
        delayed_us = time_jitted(lambda v: be.truncate(v, stats=stats), x)
        gbs = n * 4 / (fused_us * 1e-6) / 1e9
        emit(f"s2fp8_truncate_ref_n{n}", ref_us,
             f"{n*4/(ref_us*1e-6)/1e9:.2f}GB/s")
        emit(f"s2fp8_truncate_staged_n{n}", staged_us,
             f"{n*4/(staged_us*1e-6)/1e9:.2f}GB/s")
        emit(f"s2fp8_truncate_fused_n{n}", fused_us, f"{gbs:.2f}GB/s")
        emit(f"s2fp8_truncate_delayed_n{n}", delayed_us,
             f"{n*4/(delayed_us*1e-6)/1e9:.2f}GB/s")
        results["truncate"].append({
            "n": n, "backend": be.name,
            # pre-refactor eager execution (what non-jitted Policy ops paid)
            "ref_us": ref_us,
            # compiled four-program chain (jitted pre-refactor structure)
            "ref_staged_jit_us": staged_us,
            "fused_us": fused_us,
            "delayed_stats_us": delayed_us,
            "fused_speedup": ref_us / fused_us,
            "fused_vs_staged": staged_us / fused_us,
        })


def bench_statsbank(results):
    """The stats lane: full train-step time, exact stats (a reduction per
    truncation, every step) vs the jit-carried StatsBank (reductions under
    ``lax.cond``, skipped on non-refresh steps).  Times a non-refresh step
    — the steady state: refresh_every-1 of every refresh_every steps."""
    import jax.numpy as jnp
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    key = jax.random.PRNGKey(0)
    # small batch through big weights: the per-step cost is the WEIGHT
    # truncations (the tensors whose stats the bank amortizes), not MXU
    # flops — the shape of the win the subsystem targets
    n_tensors, dim, batch = 4, 1024, 16
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (dim, dim)) * 1e-4
              for i in range(n_tensors)}
    x = jax.random.normal(jax.random.fold_in(key, 99), (batch, dim)) * 1e-4
    pol = make_policy("s2fp8")

    def loss_fn(p, batch, pol_):
        h = batch
        for i in range(n_tensors):
            h = pol_.dot(h, p[f"w{i}"])
        return jnp.sum(h * h), {}

    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    scfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(loss_fn, params, x, pol, scfg)
    ost = opt.init(params)

    exact_step = jax.jit(make_train_step(loss_fn, opt, sched, pol))
    bank_step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg))
    # bootstrap-refresh the bank once so the timed step is pure delayed
    _, _, bank, _ = bank_step(params, ost, bank, x, jnp.int32(0))

    step = jnp.int32(1)      # 1 % 16 != 0 -> non-refresh step
    exact_us = time_jitted(lambda p: exact_step(p, ost, x, step)[2]["loss"],
                           params)
    bank_us = time_jitted(
        lambda p: bank_step(p, ost, bank, x, step)[3]["loss"], params)
    emit("statsbank_step_exact", exact_us,
         f"{n_tensors}x[{batch}x{dim}]@[{dim}x{dim}] chain")
    emit("statsbank_step_bank", bank_us,
         f"speedup {exact_us / bank_us:.2f}x (non-refresh step)")
    results["stats"].append({
        "n_tensors": n_tensors, "dim": dim, "batch": batch,
        "refresh_every": scfg.refresh_every,
        "exact_step_us": exact_us, "bank_step_us": bank_us,
        "bank_speedup": exact_us / bank_us,
        "sites": len(bank),
    })


def main():
    results = {"backend": nbackend.get_backend().name,
               "platform": jax.default_backend(),
               "truncate": [], "quantize": [], "matmul": [], "stats": []}
    key = jax.random.PRNGKey(0)

    bench_truncate(results)
    bench_statsbank(results)

    for n in [1 << 16, 1 << 20, 1 << 22]:
        x = jax.random.normal(key, (n,)) * 1e-5
        fq = jax.jit(lambda v: s2fp8.quantize(v).payload)
        us = time_jitted(fq, x)
        emit(f"s2fp8_quantize_n{n}", us, f"{n*4/(us*1e-6)/1e9:.2f}GB/s")
        results["quantize"].append({"n": n, "us": us})

    for m, k, n2 in [(512, 512, 512), (1024, 1024, 1024)]:
        a = jax.random.normal(key, (m, k)) * 1e-3
        b = jax.random.normal(key, (k, n2)) * 1e-3
        pa, aa, ab = ref.s2fp8_quant_ref(a)
        pb, ba, bb = ref.s2fp8_quant_ref(b)
        f = jax.jit(ref.s2fp8_matmul_ref)
        us = time_jitted(f, pa, aa, ab, pb, ba, bb)
        gflops = 2 * m * k * n2 / (us * 1e-6) / 1e9
        emit(f"s2fp8_matmul_{m}x{k}x{n2}", us, f"{gflops:.1f}GFLOP/s")
        results["matmul"].append({"mkn": [m, k, n2], "us": us,
                                  "gflops": gflops})

    q = jax.random.normal(key, (1, 4, 1024, 64))
    kv = jax.random.normal(key, (1, 4, 1024, 64))
    f = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=True))
    us = time_jitted(f, q, kv, kv)
    emit("attention_ref_1k", us, "oracle")

    with open(BENCH_JSON, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
