"""Microbenchmarks for the S2FP8 numeric layer (paper §5 cost discussion).

Two lanes:

  * the original CSV rows (jnp reference path — the CPU-executable oracle;
    the Pallas kernels are the TPU target and validate in interpret mode
    in tests/);
  * the backend comparison the dispatch refactor is about: the
    pre-refactor truncate (eager ``s2fp8.truncate_value`` — every jnp op
    its own dispatch, ~five passes over the tensor, which is what
    non-jitted ``Policy`` callers paid per tensor) vs the backend's fused
    truncate (two compiled programs: stats reduction + fused
    apply->RNE->inverse) and the delayed-stats path (one elementwise
    program, no reduction).  A jitted four-program staged lane is also
    recorded as the compiled-vs-compiled baseline.  Results land in
    ``BENCH_kernels.json``.

On TPU the same entry points route to the compiled Pallas kernels; the
interpreter is debug-grade, so off-TPU the fused lane times the ref
backend (identical op graph, XLA-fused).
"""
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.bench_util import emit, time_jitted
from repro.core import backend as nbackend
from repro.core import fp8, s2fp8
from repro.kernels import ref

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def bench_truncate(results):
    key = jax.random.PRNGKey(0)
    be = nbackend.get_backend()           # platform default backend
    stats_j = jax.jit(s2fp8.compute_stats)
    fwd_j = jax.jit(s2fp8._forward_map)
    rne_j = jax.jit(fp8.truncate_e5m2)
    inv_j = jax.jit(s2fp8._inverse_map)

    def eager_ref(v):
        # pre-refactor execution: op-by-op dispatch, ~5 tensor passes
        return s2fp8.truncate_value(v)

    def staged_jit(v):
        # compiled-vs-compiled baseline: each Eq. 5 stage its own program
        a, b = stats_j(v)
        return inv_j(rne_j(fwd_j(v, a, b)), a, b)

    def fused(v):
        # the backend path: stats program + fused apply program
        return be.truncate(v)

    for n in [1 << 16, 1 << 20, 1 << 22]:
        x = jax.random.normal(key, (n,)) * 1e-5
        ref_us = time_jitted(eager_ref, x)
        staged_us = time_jitted(staged_jit, x)
        fused_us = time_jitted(fused, x)
        stats = be.compute_stats(x)
        delayed_us = time_jitted(lambda v: be.truncate(v, stats=stats), x)
        gbs = n * 4 / (fused_us * 1e-6) / 1e9
        emit(f"s2fp8_truncate_ref_n{n}", ref_us,
             f"{n*4/(ref_us*1e-6)/1e9:.2f}GB/s")
        emit(f"s2fp8_truncate_staged_n{n}", staged_us,
             f"{n*4/(staged_us*1e-6)/1e9:.2f}GB/s")
        emit(f"s2fp8_truncate_fused_n{n}", fused_us, f"{gbs:.2f}GB/s")
        emit(f"s2fp8_truncate_delayed_n{n}", delayed_us,
             f"{n*4/(delayed_us*1e-6)/1e9:.2f}GB/s")
        results["truncate"].append({
            "n": n, "backend": be.name,
            # pre-refactor eager execution (what non-jitted Policy ops paid)
            "ref_us": ref_us,
            # compiled four-program chain (jitted pre-refactor structure)
            "ref_staged_jit_us": staged_us,
            "fused_us": fused_us,
            "delayed_stats_us": delayed_us,
            "fused_speedup": ref_us / fused_us,
            "fused_vs_staged": staged_us / fused_us,
        })


def bench_statsbank(results, smoke=False):
    """The stats lane: full train-step time, exact stats (a reduction per
    truncation, every step) vs the jit-carried StatsBank (reductions under
    ``lax.cond``, skipped on non-refresh steps).  Times a non-refresh step
    — the steady state: refresh_every-1 of every refresh_every steps."""
    import jax.numpy as jnp
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    key = jax.random.PRNGKey(0)
    # small batch through big weights: the per-step cost is the WEIGHT
    # truncations (the tensors whose stats the bank amortizes), not MXU
    # flops — the shape of the win the subsystem targets
    n_tensors, dim, batch = (2, 256, 8) if smoke else (4, 1024, 16)
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (dim, dim)) * 1e-4
              for i in range(n_tensors)}
    x = jax.random.normal(jax.random.fold_in(key, 99), (batch, dim)) * 1e-4
    pol = make_policy("s2fp8")

    def loss_fn(p, batch, pol_):
        h = batch
        for i in range(n_tensors):
            h = pol_.dot(h, p[f"w{i}"])
        return jnp.sum(h * h), {}

    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    scfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(loss_fn, params, x, pol, scfg)
    ost = opt.init(params)

    exact_step = jax.jit(make_train_step(loss_fn, opt, sched, pol))
    bank_step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg))
    # bootstrap-refresh the bank once so the timed step is pure delayed
    _, _, bank, _ = bank_step(params, ost, bank, x, jnp.int32(0))

    step = jnp.int32(1)      # 1 % 16 != 0 -> non-refresh step
    exact_us = time_jitted(lambda p: exact_step(p, ost, x, step)[2]["loss"],
                           params)
    bank_us = time_jitted(
        lambda p: bank_step(p, ost, bank, x, step)[3]["loss"], params)
    emit("statsbank_step_exact", exact_us,
         f"{n_tensors}x[{batch}x{dim}]@[{dim}x{dim}] chain")
    emit("statsbank_step_bank", bank_us,
         f"speedup {exact_us / bank_us:.2f}x (non-refresh step)")
    results["stats"].append({
        "n_tensors": n_tensors, "dim": dim, "batch": batch,
        "refresh_every": scfg.refresh_every,
        "exact_step_us": exact_us, "bank_step_us": bank_us,
        "bank_speedup": exact_us / bank_us,
        "sites": len(bank),
    })


def modeled_hbm_bytes(mode: str, m: int, k: int, n: int) -> dict:
    """Modeled per-train-step HBM traffic of ONE GEMM's numerics dataflow
    (operand/result tensor crossings only; the MXU-internal traffic is
    common to both).  See kernels/README.md, "payload-domain training
    dataflow" for the crossing-by-crossing derivation."""
    mk, kn, mn = m * k, k * n, m * n
    if mode == "fig4":
        # fwd: read a (4) + write At (4), same for b; dot reads At, Bt (4)
        # and writes the raw f32 output (4); the separate out truncation
        # reads it back + writes (4+4); At, Bt persist as residuals.
        fwd = 8 * mk + 8 * kn + 4 * (mk + kn) + 12 * mn
        # bwd: trunc g (8); dA GEMM reads g_t + Bt (4) + writes raw dA
        # (4), trunc dA read+write (8); dB likewise
        bwd = 8 * mn + 4 * (mn + kn) + 12 * mk + 4 * (mk + mn) + 12 * kn
    elif mode == "payload":
        # fwd: quantize a: read 4B, write 1B payload; GEMM streams payloads
        # at 1B, epilogue writes the truncated output in the same pass.
        fwd = 5 * mk + 5 * kn + 1 * (mk + kn) + 4 * mn
        # bwd: quantize g (4+1); dA GEMM streams qg + qb (1B) with fused
        # dA truncation epilogue (write 4); dB likewise
        bwd = 5 * mn + (mn + kn) + 4 * mk + (mk + mn) + 4 * kn
    else:
        raise ValueError(mode)
    total = fwd + bwd
    return {"total_bytes": total,
            "bytes_per_element": total / (mk + kn + mn)}


def modeled_hbm_bytes_batched(mode: str, g: int, gb: int, m: int, k: int,
                              n: int) -> dict:
    """Batched extension of :func:`modeled_hbm_bytes`: the A operand and
    the output carry the full combined batch ``g``; B is stored at its
    broadcast batch ``gb <= g`` (the ``becd,edf`` weight reuse).  Per-
    tensor crossings split into quantize/truncate-side passes (scale with
    the STORED size) and GEMM-read passes (scale with the streamed size:
    broadcast B payload tiles re-stream once per broadcast group)."""
    amk, bkn, ymn = g * m * k, gb * k * n, g * m * n
    b_stream = g * k * n                  # B payload crossings per GEMM read
    if mode == "fig4":
        # fig4 streams the truncated f32 B per batch slice too (XLA
        # broadcasts the 4-byte tensor through the batched dot)
        fwd = 8 * amk + 8 * bkn + 4 * (amk + b_stream) + 12 * ymn
        bwd = (8 * ymn + 4 * (ymn + b_stream) + 12 * amk
               + 4 * (amk + ymn) + 12 * bkn)
    elif mode == "payload":
        fwd = 5 * amk + 5 * bkn + 1 * (amk + b_stream) + 4 * ymn
        bwd = (5 * ymn + (ymn + b_stream) + 4 * amk
               + (amk + ymn) + 4 * bkn)
    else:
        raise ValueError(mode)
    total = fwd + bwd
    return {"total_bytes": total,
            "bytes_per_element": total / (amk + bkn + ymn)}


def modeled_hbm_bytes_conv(mode: str, b: int, oh: int, ow: int, kh: int,
                           kw: int, cin: int, cout: int) -> dict:
    """Conv lowering traffic model.  The payload path pays the im2col
    materialization honestly — the patch tensor (a ~kh*kw-fold read
    amplification of the activation) crosses HBM at 4 B once (write +
    quantize read) before collapsing to 1-byte payloads — and still wins
    on the GEMM-side streaming; the fig4 chain runs
    ``lax.conv_general_dilated`` on truncated f32 tensors (no im2col
    blowup, but every GEMM-equivalent crossing at 4 B)."""
    m, k, n = b * oh * ow, kh * kw * cin, cout
    x_elems = m * cin                      # ~input activation size
    if mode == "fig4":
        gemm = modeled_hbm_bytes("fig4", m, k, n)
        # replace the im2col-sized operand crossings with x-sized ones:
        # fig4 truncates x (8/elt) and the conv reads it window-wise (~4)
        total = gemm["total_bytes"] - 28 * m * k + 28 * x_elems
    elif mode == "payload":
        gemm = modeled_hbm_bytes("payload", m, k, n)
        # + patch materialization: 4 B write + 4 B quantize read per patch
        # element, replacing the 4 B quantize read of a dense operand
        total = gemm["total_bytes"] - 4 * m * k + 8 * m * k
    else:
        raise ValueError(mode)
    return {"total_bytes": total,
            "bytes_per_element": total / (x_elems + k * n + m * n)}


def modeled_ici_bytes(mode: str, n_elements: int, axis_size: int) -> dict:
    """Modeled per-sync interconnect traffic of ONE gradient leaf's DP
    all-reduce across ``axis_size`` devices (bytes leaving each device;
    ring schedule).

      * ``f32``   — classic all-reduce: reduce-scatter + all-gather, both
        at 4 B/elt: ``2 * (n-1)/n * 4`` bytes/elt.
      * ``s2fp8`` — the compressed schedule (core/collectives.py): the
        reduce-scatter leg runs in bf16 (2 B/elt) and the all-gather leg
        moves 1-byte S2FP8 payloads plus one 8-byte (alpha, beta) pair
        per device-shard: ``(n-1)/n * (2 + 1)`` bytes/elt + stats.

    ~2.7x traffic cut; the dp lane records both next to the measured step
    times so the CPU numbers carry the TPU-pod story.
    """
    n = axis_size
    frac = (n - 1) / n
    if mode == "f32":
        total = 2 * frac * 4 * n_elements
    elif mode == "s2fp8":
        total = frac * (2 + 1) * n_elements + frac * 8 * n
    else:
        raise ValueError(mode)
    return {"total_bytes": total, "bytes_per_element": total / n_elements}


def bench_dp(results, smoke=False):
    """Data-parallel lane: full mesh-native train-step time (ISSUE 5,
    ``make_train_step(mesh=...)``) with f32 vs S2FP8-compressed gradient
    sync, on whatever devices exist (the CI multi-device lane forces 8
    host devices; 1 device still exercises the full collective program).
    StatsBank steady state; plus the modeled per-sync interconnect bytes
    at n=8 for the leaf sizes involved."""
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.launch.mesh import make_host_mesh
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    key = jax.random.PRNGKey(3)
    n_tensors, dim, batch = (2, 256, 8) if smoke else (4, 1024, 16)
    ndev = len(jax.devices())
    mesh = make_host_mesh()              # all devices on the data axis
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (dim, dim)) * 1e-4
              for i in range(n_tensors)}
    x = jax.random.normal(jax.random.fold_in(key, 99),
                          (batch, dim)) * 1e-4

    def loss_fn(p, batch_, pol_):
        h = batch_
        for i in range(n_tensors):
            h = pol_.dot(h, p[f"w{i}"])
        return jnp.mean(h * h), {}

    pol = make_policy("s2fp8")
    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    scfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(loss_fn, params, x, pol, scfg)
    ost = opt.init(params)
    min_size = dim * dim // 2            # leaves must actually compress

    lane = {"n_devices": ndev, "n_tensors": n_tensors, "dim": dim,
            "batch": batch, "grad_elements": n_tensors * dim * dim}
    for mode in ("f32", "s2fp8"):
        step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg,
                                       mesh=mesh, grad_sync_mode=mode,
                                       grad_sync_min_size=min_size))
        _, _, bank_w, _ = jax.block_until_ready(
            step(params, ost, bank, x, jnp.int32(0)))   # bootstrap refresh
        us = time_jitted(
            lambda p: step(p, ost, bank_w, x, jnp.int32(1))[3]["loss"],
            params, iters=2 if smoke else 5)
        lane[f"{mode}_step_us"] = us
        emit(f"dp_train_{mode}_sync_d{ndev}", us,
             f"{n_tensors}x[{dim}x{dim}] grads, {ndev}-way mesh")
    lane["s2fp8_vs_f32"] = lane["f32_step_us"] / lane["s2fp8_step_us"]
    lane["modeled_ici_bytes_per_elt_n8"] = {
        m: modeled_ici_bytes(m, n_tensors * dim * dim, 8)["bytes_per_element"]
        for m in ("f32", "s2fp8")}
    results["dp"].append(lane)


def modeled_fsdp_ici_bytes(mode: str, n_elements: int,
                           axis_size: int) -> dict:
    """Modeled per-step interconnect traffic of ONE payload-eligible
    param leaf under the param-sharding modes (bytes leaving each device;
    ring schedule, ``(n-1)/n`` per hop-leg):

      * ``replicated`` — no param movement; the grad all-reduces
        (reduce-scatter + all-gather, both f32): ``2 * frac * 4``/elt.
      * ``fsdp``       — just-in-time f32 all-gather (4 B/elt) + grad
        reduce-scatter only (FSDP grads need to exist at the owner shard,
        so the all-gather half of the all-reduce is dropped).
      * ``fsdp_q``     — the gather leg moves 1-byte S2FP8 payloads (plus
        one 8-byte (alpha, beta) pair per device); same f32 grad
        reduce-scatter.  Gather leg = 4x below fsdp — the wire cut the
        ISSUE 9 acceptance pins.
    """
    n = axis_size
    frac = (n - 1) / n
    if mode == "replicated":
        gather = 0.0
        grad = 2 * frac * 4 * n_elements
    elif mode == "fsdp":
        gather = frac * 4 * n_elements
        grad = frac * 4 * n_elements
    elif mode == "fsdp_q":
        gather = frac * 1 * n_elements + 8 * (n - 1)
        grad = frac * 4 * n_elements
    else:
        raise ValueError(mode)
    total = gather + grad
    return {"gather_bytes": gather, "grad_bytes": grad,
            "total_bytes": total,
            "bytes_per_element": total / n_elements}


def bench_fsdp(results, smoke=False):
    """Quantized-FSDP lane (ISSUE 9): the mesh-native train step with
    params/optimizer replicated vs sharded (f32 gather) vs sharded with
    S2FP8 payload streaming, on whatever devices exist.  Next to the
    measured step times it records the modeled n=8 interconnect bytes
    (``modeled_fsdp_ici_bytes``) and the modeled per-device resident
    param+opt HBM bytes (launch/memplan.py — the same per-leaf rules the
    trainer shards by), which carry the TPU-pod story off-device:
    gather-leg wire ~4x down and resident store ~n_shards x down vs
    replicated."""
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.launch import memplan
    from repro.launch.mesh import make_host_mesh
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    key = jax.random.PRNGKey(7)
    n_tensors, dim, batch = (2, 256, 8) if smoke else (4, 1024, 16)
    ndev = len(jax.devices())
    mesh = make_host_mesh()
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (dim, dim)) * 1e-4
              for i in range(n_tensors)}
    x = jax.random.normal(jax.random.fold_in(key, 99),
                          (batch, dim)) * 1e-4

    def loss_fn(p, batch_, pol_):
        h = batch_
        for i in range(n_tensors):
            h = pol_.dot(h, p[f"w{i}"])
        return jnp.mean(h * h), {}

    # fsdp_q hands FSDPPayloadParam wrappers to Policy.dot, so the GEMMs
    # must take the payload route even on the ref engine
    pol = make_policy("s2fp8", gemm_mode="payload")
    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    scfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(loss_fn, params, x, pol, scfg)
    ost = opt.init(params)

    lane = {"n_devices": ndev, "n_tensors": n_tensors, "dim": dim,
            "batch": batch, "param_elements": n_tensors * dim * dim}
    for mode in ("replicated", "fsdp", "fsdp_q"):
        step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg,
                                       mesh=mesh, param_sharding=mode))
        p1, o1, bank_w, _ = jax.block_until_ready(
            step(params, ost, bank, x, jnp.int32(0)))   # bootstrap refresh
        us = time_jitted(
            lambda b_: step(p1, o1, bank_w, b_, jnp.int32(1))[3]["loss"],
            x, iters=2 if smoke else 5)
        lane[f"{mode}_step_us"] = us
        emit(f"fsdp_train_{mode}_d{ndev}", us,
             f"{n_tensors}x[{dim}x{dim}] params, {ndev}-way mesh")
    n_elt = n_tensors * dim * dim
    ici = {m: modeled_fsdp_ici_bytes(m, n_elt, 8)
           for m in ("replicated", "fsdp", "fsdp_q")}
    lane["modeled_ici_bytes_per_elt_n8"] = {
        m: v["bytes_per_element"] for m, v in ici.items()}
    lane["modeled_gather_bytes_per_elt_n8"] = {
        m: v["gather_bytes"] / n_elt for m, v in ici.items()}
    ostruct = jax.eval_shape(opt.init, params)
    lane["modeled_hbm_resident_bytes_n8"] = {
        m: memplan.plan_state(params, ostruct, 8, m)["steady_bytes"]
        for m in ("replicated", "fsdp", "fsdp_q")}
    results["fsdp"].append(lane)


def bench_gemm(results, sizes=(512, 1024, 2048), smoke=False):
    """The payload-domain training GEMM lane: full fwd+bwd step over one
    ``Policy.dot``, three ways —

      * ``fig4_exact``   — the pre-qdot default: composed Fig. 4 chain,
        exact stats (a reduction per truncation site, every call);
      * ``fig4_bank``    — the Fig. 4 chain inside a StatsBank session
        (steady-state non-refresh step);
      * ``payload_bank`` — ``qdot_train``: payloads + fused epilogue +
        NT/TN payload backward, bank stats (steady state).

    The acceptance comparison is payload_bank vs the jitted Fig. 4 chain.
    Off-TPU the backends route to the jnp engine, so the modeled HBM
    bytes/element column carries the TPU story (1- vs 4-byte streaming).
    """
    from repro.core.policy import make_policy

    key = jax.random.PRNGKey(42)
    iters = 2 if smoke else 5

    def loss_fn(params, _batch, pol_):
        y = pol_.dot(params["a"], params["b"])
        return jnp.sum(y * y), {}

    for n in sizes:
        a = jax.random.normal(key, (n, n)) * 1e-4
        b = jax.random.normal(jax.random.fold_in(key, 1), (n, n)) * 1e-4
        params = {"a": a, "b": b}

        pol_exact = make_policy("s2fp8", gemm_mode="fig4")
        grad_exact = jax.jit(jax.value_and_grad(
            lambda p: loss_fn(p, None, pol_exact)[0]))
        exact_us = time_jitted(grad_exact, params, iters=iters)

        lane = {"n": n, "fig4_exact_us": exact_us}
        lane.update(_banked_lane_times(loss_fn, params, None, iters))

        flop = 3 * 2 * n ** 3                         # fwd + dA + dB GEMMs
        lane["payload_gflops"] = flop / (lane["payload_bank_us"] * 1e-6) / 1e9
        lane["payload_vs_fig4_exact"] = exact_us / lane["payload_bank_us"]
        lane["modeled_hbm_bytes_per_elt"] = {
            m_: modeled_hbm_bytes(m_, n, n, n)["bytes_per_element"]
            for m_ in ("fig4", "payload")}
        emit(f"gemm_train_fig4_exact_{n}", exact_us, "exact-stats chain")
        emit(f"gemm_train_fig4_bank_{n}", lane["fig4_bank_us"],
             "bank steady state")
        emit(f"gemm_train_payload_bank_{n}", lane["payload_bank_us"],
             f"{lane['payload_gflops']:.1f}GFLOP/s "
             f"{lane['payload_vs_fig4_exact']:.2f}x vs fig4-exact")
        results["gemm"].append(lane)


def _banked_lane_times(loss_fn, params, batch, iters: int) -> dict:
    """fig4-vs-payload train-step times over one loss, StatsBank steady
    state — the shared harness of the gemm/moe/conv lanes."""
    from repro.core import statsbank
    from repro.core.policy import make_policy

    scfg = statsbank.StatsConfig(refresh_every=16)
    out = {}
    for gm in ("fig4", "payload"):
        pol = make_policy("s2fp8", gemm_mode=gm)
        bank = statsbank.init_bank(loss_fn, params, batch, pol, scfg)

        @jax.jit
        def banked(p, bk, step, pol=pol):
            def f(p_, bk_):
                with statsbank.bind(bk_, step, scfg):
                    l, _ = loss_fn(p_, batch, pol)
                return l
            loss, (g, up) = jax.value_and_grad(f, argnums=(0, 1))(p, bk)
            return loss, g, statsbank.merge_updates(bk, up)

        _, _, bank = jax.block_until_ready(
            banked(params, bank, jnp.int32(0)))   # bootstrap refresh
        step = jnp.int32(1)                        # steady state
        out[f"{gm}_bank_us"] = time_jitted(
            lambda p: banked(p, bank, step)[0], params, iters=iters)
    out["payload_vs_fig4_bank"] = out["fig4_bank_us"] / out["payload_bank_us"]
    return out


def modeled_hbm_bytes_attn(mode: str, s: int, d: int) -> dict:
    """Modeled per-train-step HBM traffic of ONE attention head (fwd+bwd)
    at sequence length ``s``, head dim ``d`` — plus the bytes its saved
    residuals occupy.  Crossing-by-crossing derivations in
    kernels/README.md ("payload flash dataflow"); the headline is
    structural: only the flash modes have NO s^2 term, and the payload
    flash node's residuals are 1-byte payloads.

      * ``einsum_payload`` — the attention einsum PAIR as two batched
        payload GEMMs (s x d x s scores, s x s x d values; the [s, s]
        score tensor round-trips HBM between them) + the f32 softmax
        passes over it (read+write fwd = 8 s^2; backward reads
        probs/dprobs and writes dscores = 12 s^2).  Residuals: the two
        GEMM nodes' payloads — q, k, v (1 B each) and the [s, s] probs
        payload.
      * ``flash_payload`` — the fused node: quantize q/k/v (4 B read +
        1 B payload write each), the kernel streams payloads at 1 B and
        writes the truncated out (4 B) + lse (4 B/row), out re-payloads
        at 5 B/elt; backward quantizes g (5 B), computes delta from the
        two payloads (2 B read + 4 B/row write), re-streams 4 payloads
        through BOTH backward kernels (8 B/elt total) with lse/delta
        (8 B/row), writes raw dq/dk/dv (12 B) and truncates them
        (8 B each).  Residuals: four 1-byte payloads + f32 lse rows.
      * ``fig4_flash`` — flash over the Fig. 4 chain (PR 4's routing):
        truncate q/k/v (8 B each), flash reads f32 operands (12 B) and
        writes out (4 B) + lse; out truncation (8 B); backward re-reads
        the four f32 residuals (16 B), writes raw grads (12 B) and
        truncates them (24 B).  Residuals: four f32 tensors + lse — the
        ~4x denominator for the payload node's residual cut.
    """
    sd, ss, srow = s * d, s * s, s
    if mode == "einsum_payload":
        g1 = modeled_hbm_bytes("payload", s, d, s)["total_bytes"]
        g2 = modeled_hbm_bytes("payload", s, s, d)["total_bytes"]
        total = g1 + g2 + 8 * ss + 12 * ss
        residual = ss + 3 * sd
    elif mode == "flash_payload":
        fwd = 15 * sd + 3 * sd + 4 * sd + 4 * srow + 5 * sd
        bwd = (5 * sd + (2 * sd + 4 * srow) + (8 * sd + 8 * srow)
               + 12 * sd + 24 * sd)
        total = fwd + bwd
        residual = 4 * sd + 4 * srow
    elif mode == "fig4_flash":
        fwd = 24 * sd + 12 * sd + 4 * sd + 4 * srow + 8 * sd
        bwd = 16 * sd + 8 * srow + 12 * sd + 24 * sd
        total = fwd + bwd
        residual = 16 * sd + 4 * srow
    else:
        raise ValueError(mode)
    return {"total_bytes": total, "residual_bytes": residual,
            "bytes_per_element": total / (3 * sd + sd)}


def _attn_step_time(loss_fn, pol, params, batch, iters: int) -> float:
    """One banked steady-state train-step time (us) for an attention loss
    under ``pol`` — init_bank discovery, one bootstrap refresh step, then
    the timed non-refresh step (the _banked_lane_times recipe, for lanes
    where the POLICY ROUTING differs rather than just gemm_mode)."""
    from repro.core import statsbank

    scfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(loss_fn, params, batch, pol, scfg)

    @jax.jit
    def banked(p, bk, step):
        def f(p_, bk_):
            with statsbank.bind(bk_, step, scfg):
                l, _ = loss_fn(p_, batch, pol)
            return l
        loss, (g, up) = jax.value_and_grad(f, argnums=(0, 1))(p, bk)
        return loss, g, statsbank.merge_updates(bk, up)

    _, _, bank = jax.block_until_ready(banked(params, bank, jnp.int32(0)))
    step = jnp.int32(1)
    return time_jitted(lambda p: banked(p, bank, step)[0], params,
                       warmup=1, iters=iters)


def bench_attn(results, sizes=(1024, 4096, 16384), smoke=False):
    """Attention lane (ISSUE 6): full fwd+bwd step over one attention op,
    three ways —

      * ``flash_payload``  — the fused payload flash node
        (``Policy.flash_attention`` -> core/qdot.qflash_attention):
        1-byte Q/K/V streaming, VMEM-only score tiles, payload residuals;
      * ``einsum_payload`` — the pre-fusion routing: the einsum pair as
        two batched payload GEMMs with the [S, S] score tensor (and its
        payload residual) round-tripping HBM;
      * ``fig4``           — the composed Fig. 4 einsum chain.

    All StatsBank steady state.  The einsum lanes materialize the [S, S]
    scores, so past ``EINSUM_MAX_S`` they are skipped on the CPU lane
    (recorded explicitly as null) — the modeled bytes column carries the
    comparison there.
    """
    import math as pymath

    from repro.core.policy import make_policy

    EINSUM_MAX_S = 4096
    d = 64
    key = jax.random.PRNGKey(11)
    iters = 2 if smoke else 3

    def flash_loss(p, batch, pol_):
        out = pol_.flash_attention(p["q"], batch["k"], batch["v"],
                                   causal=True)
        return jnp.sum(out * out), {}

    def einsum_loss(p, batch, pol_):
        # the pre-fusion full_attention body, pinned here so the lane
        # keeps measuring the einsum pair now that full_attention itself
        # fast-paths payload policies to the fused node
        q, k, v = p["q"], batch["k"], batch["v"]
        s = q.shape[3]
        logits = pol_.einsum("bkgqd,bksd->bkgqs", q, k) / pymath.sqrt(d)
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        out = pol_.einsum("bkgqs,bksd->bkgqd", probs, v)
        return jnp.sum(out * out), {}

    for s in sizes:
        q = jax.random.normal(key, (1, 1, 1, s, d)) * 0.3
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, s, d)) * 0.3
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, s, d)) * 0.3
        params, batch = {"q": q}, {"k": k, "v": v}

        lane = {"s": s, "d": d}
        pol_pay = make_policy("s2fp8", gemm_mode="payload")
        lane["flash_payload_us"] = _attn_step_time(flash_loss, pol_pay,
                                                   params, batch, iters)
        emit(f"attn_train_flash_payload_s{s}", lane["flash_payload_us"],
             "fused payload flash node")
        if s <= EINSUM_MAX_S:
            lane["einsum_payload_us"] = _attn_step_time(
                einsum_loss, pol_pay, params, batch, iters)
            pol_fig4 = make_policy("s2fp8", gemm_mode="fig4")
            lane["fig4_us"] = _attn_step_time(einsum_loss, pol_fig4,
                                              params, batch, iters)
            lane["flash_vs_einsum_payload"] = (
                lane["einsum_payload_us"] / lane["flash_payload_us"])
            emit(f"attn_train_einsum_payload_s{s}",
                 lane["einsum_payload_us"],
                 f"flash speedup {lane['flash_vs_einsum_payload']:.2f}x")
            emit(f"attn_train_fig4_s{s}", lane["fig4_us"],
                 "composed einsum chain")
        else:
            lane["einsum_payload_us"] = None
            lane["fig4_us"] = None
            lane["einsum_skipped"] = (
                f"[S,S] score tensor ({s*s*4/2**30:.1f} GiB f32/head) "
                "infeasible on the CPU lane")
        lane["modeled_hbm_bytes"] = {
            m_: modeled_hbm_bytes_attn(m_, s, d)
            for m_ in ("einsum_payload", "flash_payload", "fig4_flash")}
        mb = lane["modeled_hbm_bytes"]
        lane["residual_cut_vs_fig4_flash"] = (
            mb["fig4_flash"]["residual_bytes"]
            / mb["flash_payload"]["residual_bytes"])
        results["attn"].append(lane)


def bench_moe(results, smoke=False):
    """MoE expert-einsum lane: full fwd+bwd step over the two routed
    expert contractions (``ecd,edf->ecf`` up, ``ecf,efd->ecd`` down) —
    the batched payload GEMM nodes of ISSUE 4 — payload vs Fig. 4, bank
    steady state, plus the modeled batched HBM bytes/elt."""
    key = jax.random.PRNGKey(7)
    e, c, d, f = (2, 64, 64, 128) if smoke else (8, 256, 512, 1024)
    iters = 2 if smoke else 5
    params = {"we": jax.random.normal(key, (e, d, f)) * 1e-3,
              "wd": jax.random.normal(jax.random.fold_in(key, 1),
                                      (e, f, d)) * 1e-3}
    xe = jax.random.normal(jax.random.fold_in(key, 2), (e, c, d)) * 1e-3

    def loss_fn(p, batch, pol_):
        h = pol_.einsum("ecd,edf->ecf", batch, p["we"])
        h = pol_.einsum("ecf,efd->ecd", h, p["wd"])
        return jnp.sum(h * h), {}

    lane = {"e": e, "c": c, "d": d, "f": f}
    lane.update(_banked_lane_times(loss_fn, params, xe, iters))
    lane["modeled_hbm_bytes_per_elt"] = {
        m_: modeled_hbm_bytes_batched(m_, e, e, c, d, f)["bytes_per_element"]
        for m_ in ("fig4", "payload")}
    emit(f"moe_train_fig4_bank_e{e}", lane["fig4_bank_us"],
         "bank steady state")
    emit(f"moe_train_payload_bank_e{e}", lane["payload_bank_us"],
         f"{lane['payload_vs_fig4_bank']:.2f}x vs fig4-bank "
         f"[{e}x{c}x{d}]x[{e}x{d}x{f}]")
    results["moe"].append(lane)


def bench_conv(results, smoke=False):
    """Conv lane: full fwd+bwd step over one ``Policy.conv`` — the im2col
    payload lowering (ISSUE 4, the paper's ResNet leg) vs the Fig. 4
    ``lax.conv_general_dilated`` chain, bank steady state, plus the
    modeled bytes/elt with honest im2col accounting."""
    key = jax.random.PRNGKey(8)
    b, hw, cin, cout = (2, 8, 16, 16) if smoke else (8, 32, 64, 64)
    iters = 2 if smoke else 5
    params = {"k": jax.random.normal(key, (3, 3, cin, cout)) * 1e-2}
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, hw, hw, cin)) * 1e-2

    def loss_fn(p, batch, pol_):
        y = pol_.conv(batch, p["k"])
        return jnp.sum(y * y), {}

    lane = {"b": b, "hw": hw, "cin": cin, "cout": cout}
    lane.update(_banked_lane_times(loss_fn, params, x, iters))
    lane["modeled_hbm_bytes_per_elt"] = {
        m_: modeled_hbm_bytes_conv(m_, b, hw, hw, 3, 3, cin,
                                   cout)["bytes_per_element"]
        for m_ in ("fig4", "payload")}
    emit(f"conv_train_fig4_bank_{hw}", lane["fig4_bank_us"],
         "bank steady state")
    emit(f"conv_train_payload_bank_{hw}", lane["payload_bank_us"],
         f"{lane['payload_vs_fig4_bank']:.2f}x vs fig4-bank "
         f"[{b}x{hw}x{hw}x{cin}]*[3x3x{cin}x{cout}]")
    results["conv"].append(lane)


def modeled_hbm_bytes_serving_decode(n_layers: int, kv_heads: int,
                                     head_dim: int, context: int,
                                     max_len: int, block: int) -> dict:
    """Modeled decode-attention HBM bytes **per generated token per slot**.

    A decode step reads the slot's whole KV history once.  The dense fp32
    engine streams the full ``[max_len]`` buffer (its validity mask is
    applied after the read, so padding is paid for); the paged payload
    engine reads only the slot's allocated blocks (ceil(context / block)
    blocks) at 1 byte/element plus the frozen per-layer (alpha, beta)
    scalars.  The >= 4x gap (4 B -> 1 B, minus block-rounding slack) is
    the serving-side version of the paper's activation-memory argument.
    """
    per_tok = 2 * n_layers * kv_heads * head_dim          # K+V elements
    dense = per_tok * max_len * 4
    nblk = -(-context // block)
    paged = per_tok * nblk * block * 1 + 2 * n_layers * 2 * 4  # + stats
    return {"f32_dense": dense, "payload_paged": paged,
            "ratio": dense / paged}


def modeled_serving_capacity(slots_list=(8, 64, 256), *, n_layers=32,
                             kv_heads=8, head_dim=128, max_len=2048,
                             hbm_gb=16.0) -> dict:
    """Modeled KV-cache residency for a 7B-class GQA config vs one
    accelerator's HBM: at which slot count does an fp32 dense cache stop
    fitting while the paged payload pool keeps admitting?"""
    out = {}
    per_slot = 2 * n_layers * kv_heads * head_dim * max_len
    for slots in slots_list:
        dense = slots * per_slot * 4
        paged = slots * per_slot * 1 + n_layers * 4 * 4 \
            + slots * (max_len // 16) * 4                  # stats + table
        out[str(slots)] = {
            "f32_dense_gb": dense / 1e9,
            "payload_paged_gb": paged / 1e9,
            "f32_fits": dense <= hbm_gb * 1e9,
            "payload_fits": paged <= hbm_gb * 1e9,
        }
    return out


def bench_serving(results, smoke=False):
    """Serving lane (ISSUE 10): measured tok/s of the dense fp32 engine vs
    the paged-payload engine on a tiny LM, plus the modeled decode HBM
    bytes/token and the modeled slots-vs-HBM capacity frontier."""
    import time as _time

    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core.policy import make_policy
    from repro.models import transformer as tlm
    from repro.serving import bank as sbank
    from repro.serving.engine import LMServer, PayloadLMServer, Request

    cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False)
    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
    n_req, new_tok = (3, 3) if smoke else (12, 16)
    slots, max_len = (2, 32) if smoke else (4, 128)
    pol = make_policy("s2fp8", backend="ref", gemm_mode="payload")
    bank = sbank.export_serving_bank(params, cfg, pol, prompt_len=8,
                                     batch=2, passes=1)

    def run_engine(server):
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 5 + 4 * (i % 2),
                                            dtype=np.int32),
                        max_new_tokens=new_tok) for i in range(n_req)]
        for r in reqs:
            server.submit(r)
        server.run_to_completion(max_ticks=50)     # warm compiles
        rng = np.random.default_rng(1)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, 5 + 4 * (i % 2),
                                            dtype=np.int32),
                        max_new_tokens=new_tok) for i in range(n_req)]
        for r in reqs:
            server.submit(r)
        t0 = _time.perf_counter()
        server.run_to_completion(max_ticks=200)
        dt = _time.perf_counter() - t0
        toks = sum(len(r.out) for r in reqs)
        assert toks == n_req * new_tok
        return toks / dt, len(server.prefill_shapes)

    dense_tok_s, dense_shapes = run_engine(
        LMServer(cfg, params, make_policy("fp32"), slots=slots,
                 max_len=max_len))
    payload_tok_s, payload_shapes = run_engine(
        PayloadLMServer(cfg, params, pol, bank=bank, slots=slots,
                        max_len=max_len, block=8, cache_fmt="e5m2"))

    lane = {
        "slots": slots, "max_len": max_len, "requests": n_req,
        "new_tokens": new_tok,
        "dense_f32_tok_s": dense_tok_s,
        "payload_paged_tok_s": payload_tok_s,
        "dense_prefill_shapes": dense_shapes,
        "payload_prefill_shapes": payload_shapes,
        "modeled_decode_bytes_per_token": modeled_hbm_bytes_serving_decode(
            32, 8, 128, context=2048, max_len=2048, block=16),
        "modeled_capacity_16gb": modeled_serving_capacity(),
    }
    emit("serving_dense_f32_tok_s", 1e6 / max(dense_tok_s, 1e-9),
         f"{dense_tok_s:.1f} tok/s dense fp32 engine")
    emit("serving_payload_paged_tok_s", 1e6 / max(payload_tok_s, 1e-9),
         f"{payload_tok_s:.1f} tok/s paged payload engine "
         f"(modeled {lane['modeled_decode_bytes_per_token']['ratio']:.2f}x "
         f"fewer decode HBM bytes/token)")
    results["serving"].append(lane)


def provenance() -> dict:
    """Run provenance, recorded once at the top level and stamped on every
    lane row: a BENCH_kernels.json number is only comparable to another
    run's if these match (interpret-mode Pallas timings in particular are
    debug-grade and must never be read against compiled-TPU ones)."""
    from repro import kernels as rkernels
    return {"backend": nbackend.get_backend().name,
            "platform": jax.default_backend(),
            "interpret": rkernels.auto_interpret(),
            "jax_version": jax.__version__,
            "n_devices": len(jax.devices())}


def _stamp_provenance(results: dict, prov: dict):
    """Attach the run provenance to every recorded lane row."""
    for v in results.values():
        if isinstance(v, list):
            for row in v:
                row["provenance"] = prov


def main(smoke: bool = False):
    prov = provenance()
    results = {"backend": prov["backend"],
               "platform": prov["platform"],
               "n_devices": prov["n_devices"],
               "provenance": prov,
               "truncate": [], "quantize": [], "matmul": [], "stats": [],
               "gemm": [], "moe": [], "conv": [], "dp": [], "fsdp": [],
               "attn": [], "serving": []}
    key = jax.random.PRNGKey(0)

    if smoke:
        # CI regression gate: the train-step lanes (gemm + moe + conv +
        # stats) on tiny shapes — seconds, not minutes; numbers are not
        # recorded.  (The truncate/quantize/matmul microlanes are covered
        # by the unit tests that run earlier in the same CI job.)
        bench_gemm(results, sizes=(256,), smoke=True)
        bench_moe(results, smoke=True)
        bench_conv(results, smoke=True)
        bench_statsbank(results, smoke=True)
        bench_dp(results, smoke=True)
        bench_fsdp(results, smoke=True)
        bench_attn(results, sizes=(256,), smoke=True)
        bench_serving(results, smoke=True)
        _stamp_provenance(results, prov)
        # falsifiable structure checks: every expected lane must have been
        # emitted with finite timings (a lane that silently skipped its
        # work, or a refactor that dropped one, fails the build here)
        assert all(len(results[k]) == 1
                   for k in ("gemm", "moe", "conv", "stats", "dp", "fsdp",
                             "attn", "serving")), \
            {k: len(v) for k, v in results.items() if isinstance(v, list)}
        assert all("provenance" in row for k, v in results.items()
                   if isinstance(v, list) for row in v), "unstamped lane row"
        import math as _math
        for want in ("fig4_exact_us", "fig4_bank_us", "payload_bank_us"):
            v = results["gemm"][0][want]
            assert _math.isfinite(v), (want, v)
        for lane in ("moe", "conv"):
            for want in ("fig4_bank_us", "payload_bank_us"):
                v = results[lane][0][want]
                assert _math.isfinite(v), (lane, want, v)
        assert _math.isfinite(results["stats"][0]["bank_step_us"])
        dp = results["dp"][0]
        for want in ("f32_step_us", "s2fp8_step_us"):
            assert _math.isfinite(dp[want]), (want, dp[want])
        # the modeled interconnect win must survive refactors: compressed
        # sync moves strictly fewer bytes than f32 at any n > 1
        m = dp["modeled_ici_bytes_per_elt_n8"]
        assert m["s2fp8"] < m["f32"], m
        # fsdp lane (ISSUE 9): all three modes timed; the modeled payload
        # gather leg is ~4x below the f32 gather, and the modeled
        # resident param+opt store drops ~n_shards x vs replicated
        fl = results["fsdp"][0]
        for want in ("replicated_step_us", "fsdp_step_us",
                     "fsdp_q_step_us"):
            assert _math.isfinite(fl[want]), (want, fl[want])
        gb = fl["modeled_gather_bytes_per_elt_n8"]
        assert gb["fsdp"] / gb["fsdp_q"] >= 3.5, gb
        assert gb["replicated"] == 0.0, gb
        rb = fl["modeled_hbm_resident_bytes_n8"]
        assert rb["replicated"] / rb["fsdp_q"] >= 0.9 * 8, rb
        assert rb["fsdp"] == rb["fsdp_q"], rb   # same sharded store
        ib = fl["modeled_ici_bytes_per_elt_n8"]
        assert ib["fsdp_q"] < ib["fsdp"] <= ib["replicated"], ib
        # attention lane structure: all three routings timed at smoke S,
        # the payload flash model has NO s^2 term (doubling S doubles its
        # bytes instead of quadrupling), and its saved residuals are the
        # promised ~4x smaller than the f32 fig4-flash residuals
        at = results["attn"][0]
        for want in ("flash_payload_us", "einsum_payload_us", "fig4_us"):
            assert _math.isfinite(at[want]), (want, at[want])
        f1 = modeled_hbm_bytes_attn("flash_payload", 4096, 64)
        f2 = modeled_hbm_bytes_attn("flash_payload", 8192, 64)
        assert f2["total_bytes"] / f1["total_bytes"] < 2.5, (f1, f2)
        e1 = modeled_hbm_bytes_attn("einsum_payload", 4096, 64)
        e2 = modeled_hbm_bytes_attn("einsum_payload", 8192, 64)
        assert e2["total_bytes"] / e1["total_bytes"] > 3.0, (e1, e2)
        assert at["residual_cut_vs_fig4_flash"] >= 3.5, at
        # serving lane (ISSUE 10): both engines produced tokens; the paged
        # payload cache moves >= 3.5x fewer modeled decode HBM bytes/token
        # than the dense fp32 cache, and on the modeled 16 GB capacity
        # frontier there is a slot count where fp32 has stopped fitting
        # while the payload pool still admits
        sv = results["serving"][0]
        for want in ("dense_f32_tok_s", "payload_paged_tok_s"):
            assert _math.isfinite(sv[want]) and sv[want] > 0, (want, sv)
        assert sv["modeled_decode_bytes_per_token"]["ratio"] >= 3.5, sv
        cap = sv["modeled_capacity_16gb"]
        assert any(not c["f32_fits"] and c["payload_fits"]
                   for c in cap.values()), cap
        assert sv["payload_prefill_shapes"] <= 8, sv
        print("# smoke ok (no JSON written)")
        return

    bench_truncate(results)
    bench_statsbank(results)
    bench_gemm(results)
    bench_moe(results)
    bench_conv(results)
    bench_dp(results)
    bench_fsdp(results)
    bench_attn(results)
    bench_serving(results)

    for n in [1 << 16, 1 << 20, 1 << 22]:
        x = jax.random.normal(key, (n,)) * 1e-5
        fq = jax.jit(lambda v: s2fp8.quantize(v).payload)
        us = time_jitted(fq, x)
        emit(f"s2fp8_quantize_n{n}", us, f"{n*4/(us*1e-6)/1e9:.2f}GB/s")
        results["quantize"].append({"n": n, "us": us})

    for m, k, n2 in [(512, 512, 512), (1024, 1024, 1024)]:
        a = jax.random.normal(key, (m, k)) * 1e-3
        b = jax.random.normal(key, (k, n2)) * 1e-3
        pa, aa, ab = ref.s2fp8_quant_ref(a)
        pb, ba, bb = ref.s2fp8_quant_ref(b)
        f = jax.jit(ref.s2fp8_matmul_ref)
        us = time_jitted(f, pa, aa, ab, pb, ba, bb)
        gflops = 2 * m * k * n2 / (us * 1e-6) / 1e9
        emit(f"s2fp8_matmul_{m}x{k}x{n2}", us, f"{gflops:.1f}GFLOP/s")
        results["matmul"].append({"mkn": [m, k, n2], "us": us,
                                  "gflops": gflops})

    q = jax.random.normal(key, (1, 4, 1024, 64))
    kv = jax.random.normal(key, (1, 4, 1024, 64))
    f = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=True))
    us = time_jitted(f, q, kv, kv)
    emit("attention_ref_1k", us, "oracle")

    _stamp_provenance(results, prov)
    with open(BENCH_JSON, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape lane sweep for CI (no JSON output)")
    main(smoke=ap.parse_args().smoke)
