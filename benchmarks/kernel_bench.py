"""Microbenchmarks for the S2FP8 numeric layer (paper §5 cost discussion).

Times the jnp reference path (the CPU-executable implementation; the Pallas
kernels are the TPU target and validate in interpret mode in tests/).
Derived column reports achieved GB/s — the quantity §5 claims is preserved.
"""
import jax
import jax.numpy as jnp

from benchmarks.bench_util import emit, time_jitted
from repro.core import s2fp8
from repro.kernels import ref


def main():
    key = jax.random.PRNGKey(0)
    for n in [1 << 16, 1 << 20, 1 << 22]:
        x = jax.random.normal(key, (n,)) * 1e-5
        f = jax.jit(s2fp8.truncate_value)
        us = time_jitted(f, x)
        gbs = n * 4 / (us * 1e-6) / 1e9
        emit(f"s2fp8_truncate_n{n}", us, f"{gbs:.2f}GB/s")

        fq = jax.jit(lambda v: s2fp8.quantize(v).payload)
        us = time_jitted(fq, x)
        emit(f"s2fp8_quantize_n{n}", us, f"{n*4/(us*1e-6)/1e9:.2f}GB/s")

    for m, k, n2 in [(512, 512, 512), (1024, 1024, 1024)]:
        a = jax.random.normal(key, (m, k)) * 1e-3
        b = jax.random.normal(key, (k, n2)) * 1e-3
        pa, aa, ab = ref.s2fp8_quant_ref(a)
        pb, ba, bb = ref.s2fp8_quant_ref(b)
        f = jax.jit(ref.s2fp8_matmul_ref)
        us = time_jitted(f, pa, aa, ab, pb, ba, bb)
        gflops = 2 * m * k * n2 / (us * 1e-6) / 1e9
        emit(f"s2fp8_matmul_{m}x{k}x{n2}", us, f"{gflops:.1f}GFLOP/s")

    q = jax.random.normal(key, (1, 4, 1024, 64))
    kv = jax.random.normal(key, (1, 4, 1024, 64))
    f = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=True))
    us = time_jitted(f, q, kv, kv)
    emit("attention_ref_1k", us, "oracle")


if __name__ == "__main__":
    main()
