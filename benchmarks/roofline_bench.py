"""Roofline summary from the dry-run sweep (deliverable g).

Reads benchmarks/results/dryrun.json (written by launch/dryrun.py) and
emits one CSV row per (arch x shape x mesh) cell with the three roofline
terms, the dominant bottleneck and MFU at roofline.
"""
import json
import os

from benchmarks.bench_util import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def main():
    if not os.path.exists(RESULTS):
        emit("roofline_missing", 0.0, "run launch/dryrun.py first")
        return
    with open(RESULTS) as f:
        res = json.load(f)
    for key in sorted(res):
        rec = res[key]
        name = "roofline_" + key.replace("|", "_")
        if rec["status"] == "skipped":
            emit(name, 0.0, "skipped:" + rec["reason"][:40].replace(",", ";"))
            continue
        if rec["status"] != "ok":
            emit(name, 0.0, "FAIL")
            continue
        r = rec["roofline"]
        emit(name, r["step_s"] * 1e6,
             f"dom={r['dominant']};mfu={r['mfu']:.4f};"
             f"c={r['compute_s']:.3f}s;m={r['memory_s']:.3f}s;"
             f"n={r['collective_s']:.3f}s")


if __name__ == "__main__":
    main()
