"""Timing helpers for the benchmark harness."""
import time

import jax


def time_jitted(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted callable with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
