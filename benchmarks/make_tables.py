"""Render EXPERIMENTS.md tables from benchmarks/results/dryrun.json.

    PYTHONPATH=src python -m benchmarks.make_tables [--mesh 16x16] [--tag '']
"""
import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["minicpm_2b", "stablelm_12b", "gemma3_1b", "nemotron_4_340b",
              "zamba2_1p2b", "deepseek_moe_16b", "kimi_k2_1t_a32b",
              "chameleon_34b", "falcon_mamba_7b", "whisper_medium"]


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load():
    with open(RESULTS) as f:
        return json.load(f)


def dryrun_table(res, tag=""):
    print("| arch | shape | 16x16 | 2x16x16 | bytes/dev (1-pod) | compile s |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            cells = {}
            for mesh in ["16x16", "2x16x16"]:
                key = f"{arch}|{shape}|{mesh}|s2fp8" + (f"|{tag}" if tag else "")
                cells[mesh] = res.get(key)
            c1, c2 = cells["16x16"], cells["2x16x16"]
            if c1 is None:
                continue
            if c1["status"] == "skipped":
                print(f"| {arch} | {shape} | skip | skip | — | — |")
                continue
            stat = lambda c: "✓" if (c and c["status"] == "ok") else "FAIL"
            mem = c1.get("memory_analysis", {})
            bpd = (mem.get("argument_bytes", 0) or 0) + (mem.get("temp_bytes", 0) or 0)
            print(f"| {arch} | {shape} | {stat(c1)} | {stat(c2)} "
                  f"| {bpd/2**30:.2f}GiB | {c1.get('compile_s', 0):.0f} |")


def roofline_table(res, mesh="16x16", tag=""):
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful/HLO | MFU@roofline |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            key = f"{arch}|{shape}|{mesh}|s2fp8" + (f"|{tag}" if tag else "")
            rec = res.get(key)
            if rec is None:
                continue
            if rec["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | skip(full-attn) | — | — |")
                continue
            if rec["status"] != "ok":
                print(f"| {arch} | {shape} | FAIL | | | | | |")
                continue
            r = rec["roofline"]
            print(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} "
                  f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
                  f"| **{r['dominant']}** | {r['useful_flops_frac']:.2f} "
                  f"| {r['mfu']*100:.2f}% |")


def compare(res, arch, shape, mesh, tags):
    print(f"### {arch} / {shape} / {mesh}")
    print("| variant | compute | memory | collective | step@roofline | MFU |")
    print("|---|---|---|---|---|---|")
    for tag in tags:
        key = f"{arch}|{shape}|{mesh}|s2fp8" + (f"|{tag}" if tag else "")
        rec = res.get(key)
        if not rec or rec["status"] != "ok":
            print(f"| {tag or 'baseline'} | missing | | | | |")
            continue
        r = rec["roofline"]
        print(f"| {tag or 'baseline'} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| {fmt_s(r['step_s'])} | {r['mfu']*100:.2f}% |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="both",
                    choices=["both", "dryrun", "roofline"])
    args = ap.parse_args()
    res = load()
    if args.section in ("both", "dryrun"):
        print("\n## Dry-run matrix\n")
        dryrun_table(res, args.tag)
    if args.section in ("both", "roofline"):
        print(f"\n## Roofline ({args.mesh})\n")
        roofline_table(res, args.mesh, args.tag)
