"""Paper-table convergence benches (mechanism reproduction, synthetic data).

One function per paper table:
  table1_resnet      — ResNet-20/CIFAR-shape, SGD-m + step decay (§4.2)
  table3_transformer — Transformer-tiny enc-dec, Adam (§4.3)
  table4_ncf         — NeuMF, Adam (§4.4)
  fig5_stats         — alpha/beta/mu/m evolution during training (Fig. 5)
  statsbank_delayed  — beyond-paper: jit-carried delayed stats (StatsBank)
                       vs exact per-truncation stats, same run

Derived column = the table's headline metric per numeric format.
"""
import sys

import jax
import jax.numpy as jnp

from benchmarks.bench_util import emit, time_jitted


def table1_resnet(steps=60):
    sys.path.insert(0, "examples")
    from train_resnet_cifar import run
    for mode in ["fp32", "s2fp8", "fp8", "fp8_ls"]:
        acc, loss = run(mode, steps)
        emit(f"table1_resnet20_{mode}", 0.0, f"acc={acc:.3f};loss={loss:.3f}")


def table3_transformer(steps=400):
    sys.path.insert(0, "examples")
    from train_transformer_tiny import run
    for mode in ["fp32", "s2fp8", "fp8", "fp8_ls"]:
        nll, acc = run(mode, steps)
        emit(f"table3_ttiny_{mode}", 0.0, f"nll={nll:.3f};tok_acc={acc:.3f}")


def table4_ncf(steps=300):
    sys.path.insert(0, "examples")
    from train_ncf import run
    for mode in ["fp32", "s2fp8", "fp8"]:
        hr, loss = run(mode, steps)
        emit(f"table4_ncf_{mode}", 0.0, f"HR10={hr:.3f};loss={loss:.3f}")


def fig5_stats(steps=40):
    """Track the S2FP8 statistics of a probe gradient during training."""
    from repro.configs import get_reduced_config
    from repro.core.policy import make_policy
    from repro.data import synthetic
    from repro.models import transformer as tlm
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False,
                                                   vocab=64)
    pol = make_policy("s2fp8")
    table = synthetic.make_markov_table(0, cfg.vocab)

    def loss_fn(p, b, pol_):
        return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

    step = jax.jit(make_train_step(loss_fn, optimizers.adamw(),
                                   schedules.constant(3e-3), pol,
                                   track_stats=True))
    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
    st = optimizers.adamw().init(params)
    rows = []
    for s in range(steps):
        b = synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)
        params, st, m = step(params, st, b, jnp.int32(s))
        ps = m["probe_stats"]
        rows.append((s, float(ps["mu"]), float(ps["m"]),
                     float(ps["alpha"]), float(ps["beta"])))
    for s, mu, mx, al, be in rows[:: max(steps // 8, 1)]:
        emit(f"fig5_stats_step{s}", 0.0,
             f"mu={mu:.2f};m={mx:.2f};alpha={al:.2f};beta={be:.2f}")


def statsbank_delayed(steps=40, refresh_every=8):
    """Delayed-stats convergence: the jit-carried StatsBank (refresh every
    k steps inside jit) vs exact per-truncation stats on the tiny LM.
    The derived column is the final-loss gap — the accuracy cost of
    amortizing the stats reduction k-fold."""
    from repro.configs import get_reduced_config
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.data import synthetic
    from repro.models import transformer as tlm
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False,
                                                   vocab=64)
    pol = make_policy("s2fp8")
    table = synthetic.make_markov_table(0, cfg.vocab)

    def loss_fn(p, b, pol_):
        return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

    def data_fn(s):
        return synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)

    opt = optimizers.adamw()
    sched = schedules.constant(3e-3)
    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))

    exact_step = jax.jit(make_train_step(loss_fn, opt, sched, pol))
    p, st = params, opt.init(params)
    for s in range(steps):
        p, st, m = exact_step(p, st, data_fn(s), jnp.int32(s))
    exact_loss = float(m["loss"])

    scfg = statsbank.StatsConfig(refresh_every=refresh_every)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, scfg)
    bank_step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg))
    p, st = params, opt.init(params)
    for s in range(steps):
        p, st, bank, m = bank_step(p, st, bank, data_fn(s), jnp.int32(s))
    bank_loss = float(m["loss"])

    emit(f"statsbank_exact_{steps}steps", 0.0, f"loss={exact_loss:.4f}")
    emit(f"statsbank_delayed_k{refresh_every}_{steps}steps", 0.0,
         f"loss={bank_loss:.4f};gap={bank_loss - exact_loss:+.4f}")


def fig1_grad_range(steps=10):
    """Paper Fig. 1 analog: what fraction of gradient elements lies OUTSIDE
    raw FP8's representable range [2^-16, 2^16] — the mechanism behind
    FP8's divergence and S2FP8's immunity."""
    import numpy as np
    from repro.configs import get_reduced_config
    from repro.core.policy import make_policy
    from repro.data import synthetic
    from repro.models import transformer as tlm
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    cfg = get_reduced_config("minicpm_2b").replace(n_layers=4, remat=False,
                                                   vocab=512)
    pol = make_policy("fp32")
    table = synthetic.make_markov_table(0, cfg.vocab)

    def loss_fn(p, b, pol_):
        return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))

    def grads_at(step):
        b = synthetic.lm_batch(0, step, 8, 64, cfg.vocab, table)
        g = jax.grad(lambda p: loss_fn(p, b, pol)[0])(params)
        return jax.tree_util.tree_leaves(g)

    leaves = grads_at(0)
    below = tot = 0
    for leaf in leaves:
        a = np.abs(np.asarray(leaf, np.float32)).ravel()
        a = a[a > 0]
        below += (a < 2.0 ** -16).sum()
        tot += a.size
    emit("fig1_grad_below_fp8min", 0.0,
         f"frac={below/max(tot,1):.3f};n={tot}")


def main():
    table1_resnet()
    table3_transformer()
    table4_ncf()
    fig5_stats()
    fig1_grad_range()
    statsbank_delayed()


if __name__ == "__main__":
    main()
