"""Training launcher.

Single host (this container):
    PYTHONPATH=src python -m repro.launch.train --arch minicpm_2b \
        --reduced --steps 50 --policy s2fp8 --ckpt-dir /tmp/ckpt --resume auto

Production pod: the same entry point under `jax.distributed.initialize()`
(one process per host); the mesh flag switches to the 16x16 / 2x16x16
production meshes and params/opt-state are sharded by the same rule tables
the dry-run proves out (launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, get_reduced_config
from repro.core import backend as nbackend
from repro.core import policy as policy_mod
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.checkpoint.manager import CheckpointManager
from repro.data import synthetic
from repro.launch import api
from repro.launch.mesh import (axis_sizes, make_host_mesh,
                               make_mesh_from_spec, make_production_mesh)
from repro import obs
from repro.optim import optimizers, schedules
from repro.parallel import sharding as shd
from repro.training import chaos as chaos_mod
from repro.training import guard as guard_mod
from repro.training import trainer as trainer_mod
from repro.training.trainer import TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke/convergence runs)")
    ap.add_argument("--policy", default="s2fp8",
                    choices=["fp32", "bf16", "fp8", "fp8_ls", "s2fp8"])
    ap.add_argument("--backend", default=None,
                    choices=("auto",) + nbackend.available_backends(),
                    help="numerics backend for s2fp8 truncations "
                         "(default: the arch config's, usually 'auto')")
    ap.add_argument("--gemm-mode", default="auto",
                    choices=policy_mod.GEMM_MODES,
                    help="s2fp8 GEMM execution: 'payload' = qdot_train "
                         "(FP8 operand streaming, fused epilogue, NT/TN "
                         "payload backward), 'fig4' = the composed "
                         "truncation chain; 'auto' = payload on the "
                         "pallas engines")
    ap.add_argument("--loss-scale", type=float, default=100.0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--mesh", default="host",
                    help="'host' (all local devices on the data axis), "
                         "'single'/'multi' (production 16x16 / 2x16x16), "
                         "a 'DxT' / 'PxDxT' spec (e.g. '8x1'), or 'none' "
                         "for the meshless single-device step")
    ap.add_argument("--grad-sync", default="f32", choices=["f32", "s2fp8"],
                    help="cross-shard gradient sync under the mesh: plain "
                         "f32 psum, or the S2FP8-compressed reduce-scatter"
                         "/all-gather schedule (core/collectives.py) for "
                         "every compressible leaf")
    ap.add_argument("--grad-sync-min-size", type=int, default=1 << 16,
                    help="element-count floor below which a gradient leaf "
                         "takes the exact f32 path even under s2fp8 sync "
                         "(stats overhead dominates small leaves; also the "
                         "floor for the FSDP compressed scatter leg)")
    ap.add_argument("--shard-params", default="replicated",
                    choices=trainer_mod.PARAM_SHARDING_MODES,
                    help="param/optimizer placement under the mesh: "
                         "'replicated' (every device holds full copies), "
                         "'fsdp' (ZeRO-3: leaves shard dim 0 over the "
                         "fsdp axis, f32 all-gather just-in-time, grads "
                         "reduce-scatter back), or 'fsdp_q' (gather "
                         "S2FP8 *payloads* — 1 byte/elt on the wire — "
                         "straight into the banked GEMMs; requires "
                         "--stats-refresh-every and a payload-GEMM "
                         "policy)")
    ap.add_argument("--track-stats", action="store_true")
    ap.add_argument("--stats-refresh-every", type=int, default=0,
                    help="enable the jit-carried StatsBank: refresh the "
                         "per-site (alpha, beta) reduction every K steps "
                         "(0 = off, exact stats every truncation)")
    ap.add_argument("--stats-ema", type=float, default=0.0,
                    help="EMA decay on the raw (mu, m) moments at each "
                         "StatsBank refresh (0 = replace)")
    ap.add_argument("--metrics-sink", default=None,
                    help="route loop records (step spans, watchdog / "
                         "checkpoint events, per-site FP8 health) to a "
                         "sink: jsonl:<path>, csv:<path>, console")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry per-site FP8 health metrics in the "
                         "StatsBank (requires --stats-refresh-every) and "
                         "drain them to --metrics-sink each refresh")
    ap.add_argument("--guard", action="store_true",
                    help="arm the in-step StepGuard (training/guard.py): "
                         "non-finite loss/grad + grad-norm-spike "
                         "sentinels reject bad updates in-trace and the "
                         "loop escalates skip -> forced refresh -> "
                         "snapshot rollback -> checkpoint restore")
    ap.add_argument("--guard-spike-factor", type=float, default=10.0,
                    help="trip when grad_norm exceeds this multiple of "
                         "its accepted-step EMA")
    ap.add_argument("--guard-warmup", type=int, default=8,
                    help="accepted steps before the spike sentinel arms")
    ap.add_argument("--guard-sat-threshold", type=float, default=0.0,
                    help="trip when any StatsBank site's sat_frac "
                         "telemetry exceeds this fraction (0 = off; "
                         "needs --telemetry)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="push (params, opt, bank, guard) onto an "
                         "in-memory snapshot ring every K clean steps — "
                         "the escalation ladder's rollback target "
                         "(0 = ring off)")
    ap.add_argument("--snapshot-ring", type=int, default=4,
                    help="snapshot ring depth")
    ap.add_argument("--snapshot-compress", action="store_true",
                    help="S2FP8-compress big snapshot leaves (~4x less "
                         "host memory; rollback no longer bitwise)")
    ap.add_argument("--watchdog-escalate-after", type=int, default=0,
                    help="N consecutive watchdog trips trigger a "
                         "proactive snapshot + watchdog_escalated event "
                         "(0 = trips stay log-only)")
    ap.add_argument("--chaos", default=None,
                    help="deterministic fault injection spec "
                         "(training/chaos.py), e.g. 'nan_grad@5x3,"
                         "slow_step@12:0.5'; injectors: nan_grad, "
                         "inf_loss, reject, saturating_bank, "
                         "corrupt_ckpt, slow_step, corrupt_batch. "
                         "Implies --guard.")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    backend_name = args.backend or getattr(cfg, "numerics_backend", "auto")
    pol = make_policy(args.policy, loss_scale=args.loss_scale,
                      backend=backend_name, gemm_mode=args.gemm_mode)
    print(f"[train] numerics backend: {backend_name} "
          f"-> {pol.backend_obj.name} ({jax.default_backend()}), "
          f"gemm: {'payload' if pol.uses_payload_gemm else 'fig4'}")
    key = jax.random.PRNGKey(args.seed)

    if args.mesh == "none":
        mesh = None
    elif args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    else:
        mesh = make_mesh_from_spec(args.mesh)
    sizes = axis_sizes(mesh) if mesh is not None else {}

    loss_fn = api.make_loss_fn(cfg)
    opt = optimizers.adamw(weight_decay=0.01)
    sched = schedules.make_schedule(
        cfg.schedule if cfg.schedule == "wsd" else "cosine",
        args.lr, total_steps=args.steps, warmup=max(args.steps // 20, 1))
    stats_cfg = None
    if args.stats_refresh_every > 0:
        stats_cfg = statsbank.StatsConfig(
            refresh_every=args.stats_refresh_every,
            ema_decay=args.stats_ema,
            telemetry=args.telemetry)
    if args.telemetry and stats_cfg is None:
        raise SystemExit("--telemetry requires --stats-refresh-every > 0 "
                         "(health metrics ride the StatsBank refresh)")
    # no sink spec: loop records fall back to the console (TrainLoop's
    # default), telemetry (if on) prints through an explicit ConsoleSink
    sink = obs.make_sink(args.metrics_sink) if args.metrics_sink else \
        (obs.ConsoleSink() if args.telemetry else None)
    telemetry = (obs.Telemetry(sink, every=args.stats_refresh_every)
                 if args.telemetry else None)
    chaos_plan = chaos_mod.ChaosPlan.parse(args.chaos) if args.chaos else None
    use_guard = args.guard or chaos_plan is not None
    if args.guard_sat_threshold > 0 and not args.telemetry:
        raise SystemExit("--guard-sat-threshold reads the StatsBank's "
                         "sat_frac telemetry leaves: add --telemetry "
                         "(and --stats-refresh-every)")
    guard_cfg = None
    if use_guard:
        guard_cfg = guard_mod.GuardConfig(
            spike_factor=args.guard_spike_factor,
            warmup=args.guard_warmup,
            sat_threshold=args.guard_sat_threshold)
        print(f"[train] step guard armed: spike x{guard_cfg.spike_factor} "
              f"(warmup {guard_cfg.warmup}), sat_threshold "
              f"{guard_cfg.sat_threshold}"
              + (f", chaos: {args.chaos}" if chaos_plan else ""))
    if args.shard_params != "replicated":
        if mesh is None:
            raise SystemExit("--shard-params needs a mesh (--mesh != none)")
        if args.shard_params == "fsdp_q" and stats_cfg is None:
            raise SystemExit("--shard-params fsdp_q streams payloads into "
                             "the banked GEMMs: add --stats-refresh-every "
                             "(and an s2fp8 payload-GEMM policy)")
    step_fn = make_train_step(loss_fn, opt, sched, pol,
                              track_stats=args.track_stats,
                              stats=stats_cfg, mesh=mesh,
                              grad_sync_mode=args.grad_sync,
                              grad_sync_min_size=args.grad_sync_min_size,
                              telemetry=telemetry, guard=guard_cfg,
                              param_sharding=args.shard_params)
    if mesh is not None:
        n_shards = 1
        for a in ("pod", "data"):
            n_shards *= sizes.get(a, 1)
        print(f"[train] mesh {dict(sizes)}: {n_shards}-way data-parallel "
              f"step, grad sync {args.grad_sync}, params "
              f"{args.shard_params}"
              + (f" ({shd.fsdp_axis_size(mesh)}-way over "
                 f"'{shd.fsdp_axis_entry(mesh)}')"
                 if args.shard_params != "replicated" else ""))
        if args.batch % n_shards != 0:
            print(f"[train] WARNING: --batch {args.batch} does not divide "
                  f"the {n_shards}-way data axis — the divisibility guard "
                  f"will REPLICATE the batch (every device computes the "
                  f"full batch; no data-parallel speedup)")
        if sizes.get("model", 1) > 1:
            print(f"[train] WARNING: the shard_map train step parallelizes "
                  f"the batch and (with --shard-params) the param store "
                  f"over the data axis only — the {sizes['model']}-way "
                  f"model axis runs duplicate compute (TP inside the step "
                  f"is a ROADMAP item); size the mesh as Nx1 to use every "
                  f"device for data")

    table = synthetic.make_markov_table(args.seed, cfg.vocab) \
        if not cfg.enc_dec else None

    def data_fn(step):
        if cfg.enc_dec:
            b = synthetic.seq2seq_batch(args.seed, step, args.batch,
                                        args.seq, args.seq, cfg.vocab)
            return {"enc_inputs": b["enc_tokens"], "dec_tokens": b["dec_tokens"],
                    "dec_labels": b["dec_labels"]}
        return synthetic.lm_batch(args.seed, step, args.batch, args.seq,
                                  cfg.vocab, table)

    import contextlib
    mesh_ctx = mesh if mesh is not None else contextlib.nullcontext()
    rules_ctx = (shd.use_rules(shd.TRAIN_RULES, sizes) if mesh is not None
                 else contextlib.nullcontext())
    with mesh_ctx, rules_ctx:
        params = api.init_params(cfg, key)
        opt_state = opt.init(params)
        bank = None
        if stats_cfg is not None:
            bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol,
                                       stats_cfg)
            print(f"[train] statsbank: {len(bank)} sites, refresh every "
                  f"{stats_cfg.refresh_every} steps, ema {stats_cfg.ema_decay}")
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(
                args.ckpt_dir,
                event_fn=(sink.emit if sink is not None else None))
        loop = TrainLoop(step_fn, params, opt_state,
                         chaos_mod.wrap_data_fn(data_fn, chaos_plan),
                         ckpt_manager=ckpt, ckpt_every=args.ckpt_every,
                         stats_bank=bank, sink=sink,
                         guard_state=(guard_mod.init_state() if use_guard
                                      else None),
                         chaos=chaos_plan,
                         snapshot_every=args.snapshot_every,
                         snapshot_ring=args.snapshot_ring,
                         snapshot_compress=args.snapshot_compress,
                         watchdog_escalate_after=args.watchdog_escalate_after)
        if args.resume == "auto" and ckpt is not None:
            loop.maybe_resume()
        history = loop.run(args.steps)
    if sink is not None:
        sink.close()
    final = history[-1] if history else {}
    print(f"[train] done: final loss {final.get('loss'):.4f}")


if __name__ == "__main__":
    main()
