import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the two lines above run before any other
import — jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm_2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results (memory analysis, cost analysis, collective bytes, roofline terms)
are cached incrementally into benchmarks/results/dryrun.json so the 80-cell
sweep can run across multiple invocations.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, SHAPE_SPECS, get_config
from repro.core.policy import make_policy
from repro.launch import api, memplan
from repro.launch.mesh import make_production_mesh, axis_sizes
from repro.parallel import sharding as shd
from repro.roofline import analysis as roofline

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.json")

LM_ARCHS = [a for a in ARCH_IDS if a not in
            ("resnet20_cifar", "ncf_ml1m", "transformer_tiny")]


def _load_results():
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return json.load(f)
    return {}


def _save_results(res):
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    tmp = RESULTS + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS)


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", 0),
        }
    except Exception as e:                                   # backend-specific
        return {"error": str(e)}


def run_cell(arch: str, shape: str, multi_pod: bool, policy_mode: str = "s2fp8",
             save_hlo: bool = False, overrides: dict | None = None,
             truncate_output: bool | None = None, tag: str = "",
             moe_routing: str | None = None, output_dtype: str | None = None):
    import dataclasses as _dc
    overrides = dict(overrides) if overrides else {}
    shard_kv_seq = overrides.pop("_shard_kv_seq", True)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if moe_routing and cfg.moe is not None:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, routing=moe_routing))
    reason = cfg.skip_reason(shape)
    if reason:
        return {"status": "skipped", "reason": reason}
    seq, gbs, kind = SHAPE_SPECS[shape]

    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = axis_sizes(mesh)
    chips = mesh.devices.size
    pol = make_policy(policy_mode)
    if truncate_output is not None:
        pol = _dc.replace(pol, truncate_output=truncate_output)
    if output_dtype:
        pol = _dc.replace(pol, output_dtype=output_dtype)
    rules = shd.TRAIN_RULES if kind == "train" else shd.DECODE_RULES
    if not shard_kv_seq:
        rules = dict(rules)
        rules["kv_seq"] = None

    # Serving runs bf16 weights; training keeps FP32 masters (paper Fig. 4).
    pdtype = jnp.float32 if kind == "train" else jnp.bfloat16
    pstruct = api.param_struct(cfg, dtype=pdtype)
    pspecs = api.param_pspecs(cfg, pstruct, sizes)
    bstruct = api.batch_struct(cfg, shape)
    bspecs = api.batch_pspecs(bstruct, sizes)

    def shardings(tree_specs):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree_specs,
            is_leaf=lambda x: isinstance(x, P))

    t0 = time.time()
    with mesh, shd.use_rules(rules, sizes):
        if kind == "train":
            step_fn, opt = api.make_train_step(cfg, pol)
            ostruct = jax.eval_shape(opt.init, pstruct)
            # opt state mirrors params for m/v (ZeRO); step is replicated
            from repro.optim.optimizers import OptState
            ospecs = OptState(P(), api.param_pspecs(cfg, ostruct.m, sizes),
                              None if ostruct.v is None
                              else api.param_pspecs(cfg, ostruct.v, sizes))
            jitted = jax.jit(
                step_fn,
                in_shardings=(shardings(pspecs), shardings(ospecs),
                              shardings(bspecs), None),
            )
            lowered = jitted.lower(pstruct, ostruct, bstruct, jnp.int32(0))
        elif kind == "prefill":
            step_fn = api.make_prefill_step(cfg, pol)
            if cfg.enc_dec:
                jitted = jax.jit(step_fn, in_shardings=(shardings(pspecs),
                                                        shardings(bspecs)))
                lowered = jitted.lower(pstruct, bstruct)
            else:
                cstruct = api.cache_struct(cfg, shape)
                cspecs = api.cache_pspecs(cfg, cstruct, sizes)
                jitted = jax.jit(step_fn, in_shardings=(shardings(pspecs),
                                                        shardings(bspecs),
                                                        shardings(cspecs)))
                lowered = jitted.lower(pstruct, bstruct, cstruct)
        else:  # decode
            step_fn = api.make_decode_step(cfg, pol)
            cstruct = api.cache_struct(cfg, shape)
            cspecs = api.cache_pspecs(cfg, cstruct, sizes,
                                      shard_kv_seq=shard_kv_seq)
            jitted = jax.jit(step_fn, in_shardings=(shardings(pspecs),
                                                    shardings(bspecs),
                                                    shardings(cspecs), None))
            lowered = jitted.lower(pstruct, bstruct, cstruct, jnp.int32(0))

        compiled = lowered.compile()

    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    mem = _mem_analysis_dict(compiled)
    hlo = compiled.as_text()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rl = roofline.analyze(arch, shape, mesh_name, chips, cost, hlo,
                          mem_bytes=float(mem.get("argument_bytes", 0) or 0)
                          + float(mem.get("temp_bytes", 0) or 0),
                          model_gflops_total=roofline.model_flops(cfg, shape) / 1e9)
    rec = {"status": "ok", "compile_s": compile_s, "memory_analysis": mem,
           "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
           "xla_cost_bytes_raw": float(cost.get("bytes accessed", 0.0)),
           "roofline": rl.to_dict(), "policy": policy_mode}
    if save_hlo:
        import gzip
        hdir = os.path.join(os.path.dirname(RESULTS), "hlo")
        os.makedirs(hdir, exist_ok=True)
        suffix = f".{tag}" if tag else ""
        with gzip.open(os.path.join(
                hdir, f"{arch}.{shape}.{mesh_name}.{policy_mode}{suffix}.txt.gz"),
                "wt") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="s2fp8")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attn-impl", default=None, choices=[None, "naive", "flash"])
    ap.add_argument("--ssm-impl", default=None,
                    choices=[None, "step", "unroll8", "ssd"])
    ap.add_argument("--decode-kv-seq", default=None, choices=[None, "0", "1"],
                    help="0: replicate KV-cache seq axis (batch-only decode "
                         "sharding variant)")
    ap.add_argument("--moe-routing", default=None,
                    choices=[None, "global", "grouped"])
    ap.add_argument("--output-dtype", default=None,
                    choices=[None, "bfloat16"])
    ap.add_argument("--truncate-output", default=None, choices=[None, "0", "1"])
    ap.add_argument("--tag", default="", help="suffix for the results key "
                    "(perf-iteration label, e.g. 'flash')")
    ap.add_argument("--mem-report", action="store_true",
                    help="print the per-device param/optimizer residency "
                         "plan (launch/memplan.py) for the selected archs "
                         "under replicated/fsdp/fsdp_q and exit — no "
                         "compilation; the fits verdict uses the "
                         "trainer's own per-leaf eligibility rules")
    args = ap.parse_args()

    if args.mem_report:
        archs = LM_ARCHS if (args.all or args.arch is None) else [args.arch]
        sizes = ({"pod": 2, "data": 16, "model": 16}
                 if args.mesh == "multi" else {"data": 16, "model": 16})
        print(memplan.format_report(archs, sizes))
        return
    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.ssm_impl:
        overrides["ssm_impl"] = args.ssm_impl
    if args.decode_kv_seq is not None:
        overrides["_shard_kv_seq"] = args.decode_kv_seq == "1"

    archs = LM_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    trunc_out = None if args.truncate_output is None else args.truncate_output == "1"
    results = _load_results()
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                key = f"{arch}|{shape}|{mesh_name}|{args.policy}"
                if args.tag:
                    key += f"|{args.tag}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, args.policy, args.save_hlo,
                                   overrides=overrides or None,
                                   truncate_output=trunc_out, tag=args.tag,
                                   moe_routing=args.moe_routing,
                                   output_dtype=args.output_dtype)
                except Exception as e:
                    rec = {"status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                results[key] = rec
                _save_results(results)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']:.1f}s "
                          f"flops/dev={r['hlo_gflops_per_dev']:.1f}G "
                          f"coll/dev={r['coll_gbytes_per_dev']:.3f}GB "
                          f"dominant={r['dominant']} mfu={r['mfu']:.3f}")
                elif rec["status"] == "skipped":
                    print(f"  skipped: {rec['reason']}")
                else:
                    print(f"  FAIL: {rec['error']}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
