"""s2fp8-doctor: per-site FP8 health report for a checkpointed run.

Loads a checkpoint (params + optimizer state + StatsBank), replays ONE
synthetic batch per requested backend with every StatsBank refresh
forced, and prints a ranked per-site health report: saturation /
underflow fractions measured against the bank's carried stats,
quantization SNR, EMA-vs-live moment drift, staleness, and an e4m3/e5m2
format recommendation per site (range vs resolution — the manual half of
the ROADMAP's format-autotuning item).

    PYTHONPATH=src python -m repro.launch.doctor --arch minicpm_2b \
        --reduced --ckpt-dir /tmp/ckpt --backends ref,pallas

Checkpoints saved without a bank (or with a different site structure —
e.g. a fig4-mode checkpoint probed under the payload GEMM routing) fall
back to a cold bank for that backend: sites bootstrap with fresh stats
and report clean, which is exactly what a fresh run would do.

``--smoke`` is the CI self-test: initializes a tiny transformer, saves a
fresh checkpoint, verifies the healthy probe reports clean, then
verifies a deliberately saturating synthetic tensor is flagged
(sat_frac > 0, e4m3 -> e5m2 recommendation).
"""
from __future__ import annotations

import argparse
import sys
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, get_reduced_config
from repro.core import backend as nbackend
from repro.core import policy as policy_mod
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.launch import api
from repro.obs import doctor as obs_doctor
from repro.obs import metrics as obs_metrics
from repro.optim import optimizers


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="s2fp8-doctor", description=__doc__)
    ap.add_argument("--arch", default="transformer_tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="s2fp8",
                    choices=["s2fp8", "s2fp8_e4m3"])
    ap.add_argument("--backends", default="ref",
                    help="comma-separated numerics backends to probe "
                         f"(available: {', '.join(nbackend.available_backends())})")
    ap.add_argument("--gemm-mode", default="auto",
                    choices=policy_mod.GEMM_MODES)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step to load (default: newest)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--refresh-every", type=int, default=16,
                    help="refresh cadence for the staleness flag context")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="self-test: fresh tiny-transformer checkpoint "
                         "reports clean; a saturating tensor is flagged")
    return ap


def _data(cfg, args):
    if cfg.enc_dec:
        b = synthetic.seq2seq_batch(args.seed, 0, args.batch, args.seq,
                                    args.seq, cfg.vocab)
        return {"enc_inputs": b["enc_tokens"], "dec_tokens": b["dec_tokens"],
                "dec_labels": b["dec_labels"]}
    table = synthetic.make_markov_table(args.seed, cfg.vocab)
    return synthetic.lm_batch(args.seed, 0, args.batch, args.seq,
                              cfg.vocab, table)


def _restore(ckpt_dir, step, params, opt_state, bank):
    """(params, opt_state, bank_or_None, step): try (params, opt, bank)
    templates with and without telemetry leaves, then the bankless
    layout.  A leaf-count mismatch (different site structure / no bank in
    the checkpoint) falls through rather than failing the report."""
    ck = CheckpointManager(ckpt_dir)
    for tmpl_bank in (bank, obs_metrics.ensure_telemetry(bank)):
        try:
            (p, o, b), s = ck.restore((params, opt_state, tmpl_bank), step)
            return p, o, b, s
        except ValueError:
            continue
    (p, o), s = ck.restore((params, opt_state), step)
    return p, o, None, s


def run(args) -> int:
    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    loss_fn = api.make_loss_fn(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = optimizers.adamw(weight_decay=0.01)
    opt_state = opt.init(params)
    batch = _data(cfg, args)
    base_cfg = statsbank.StatsConfig(refresh_every=args.refresh_every)

    for backend_name in args.backends.split(","):
        pol = make_policy(args.policy, backend=backend_name,
                          gemm_mode=args.gemm_mode)
        # this backend's expected site structure (gemm routing differs
        # between payload and fig4 modes)
        expected = statsbank.init_bank(loss_fn, params, batch, pol, base_cfg)
        bank, probe_step, p, o = expected, 0, params, opt_state
        if args.ckpt_dir:
            p, o, restored, s = _restore(args.ckpt_dir, args.step,
                                         params, opt_state, expected)
            probe_step = s
            if restored is not None:
                bank = restored
            else:
                print(f"[s2fp8-doctor] checkpoint bank does not match "
                      f"backend {backend_name!r}'s site structure "
                      f"(or has no bank) — probing a cold bank")
        probed, loss = obs_doctor.probe_bank(loss_fn, p, batch, pol, bank,
                                             base_cfg, step=probe_step)
        rows = obs_doctor.site_report(probed, step=probe_step,
                                      refresh_every=args.refresh_every)
        print(obs_doctor.format_report(rows, backend=backend_name,
                                       loss=loss, top=args.top))
    return 0


def _smoke(args) -> int:
    # 1) freshly-initialized tiny transformer checkpoint -> clean report
    args.arch, args.reduced = "transformer_tiny", True
    args.batch, args.seq = 2, 16
    cfg = get_reduced_config(args.arch)
    loss_fn = api.make_loss_fn(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = optimizers.adamw(weight_decay=0.01)
    opt_state = opt.init(params)
    batch = _data(cfg, args)
    pol = make_policy(args.policy, backend="ref", gemm_mode=args.gemm_mode)
    base_cfg = statsbank.StatsConfig(refresh_every=args.refresh_every)
    bank = statsbank.init_bank(loss_fn, params, batch, pol, base_cfg)
    with tempfile.TemporaryDirectory() as td:
        CheckpointManager(td).save(0, (params, opt_state, bank))
        args.ckpt_dir = td
        p, o, restored, s = _restore(td, None, params, opt_state, bank)
        assert restored is not None, "smoke: bank failed to restore"
        probed, loss = obs_doctor.probe_bank(loss_fn, p, batch, pol,
                                             restored, base_cfg, step=s)
    rows = obs_doctor.site_report(probed, step=s,
                                  refresh_every=args.refresh_every)
    print(obs_doctor.format_report(rows, backend="ref", loss=loss,
                                   top=args.top))
    if not rows:
        print("[s2fp8-doctor] smoke FAILED: no sites probed")
        return 1
    unhealthy = [r for r in rows if not obs_doctor.is_clean(r)]
    if unhealthy:
        print(f"[s2fp8-doctor] smoke FAILED: fresh checkpoint reported "
              f"{len(unhealthy)} unhealthy sites")
        return 1

    # 2) saturating synthetic tensor -> SAT flag + e4m3 -> e5m2 rec
    def toy_loss(p_, b_, pol_):
        return jnp.sum(pol_.dot(b_, p_["w"]) ** 2), {}

    tpol = make_policy("s2fp8_e4m3", backend="ref", gemm_mode="fig4")
    tparams = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 8),
                                      jnp.float32) * 0.1}
    tbatch = jax.random.normal(jax.random.PRNGKey(2), (8, 16), jnp.float32)
    tbank = statsbank.init_bank(toy_loss, tparams, tbatch, tpol, base_cfg)
    # warm the bank on the in-range batch, then probe one scaled 2^12x
    # hotter — the carried stats must report saturation
    warm, _ = obs_doctor.probe_bank(toy_loss, tparams, tbatch, tpol,
                                    tbank, base_cfg, step=0)
    probed, _ = obs_doctor.probe_bank(toy_loss, tparams,
                                      tbatch * jnp.float32(2.0 ** 12),
                                      tpol, warm, base_cfg, step=1)
    rows = obs_doctor.site_report(probed, step=1,
                                  refresh_every=args.refresh_every)
    print(obs_doctor.format_report(rows, backend="ref", top=args.top))
    worst = rows[0]
    ok = (worst["sat_frac"] > 0 and "SAT" in worst["flags"]
          and worst["recommend"] == "e5m2")
    if not ok:
        print("[s2fp8-doctor] smoke FAILED: saturating tensor not flagged")
        return 1
    print("[s2fp8-doctor] smoke ok: fresh checkpoint clean, saturating "
          "site flagged with e5m2 recommendation")
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.smoke:
        return _smoke(args)
    return run(args)


if __name__ == "__main__":
    sys.exit(main())
