"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — dryrun.py sets
XLA_FLAGS before importing anything else).

Axis contract: the ``data`` axis carries both the batch AND (under the
launchers' ``--shard-params`` FSDP modes) the param/optimizer shards —
``parallel/sharding.py``'s TRAIN_RULES maps the logical ``fsdp`` axis to
``data``, so every mesh built here supports ZeRO-3 sharding with no extra
axis.  Multi-host meshes additionally need ``jax.distributed.initialize``
before any builder runs (ROADMAP: multi-host FSDP remainder).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — for smoke runs (usually 1 device).

    All devices go on the ``data`` axis, so the mesh-native train step
    (training/trainer.py) data-parallelizes a multi-device host (e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` CPU smoke
    runs) out of the box."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_mesh_from_spec(spec: str):
    """``"DxT"`` mesh specs for the launchers' ``--mesh`` flag.

    Two ints (``"8x1"``) build a ``("data", "model")`` mesh; three
    (``"2x8x1"``) a ``("pod", "data", "model")`` one.  The product must
    match the visible device count (``jax.make_mesh`` enforces it)."""
    try:
        dims = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh spec {spec!r} is not of the form 'DxT' "
                         f"or 'PxDxT' (e.g. '8x1')") from None
    if len(dims) == 2:
        axes = ("data", "model")
    elif len(dims) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(f"mesh spec {spec!r}: want 2 (DxT) or 3 (PxDxT) "
                         f"factors, got {len(dims)}")
    return jax.make_mesh(dims, axes)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
