"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — dryrun.py sets
XLA_FLAGS before importing anything else).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — for smoke runs (usually 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
