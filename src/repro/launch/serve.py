"""Serving launcher: batched LM serving with the slot engines.

Dense engine (any block pattern):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b --reduced \
        --requests 8 --policy s2fp8

Payload engine (paged S2FP8 KV cache, frozen export-time stats; global
attention patterns only):

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm_2b --reduced \
        --engine payload --cache-fmt e5m2 --requests 16 --slots 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced_config
from repro.core.policy import make_policy
from repro.launch import api
from repro.obs.sinks import make_sink
from repro.serving import bank as sbank
from repro.serving import paged_cache
from repro.serving.engine import LMServer, PayloadLMServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="s2fp8")
    ap.add_argument("--engine", choices=("dense", "payload"), default="dense")
    ap.add_argument("--cache-fmt", default="e5m2",
                    choices=paged_cache.CACHE_FMTS)
    ap.add_argument("--block", type=int, default=16,
                    help="paged cache block size (payload engine)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--export-passes", type=int, default=2,
                    help="stats-bank probe passes at export (payload engine)")
    ap.add_argument("--metrics", default=None,
                    help="per-tick metrics sink spec (obs.sinks.make_sink)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.enc_dec:
        raise SystemExit("serve launcher covers decoder LMs; whisper uses "
                         "encdec.serve_prefill/serve_decode (see examples)")
    pol = make_policy(args.policy)
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(cfg, key)

    if args.engine == "payload":
        print(f"[serve] exporting frozen serving bank "
              f"({args.export_passes} probe passes)...")
        bank = sbank.export_serving_bank(
            params, cfg, pol, prompt_len=min(args.prompt_len, 32),
            passes=args.export_passes, seed=args.seed)
        sink = make_sink(args.metrics) if args.metrics else None
        server = PayloadLMServer(
            cfg, params, pol, bank=bank, slots=args.slots,
            max_len=args.max_len, block=args.block,
            cache_fmt=args.cache_fmt, sink=sink)
        pool_b, stats_b = server.cache_bytes()
        print(f"[serve] paged cache: {pool_b/1e6:.2f} MB pool + "
              f"{stats_b} B frozen stats ({args.cache_fmt}, "
              f"block={args.block}, {server.n_blocks} blocks)")
    else:
        server = LMServer(cfg, params, pol, slots=args.slots,
                          max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    ticks = server.run_to_completion()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"[serve] {args.requests} requests, {total_tokens} tokens, "
          f"{ticks} ticks, {dt:.2f}s ({total_tokens/dt:.1f} tok/s), "
          f"{len(server.prefill_shapes)} compiled prefill shapes")
    if args.engine == "payload":
        print(f"[serve] preemptions: {server.preemptions}")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
