"""Per-leaf HBM residency planner for the FSDP param-sharding modes.

Answers "does this arch's param + optimizer store fit per chip?" WITHOUT
compiling anything, by applying the trainer's own sharding/eligibility
rules (parallel/sharding.py) to the abstract param tree:

  * ``replicated`` — every device stores the full f32 master + both adamw
    moments: 12 bytes/element.
  * ``fsdp``       — eligible leaves (float, dim 0 divisible by the fsdp
    axis) store 1/n_shards of that, plus a transient full-size f32
    all-gather (4 bytes/element) while the leaf's GEMM consumes it.
  * ``fsdp_q``     — same sharded store, but payload-eligible leaves
    (rank 2, the GEMM B slots) gather as S2FP8 payloads: 1 byte/element
    + 8 bytes of (alpha, beta) stats riding along.  Non-payload eligible
    leaves still gather f32.

The gather term is reported both as a per-leaf PEAK (the just-in-time
schedule frees each gathered leaf after its GEMMs — the steady-state
working set holds one big leaf) and as a SUM (the pessimistic
everything-live bound).  Activations/temps are out of scope — this plans
the param/optimizer store the ISSUE's FSDP refactor moves, the rest is
dryrun.py's compiled memory_analysis.

Import-safe: pure shape arithmetic; nothing here initializes a jax
backend, so launch/dryrun.py can import it before pinning XLA_FLAGS.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Tuple

HBM_PER_CHIP_GB = 16.0        # TPU v5e (roofline/analysis.py's target part)
PAYLOAD_STATS_BYTES = 8       # f32 (alpha, beta) per payload leaf
MODES = ("replicated", "fsdp", "fsdp_q")

_FLOAT_DTYPES = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


def _dtype_name(dtype) -> str:
    # accepts np.dtype objects (.name), scalar types like jnp.float32
    # (.__name__), and plain strings
    return (getattr(dtype, "name", None)
            or getattr(dtype, "__name__", None) or str(dtype))


def _itemsize(dtype) -> int:
    name = _dtype_name(dtype)
    if name in _FLOAT_DTYPES:
        return _FLOAT_DTYPES[name]
    if "int8" in name or "uint8" in name or "bool" in name:
        return 1
    if "16" in name:
        return 2
    if "64" in name:
        return 8
    return 4


def leaf_eligible(shape: Tuple[int, ...], dtype, n_shards: int) -> bool:
    """Mirror of sharding.fsdp_leaf_eligible without touching jax: float
    dtype, rank >= 1, dim 0 divisible by the fsdp axis size."""
    if _dtype_name(dtype) not in _FLOAT_DTYPES:
        return False
    if len(shape) == 0 or shape[0] == 0:
        return False
    return shape[0] % n_shards == 0


def payload_eligible(shape: Tuple[int, ...], dtype, n_shards: int) -> bool:
    """The trainer streams payloads only for rank-2 eligible leaves (the
    GEMM B slots qdot_train consumes)."""
    return leaf_eligible(shape, dtype, n_shards) and len(shape) == 2


@dataclasses.dataclass
class LeafPlan:
    n_elements: int
    store_bytes: int          # per-device persistent store (one copy)
    gather_bytes: int         # transient full-size residency while live
    sharded: bool
    payload: bool


def plan_leaf(shape: Tuple[int, ...], dtype, n_shards: int,
              mode: str) -> LeafPlan:
    """Byte plan for ONE param (or moment) leaf under a sharding mode."""
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    n = int(math.prod(shape)) if shape else 1
    item = _itemsize(dtype)
    elig = n_shards > 1 and leaf_eligible(shape, dtype, n_shards) \
        and mode != "replicated"
    pay = elig and mode == "fsdp_q" and payload_eligible(shape, dtype,
                                                        n_shards)
    store = n * item // n_shards if elig else n * item
    if not elig:
        gather = 0                       # already resident full-size
    elif pay:
        gather = n * 1 + PAYLOAD_STATS_BYTES
    else:
        gather = n * item
    return LeafPlan(n_elements=n, store_bytes=store, gather_bytes=gather,
                    sharded=elig, payload=pay)


def plan_leaves(leaves: Iterable[Tuple[Tuple[int, ...], object]],
                n_shards: int, mode: str,
                with_gather: bool = True) -> Dict[str, int]:
    """Aggregate plan over (shape, dtype) leaves.  ``with_gather=False``
    for optimizer moments: updates run shard-local (ZeRO-3), the moments
    are never gathered."""
    out = {"store_bytes": 0, "gather_peak_bytes": 0, "gather_sum_bytes": 0,
           "n_leaves": 0, "n_sharded": 0, "n_payload": 0}
    for shape, dtype in leaves:
        lp = plan_leaf(tuple(shape), dtype, n_shards, mode)
        out["store_bytes"] += lp.store_bytes
        if with_gather:
            out["gather_peak_bytes"] = max(out["gather_peak_bytes"],
                                           lp.gather_bytes)
            out["gather_sum_bytes"] += lp.gather_bytes
        out["n_leaves"] += 1
        out["n_sharded"] += int(lp.sharded)
        out["n_payload"] += int(lp.payload)
    return out


def _tree_leaves(tree):
    """(shape, dtype) pairs from a pytree of arrays/ShapeDtypeStructs.
    Imported lazily: jax import is safe, but keep module import free of
    it for symmetry with launch/mesh.py's no-device-state contract."""
    import jax
    return [(tuple(l.shape), l.dtype)
            for l in jax.tree_util.tree_leaves(tree)]


def plan_state(param_tree, opt_tree, n_shards: int, mode: str) -> dict:
    """Full param + optimizer plan for one device.

    ``steady_bytes``: persistent store (params + moments).
    ``peak_bytes``: steady + the largest single transient gather.
    """
    p = plan_leaves(_tree_leaves(param_tree), n_shards, mode)
    o = plan_leaves(_tree_leaves(opt_tree), n_shards, mode,
                    with_gather=False)
    steady = p["store_bytes"] + o["store_bytes"]
    return {
        "mode": mode, "n_shards": n_shards,
        "param_store_bytes": p["store_bytes"],
        "opt_store_bytes": o["store_bytes"],
        "steady_bytes": steady,
        "gather_peak_bytes": p["gather_peak_bytes"],
        "gather_sum_bytes": p["gather_sum_bytes"],
        "peak_bytes": steady + p["gather_peak_bytes"],
        "n_leaves": p["n_leaves"], "n_sharded": p["n_sharded"],
        "n_payload": p["n_payload"],
    }


def fsdp_shards_of(axis_sizes: Dict[str, int]) -> int:
    """fsdp-axis size for a mesh's {axis: size} dict under TRAIN_RULES
    (the ``data`` axis carries the fsdp logical axis — launch/mesh.py)."""
    return int(axis_sizes.get("data", 1))


def plan_arch(arch: str, n_shards: int, mode: str = "fsdp_q",
              hbm_gb: float = HBM_PER_CHIP_GB) -> dict:
    """Plan one arch config's train-time store (f32 masters + adamw
    moments, paper Fig. 4) and render the fits-or-not verdict."""
    import jax
    from repro.configs.base import get_config
    from repro.launch import api
    from repro.optim import optimizers

    cfg = get_config(arch)
    pstruct = api.param_struct(cfg)
    ostruct = jax.eval_shape(optimizers.adamw().init, pstruct)
    plan = plan_state(pstruct, ostruct, n_shards, mode)
    plan["arch"] = arch
    plan["hbm_gb"] = hbm_gb
    plan["fits"] = plan["peak_bytes"] <= hbm_gb * 2**30
    return plan


def format_report(archs, axis_sizes: Dict[str, int],
                  hbm_gb: float = HBM_PER_CHIP_GB) -> str:
    """Residency table (GB/device) across all three modes per arch."""
    n = fsdp_shards_of(axis_sizes)
    gb = 2**30
    lines = [f"[memplan] fsdp axis: {n}-way 'data' "
             f"({dict(axis_sizes)}), HBM {hbm_gb:.0f} GB/chip",
             f"{'arch':<22}{'mode':<12}{'params':>9}{'opt':>9}"
             f"{'gather':>9}{'peak':>9}  fits"]
    for arch in archs:
        for mode in MODES:
            p = plan_arch(arch, n, mode, hbm_gb)
            lines.append(
                f"{arch:<22}{mode:<12}"
                f"{p['param_store_bytes'] / gb:>8.2f}G"
                f"{p['opt_store_bytes'] / gb:>8.2f}G"
                f"{p['gather_peak_bytes'] / gb:>8.2f}G"
                f"{p['peak_bytes'] / gb:>8.2f}G"
                f"  {'yes' if p['fits'] else 'NO'}")
    return "\n".join(lines)
