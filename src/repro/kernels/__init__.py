# Kernel layer: Pallas TPU kernels (s2fp8_quant, s2fp8_matmul,
# flash_attention, selective_scan), their pure-jnp oracles (ref.py), the
# shape/rank-generalizing dispatch layer (dispatch.py), and the public
# jit'd wrappers (ops.py).  See README.md in this directory for how the
# numerics-backend registry in core/backend.py selects between them.
import jax


def auto_interpret() -> bool:
    """Resolve ``interpret=None`` on a Pallas kernel: compile on TPU,
    fall back to the (slow, debug-grade) interpreter everywhere else."""
    return jax.default_backend() != "tpu"
