"""Jit'd public wrappers dispatching between Pallas kernels and jnp refs.

On a real TPU runtime, set ``interpret=False`` (the default flips on TPU
backends).  In this CPU container the kernels execute via interpret=True —
same kernel body, Python evaluation — and the refs serve both as oracles
and as the fast CPU path for large shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.s2fp8_quant import quant_pallas, dequant_pallas, stats_pallas
from repro.kernels.s2fp8_matmul import s2fp8_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def s2fp8_quant(x: jnp.ndarray, *, use_pallas: bool | None = None):
    """(payload_e5m2, alpha, beta). x must be 2-D for the kernel path."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas and x.ndim == 2:
        return quant_pallas(x, interpret=not _on_tpu())
    return ref.s2fp8_quant_ref(x)


def s2fp8_dequant(payload, alpha, beta, *, use_pallas: bool | None = None):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas and payload.ndim == 2:
        return dequant_pallas(payload, alpha, beta, interpret=not _on_tpu())
    return ref.s2fp8_dequant_ref(payload, alpha, beta)


def s2fp8_matmul(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                 *, use_pallas: bool | None = None):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return s2fp8_matmul_pallas(a_payload, a_alpha, a_beta,
                                   b_payload, b_alpha, b_beta,
                                   interpret=not _on_tpu())
    return ref.s2fp8_matmul_ref(a_payload, a_alpha, a_beta,
                                b_payload, b_alpha, b_beta)


def flash_attention(q, k, v, *, causal=True, window=None,
                    use_pallas: bool | None = None, bq=512, bk=512):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window)
