"""Jit'd public wrappers dispatching between Pallas kernels and jnp refs.

On TPU the Pallas path compiles (``interpret`` auto-resolves to False via
``repro.kernels.auto_interpret``); elsewhere the kernels run under the
interpreter — same kernel body, Python evaluation — and the refs serve
both as oracles and as the fast CPU path for large shapes.

Shape handling lives in kernels/dispatch.py: any rank, any (ragged) shape
— tensors are re-tiled/zero-padded to the block grid and sliced back, so
callers never see the kernels' 2-D block-divisible contract.  The
backend-object layer over these functions is core/backend.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import auto_interpret, dispatch, ref
from repro.kernels.flash_attention import flash_attention_pallas


def _use_pallas(flag: bool | None) -> bool:
    # one platform probe governs kernels and wrappers alike
    return (not auto_interpret()) if flag is None else flag


def s2fp8_quant(x: jnp.ndarray, *, use_pallas: bool | None = None):
    """(payload_e5m2, alpha, beta); any rank/shape on either path."""
    if _use_pallas(use_pallas):
        return dispatch.quant_nd(x)
    return ref.s2fp8_quant_ref(x)


def s2fp8_dequant(payload, alpha, beta, *, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return dispatch.dequant_nd(payload, alpha, beta)
    return ref.s2fp8_dequant_ref(payload, alpha, beta)


def s2fp8_truncate(x: jnp.ndarray, *, stats=None, fmt: str = "e5m2",
                   use_pallas: bool | None = None):
    """Fused Eq. 5 truncation; ``stats=(alpha, beta)`` enables the
    delayed-stats single-pass path."""
    if _use_pallas(use_pallas):
        return dispatch.truncate_nd(x, stats=stats, fmt=fmt)
    return ref.s2fp8_truncate_ref(x, stats=stats, fmt=fmt)


def s2fp8_matmul(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                 *, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return dispatch.qmatmul_nd(a_payload, a_alpha, a_beta,
                                   b_payload, b_alpha, b_beta)
    return ref.s2fp8_matmul_ref(a_payload, a_alpha, a_beta,
                                b_payload, b_alpha, b_beta)


def flash_attention(q, k, v, *, causal=True, window=None,
                    use_pallas: bool | None = None, bq=512, bk=512):
    if _use_pallas(use_pallas):
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=auto_interpret())
    return ref.attention_ref(q, k, v, causal=causal, window=window)
