"""Shape/rank-generalizing dispatch for the S2FP8 Pallas kernels.

The raw kernels in s2fp8_quant.py / s2fp8_matmul.py are deliberately strict:
2-D, block-divisible inputs only (that is the shape the TPU wants).  Real
tensors are none of those things — conv kernels are 4-D, bias rows are 1-D,
vocab projections are 50257-wide.  This layer closes the gap:

  * arbitrary rank  — tensors are flattened and re-tiled to a (rows, LANE)
    2-D layout (LANE = 512, a multiple of the 128-lane VPU width);
  * ragged shapes   — zero-padded up to the block grid.  Zero is the one
    value S2FP8 treats specially everywhere (excluded from stats, mapped to
    itself by both transforms), so zero-padding is exact: padding never
    perturbs stats, truncation, or GEMM results;
  * platform        — ``interpret=None`` resolves via
    ``repro.kernels.auto_interpret()`` (compiled on TPU, interpreter
    elsewhere);
  * stats modes     — every truncate entry point accepts precomputed
    ``stats=(alpha, beta)`` (the delayed-stats fast path: one HBM pass) or
    computes them, either exactly (same monolithic reduction as the
    reference — bitwise-parity mode) or in-kernel (``fused_stats=True``,
    the two-phase single-kernel path).

core/backend.py builds the user-facing backend objects on top of these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import s2fp8
from repro.kernels import auto_interpret
from repro.kernels.ref import gemm_dims
from repro.kernels.s2fp8_matmul import (pick_gemm_block, s2fp8_matmul_pallas,
                                        s2fp8_matmul_batched_pallas)
from repro.kernels.s2fp8_quant import (DEFAULT_BLOCK, dequant_pallas,
                                       quant_apply_pallas, quant_pallas,
                                       stats_pallas, truncate_apply_pallas,
                                       truncate_fused_pallas)

# Lane width for the flattened layout of non-2-D tensors.
LANE = 512
# Hardware tile alignment every block is padded to: TPU f32 tiles are
# (8, 128) (sublane x lane); interpret mode does not care, but compiled
# Mosaic does, so ragged shapes are padded to these multiples BEFORE the
# block grid is derived.
SUBLANE_ALIGN = 8
LANE_ALIGN = 128


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_axis(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_to_lane(x: jnp.ndarray, align: int = LANE_ALIGN) -> jnp.ndarray:
    """Zero-pad the trailing axis up to a multiple of ``align`` (the MXU
    lane width).  Exact for S2FP8 payload math: zero elements carry a zero
    payload, are excluded from stats, and contribute nothing to any
    contraction — so a padded attention/GEMM over payloads equals the
    unpadded one on the original columns."""
    return _pad_axis(x, x.ndim - 1,
                     _ceil_to(max(x.shape[-1], 1), align))


def as_blocked_2d(x: jnp.ndarray, block=DEFAULT_BLOCK) -> jnp.ndarray:
    """Reshape/zero-pad an arbitrary-rank tensor into a tile-aligned,
    block-divisible 2-D layout the kernels accept.  Invert with
    :func:`from_blocked_2d`."""
    if x.ndim == 2:
        x2 = x
    else:
        flat = x.reshape(-1)
        lane = min(LANE, _ceil_to(max(flat.shape[0], 1), LANE_ALIGN))
        # widen the lane so the block width and tile alignment both divide
        # it: all later padding then lands on whole trailing rows, never
        # interleaved mid-row (from_blocked_2d's flatten-and-slice inverse
        # requires the flattened element order to be a prefix)
        lane = _ceil_to(lane, math.lcm(min(block[1], lane), LANE_ALIGN))
        flat = _pad_axis(flat, 0, _ceil_to(max(flat.shape[0], 1), lane))
        x2 = flat.reshape(-1, lane)
    x2 = _pad_axis(x2, 0, _ceil_to(x2.shape[0], SUBLANE_ALIGN))
    x2 = _pad_axis(x2, 1, _ceil_to(x2.shape[1], LANE_ALIGN))
    bm = min(block[0], x2.shape[0])
    bn = min(block[1], x2.shape[1])
    x2 = _pad_axis(x2, 0, _ceil_to(x2.shape[0], bm))
    return _pad_axis(x2, 1, _ceil_to(x2.shape[1], bn))


def from_blocked_2d(y2: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Undo :func:`as_blocked_2d`: strip padding, restore the original shape."""
    if len(shape) == 2:
        return y2[: shape[0], : shape[1]]
    size = 1
    for d in shape:
        size *= d
    return y2.reshape(-1)[:size].reshape(shape)


# ---------------------------------------------------------------------------
# quantization / stats
# ---------------------------------------------------------------------------

def stats_partials_nd(x: jnp.ndarray, *, block=DEFAULT_BLOCK,
                      interpret: Optional[bool] = None):
    """Raw (log_sum, log_max, count) triplet via the Pallas blocked
    reduction, any rank/shape.  Zero-padding is exact (zeros are excluded
    from the reduction), so partials from disjoint shards combine with
    (+, max, +) — the sharded-stats building block."""
    x2 = as_blocked_2d(x.astype(jnp.float32), block)
    return stats_pallas(x2, block=block, interpret=interpret)


def stats_nd(x: jnp.ndarray, *, target_max: float = s2fp8.TARGET_MAX_LOG2,
             block=DEFAULT_BLOCK, interpret: Optional[bool] = None):
    """(alpha, beta) via the Pallas blocked reduction, any rank/shape."""
    s, mx, c = stats_partials_nd(x, block=block, interpret=interpret)
    return s2fp8.stats_from_reduction(s, mx, c, target_max)


def quant_nd(x: jnp.ndarray, *, stats=None, fmt: str = "e5m2",
             block=DEFAULT_BLOCK, interpret: Optional[bool] = None):
    """(payload, alpha, beta) with payload in x's shape, any rank.

    ``stats=(alpha, beta)`` skips the in-kernel reduction and quantizes
    with the given scalars (exact-stats / delayed-stats paths); ``fmt``
    selects the payload format (e5m2 / e4m3).
    """
    x2 = as_blocked_2d(x.astype(jnp.float32), block)
    if stats is None:
        payload2, alpha, beta = quant_pallas(x2, fmt=fmt, block=block,
                                             interpret=interpret)
    else:
        alpha, beta = stats
        payload2 = quant_apply_pallas(x2, alpha, beta, fmt=fmt, block=block,
                                      interpret=interpret)
    return from_blocked_2d(payload2, x.shape), alpha, beta


def dequant_nd(payload: jnp.ndarray, alpha, beta, *, dtype=jnp.float32,
               block=DEFAULT_BLOCK, interpret: Optional[bool] = None):
    """Dense tensor from an e5m2 payload of any rank."""
    p2 = as_blocked_2d(payload, block)
    out2 = dequant_pallas(p2, jnp.asarray(alpha, jnp.float32),
                          jnp.asarray(beta, jnp.float32),
                          block=block, interpret=interpret)
    return from_blocked_2d(out2, payload.shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused truncate (Eq. 5)
# ---------------------------------------------------------------------------

def truncate_nd(x: jnp.ndarray, *, stats=None, fmt: str = "e5m2",
                fused_stats: bool = False, block=DEFAULT_BLOCK,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused S2FP8 truncation of an arbitrary-rank tensor.

    Stats selection (in priority order):
      * ``stats=(alpha, beta)`` — delayed-stats mode: no reduction at all,
        a single elementwise HBM pass.
      * ``fused_stats=True``    — the two-phase single-kernel path
        (in-kernel blocked reduction; float-tolerance parity with the ref).
      * default                 — exact stats via the same monolithic jnp
        reduction the reference uses, then the fused elementwise kernel:
        bitwise-identical to ``s2fp8.truncate_value`` and still only two
        HBM passes over the tensor.
    """
    target_max = s2fp8.FMT_TARGET_MAX[fmt]
    x2 = as_blocked_2d(x.astype(jnp.float32), block)
    if stats is None and fused_stats:
        out2, _, _ = truncate_fused_pallas(x2, fmt=fmt, target_max=target_max,
                                           block=block, interpret=interpret)
    else:
        if stats is None:
            stats = s2fp8.compute_stats_jit(x, target_max=target_max)
        alpha, beta = stats
        out2 = truncate_apply_pallas(x2, alpha, beta, fmt=fmt,
                                     block=block, interpret=interpret)
    return from_blocked_2d(out2, x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# quantized GEMM
# ---------------------------------------------------------------------------

def _gemm_pad_plan(layout, a_payload, b_payload, bm, bk, bn, axis0: int):
    """Shared alignment/heuristic/padding of the 2-D GEMM tile of each
    operand (``axis0`` = index of the tile's first axis: 0 for plain
    GEMMs, 1 for batched ones — the leading batch axis needs no padding).

    Per-layout tile alignment: a GEMM dim needs the 128-lane multiple
    only where it is the LANE (last) dim of a stored operand or of the
    output; row dims need sublane (8).  M: sublane everywhere except
    "tn" (lane of the stored [K, M] operand).  K: lane of A ("nn") or
    of both operands ("nt"), rows-only under "tn".  N: always the
    output's lane.  This keeps small-M inference GEMMs at 8-row padding
    instead of inflating them 16x.  Returns
    ``(a_pad, b_pad, bm_, bk_, bn_, m, n)``.
    """
    m, k, n = gemm_dims(layout, a_payload.shape[axis0:],
                        b_payload.shape[axis0:])
    ma = _ceil_to(m, LANE_ALIGN if layout == "tn" else SUBLANE_ALIGN)
    ka = _ceil_to(k, SUBLANE_ALIGN if layout == "tn" else LANE_ALIGN)
    na = _ceil_to(n, LANE_ALIGN)
    hm, hk, hn = pick_gemm_block(ma, ka, na)
    bm_ = min(hm if bm is None else bm, ma)
    bk_ = min(hk if bk is None else bk, ka)
    bn_ = min(hn if bn is None else bn, na)
    mp, kp, np_ = _ceil_to(ma, bm_), _ceil_to(ka, bk_), _ceil_to(na, bn_)
    pads = {"nn": ((mp, kp), (kp, np_)),
            "nt": ((mp, kp), (np_, kp)),
            "tn": ((kp, mp), (kp, np_))}[layout]
    (ar, ac), (br, bc) = pads
    a_pad = _pad_axis(_pad_axis(a_payload, axis0, ar), axis0 + 1, ac)
    b_pad = _pad_axis(_pad_axis(b_payload, axis0, br), axis0 + 1, bc)
    return a_pad, b_pad, bm_, bk_, bn_, m, n


def qmatmul_nd(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta, *,
               layout: str = "nn", epilogue_stats=None, fmt: str = "e5m2",
               bm: Optional[int] = None, bk: Optional[int] = None,
               bn: Optional[int] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """C[M,N] = dequant(A) @ dequant(B) under ``layout``, arbitrary M/K/N.

    Ragged dims are zero-padded to the block grid (payload zeros dequantize
    to 0.0, contributing nothing to the accumulation; the Eq. 5 epilogue
    maps zero to zero) and the result is sliced back.  Block sizes default
    to the (M, K, N, platform) heuristic table in
    ``s2fp8_matmul.pick_gemm_block`` (``REPRO_GEMM_BLOCK`` overrides).
    ``epilogue_stats=(alpha, beta)`` fuses the output-site truncation into
    the kernel's last K step.
    """
    a_pad, b_pad, bm_, bk_, bn_, m, n = _gemm_pad_plan(
        layout, a_payload, b_payload, bm, bk, bn, axis0=0)
    oa, ob = (None, None) if epilogue_stats is None else epilogue_stats
    out = s2fp8_matmul_pallas(a_pad, jnp.asarray(a_alpha, jnp.float32),
                              jnp.asarray(a_beta, jnp.float32),
                              b_pad, jnp.asarray(b_alpha, jnp.float32),
                              jnp.asarray(b_beta, jnp.float32),
                              oa, ob, layout=layout, fmt=fmt,
                              bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:m, :n]


def qmatmul_batched_nd(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                       *, layout: str = "nn", out_batch: Optional[int] = None,
                       epilogue_stats=None, fmt: str = "e5m2",
                       bm: Optional[int] = None, bk: Optional[int] = None,
                       bn: Optional[int] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """C[Go,M,N] = batched dequant-GEMM under ``layout``, arbitrary M/K/N.

    The leading batch axes need no padding (block batch size is 1); the
    trailing two dims of each operand get the same per-layout tile
    alignment + block-grid zero-padding as :func:`qmatmul_nd`
    (``_gemm_pad_plan``; exact for S2FP8).  Broadcast (``Ga``/``Gb``
    dividing the combined batch) and ``out_batch`` reduction semantics
    live in ``s2fp8_matmul_batched_pallas``.
    """
    a_pad, b_pad, bm_, bk_, bn_, m, n = _gemm_pad_plan(
        layout, a_payload, b_payload, bm, bk, bn, axis0=1)
    oa, ob = (None, None) if epilogue_stats is None else epilogue_stats
    out = s2fp8_matmul_batched_pallas(
        a_pad, jnp.asarray(a_alpha, jnp.float32),
        jnp.asarray(a_beta, jnp.float32),
        b_pad, jnp.asarray(b_alpha, jnp.float32),
        jnp.asarray(b_beta, jnp.float32),
        oa, ob, layout=layout, out_batch=out_batch, fmt=fmt,
        bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:, :m, :n]
