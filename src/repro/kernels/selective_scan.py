"""Pallas TPU kernel: Mamba-1 selective scan with VMEM-resident state.

WHY (EXPERIMENTS.md §Perf, falcon cell): the XLA lax.scan formulation
round-trips the state h [B,di,n] plus per-step [di,n] temporaries through
HBM every timestep — the static analysis shows ~2.2 PB/device/step for
falcon-mamba train_4k (memory term ~2600s).  Unrolling helps 1.5x; the SSD
chunk factorization that fixes Mamba-2 is numerically UNSTABLE for Mamba-1
(matrix A: exp(±cum) factors overflow f32 for fast-decaying channels — the
exact reason Mamba-2 moved to scalar decay).  The TPU-native answer is a
kernel that pins h and the dA temporaries in VMEM/VREGs and streams only
x/dt/B/C/y through HBM:

    traffic = (3*[B,S,di] + 2*[B,S,n] streams) ~ 4 bytes/elt each
    vs ~ 2*[B,di,n]*S state round-trips + per-step temporaries.

Grid: (B, di/bd).  Each program owns a [bd, n] state slab and walks the
whole sequence with fori_loop; x/dt/y tiles [S, bd] and B/C tiles [S, n]
live in VMEM for the program's lifetime (S=4096, bd=256, n=16:
~3 * 4096*256*4 + 2 * 4096*16*4 + 256*16*4 bytes ~= 13 MiB — fits v5e VMEM;
halve bd for longer S).

Validated against ref.selective_scan_ref in interpret mode
(tests/test_kernels.py); the dry-run graphs keep the lax.scan form (the
CPU backend can't lower pallas), so EXPERIMENTS.md reports this kernel's
roofline analytically next to the XLA-sim numbers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
                 y_ref, hout_ref, *, seq_len):
    a = a_ref[...]                       # [bd, n]
    dskip = d_ref[0]                     # [bd]
    h0 = jnp.zeros(a.shape, jnp.float32)

    def step(t, h):
        xt = x_ref[0, t, :]              # [bd]
        dtt = dt_ref[0, t, :]            # [bd]
        bt = b_ref[0, t, :]              # [n]
        ct = c_ref[0, t, :]              # [n]
        da = jnp.exp(dtt[:, None] * a)   # [bd, n] — in-register
        h = h * da + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=-1) + dskip * xt
        y_ref[0, t, :] = y
        return h

    h = jax.lax.fori_loop(0, seq_len, step, h0)
    hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def selective_scan_pallas(x, dt, bmat, cmat, a, d_skip, *,
                          block_d: int = 256, interpret: bool = True):
    """x, dt: [B,S,di]; bmat, cmat: [B,S,n]; a: [di,n]; d_skip: [di].
    Returns (y [B,S,di], h_final [B,di,n])."""
    b, s, di = x.shape
    n = bmat.shape[-1]
    bd = min(block_d, di)
    assert di % bd == 0
    grid = (b, di // bd)
    y, hout = pl.pallas_call(
        functools.partial(_scan_kernel, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bd, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32),
      bmat.astype(jnp.float32), cmat.astype(jnp.float32),
      a.astype(jnp.float32), d_skip.reshape(1, -1).astype(jnp.float32))
    return y, hout
