"""Pallas TPU kernels for S2FP8 quantization (stats + apply).

The paper (§5) describes two HW components: (1) a statistics unit computing
(mu, m) per tensor, (2) an exponent-shift / mantissa-squeeze unit applied
before the 8-bit truncation.  On TPU these become:

  * ``stats``  — a blocked reduction over the tensor resident in HBM,
    streamed through VMEM tiles; partials accumulate in a (1,1) VMEM cell
    across the sequential grid (TPU grid iterations run in order on a core).
  * ``apply``  — an elementwise VPU map: y = sign(x)*2^(alpha*log2|x|+beta),
    cast RNE to float8_e5m2 in-register, written back as the 1-byte payload.

Block shapes default to (256, 512): 256*512*4B = 512 KiB per input tile —
comfortably inside the ~16 MiB v5e VMEM with double-buffering, and the
lane dim (512) is a multiple of 128 for clean vectorization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)
_NEG_INF = -jnp.inf


def _stats_kernel(x_ref, sum_ref, max_ref, cnt_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        sum_ref[0, 0] = 0.0
        max_ref[0, 0] = _NEG_INF
        cnt_ref[0, 0] = 0.0

    x = x_ref[...].astype(jnp.float32)
    absx = jnp.abs(x)
    nz = absx > 0.0
    logx = jnp.where(nz, jnp.log2(jnp.where(nz, absx, 1.0)), 0.0)
    sum_ref[0, 0] += jnp.sum(logx)
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], jnp.max(jnp.where(nz, logx, _NEG_INF)))
    cnt_ref[0, 0] += jnp.sum(nz.astype(jnp.float32))


def _apply_kernel(alpha_ref, beta_ref, x_ref, out_ref):
    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    absx = jnp.abs(x)
    nz = absx > 0.0
    ylog = alpha * jnp.log2(jnp.where(nz, absx, 1.0)) + beta
    y = jnp.where(nz, jnp.sign(x) * jnp.exp2(ylog), 0.0)
    out_ref[...] = y.astype(jnp.float8_e5m2)


def _dequant_kernel(alpha_ref, beta_ref, y_ref, out_ref):
    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    y = y_ref[...].astype(jnp.float32)
    absy = jnp.abs(y)
    nz = absy > 0.0
    xlog = (jnp.log2(jnp.where(nz, absy, 1.0)) - beta) / alpha
    out_ref[...] = jnp.where(nz, jnp.sign(y) * jnp.exp2(xlog), 0.0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stats_pallas(x: jnp.ndarray, *, block=DEFAULT_BLOCK, interpret: bool = True):
    """Blocked (sum_log, max_log, count) reduction. x must be 2-D, block-divisible."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    s, mx, c = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[scalar_spec, scalar_spec, scalar_spec],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(x)
    return s[0, 0], mx[0, 0], c[0, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quant_pallas(x: jnp.ndarray, *, block=DEFAULT_BLOCK, interpret: bool = True):
    """Full S2FP8 quantization: returns (payload_e5m2, alpha, beta)."""
    from repro.core.s2fp8 import TARGET_MAX_LOG2, _DEGENERATE_EPS

    s, mx, c = stats_pallas(x, block=block, interpret=interpret)
    mu = s / jnp.maximum(c, 1.0)
    spread = mx - mu
    degenerate = spread < _DEGENERATE_EPS
    alpha = jnp.where(degenerate, 1.0,
                      TARGET_MAX_LOG2 / jnp.where(degenerate, 1.0, spread))
    beta = jnp.where(degenerate, TARGET_MAX_LOG2 - mx, -alpha * mu)
    empty = c == 0
    alpha = jnp.where(empty, 1.0, alpha)
    beta = jnp.where(empty, 0.0, beta)

    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    payload = pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec,
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float8_e5m2),
        interpret=interpret,
    )(alpha.reshape(1, 1), beta.reshape(1, 1), x)
    return payload, alpha, beta


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_pallas(payload, alpha, beta, *, block=DEFAULT_BLOCK, interpret: bool = True):
    """Inverse map back to f32."""
    m, n = payload.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec,
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(alpha.reshape(1, 1), beta.reshape(1, 1), payload)
