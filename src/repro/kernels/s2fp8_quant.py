"""Pallas TPU kernels for S2FP8 quantization (stats + apply + fused truncate).

The paper (§5) describes two HW components: (1) a statistics unit computing
(mu, m) per tensor, (2) an exponent-shift / mantissa-squeeze unit applied
before the 8-bit truncation.  On TPU these become:

  * ``stats``  — a blocked reduction over the tensor resident in HBM,
    streamed through VMEM tiles; partials accumulate in a (1,1) VMEM cell
    across the sequential grid (TPU grid iterations run in order on a core).
  * ``apply``  — an elementwise VPU map: y = sign(x)*2^(alpha*log2|x|+beta),
    cast RNE to float8_e5m2 in-register, written back as the 1-byte payload.
  * ``truncate`` — the Eq. 5 round-trip (forward map -> FP8 RNE -> inverse
    map) fused into ONE elementwise kernel: one HBM read + one HBM write,
    where the reference jnp path issues three elementwise passes.
  * ``truncate_fused`` — stats AND the truncate round-trip in a single
    ``pallas_call`` with a two-phase sequential grid: phase 0 streams the
    tensor once to accumulate (sum, max, count), phase 1 streams it again
    applying forward->RNE->inverse.  Two HBM passes total instead of the
    reference path's ~five.

Block shapes default to (256, 512): 256*512*4B = 512 KiB per input tile —
comfortably inside the ~16 MiB v5e VMEM with double-buffering, and the
lane dim (512) is a multiple of 128 for clean vectorization.

All entry points take ``interpret=None`` which resolves via
``repro.kernels.auto_interpret()``: compiled on TPU, interpreter elsewhere.
Inputs must be 2-D and block-divisible — arbitrary rank and ragged shapes
are handled one layer up in ``repro.kernels.dispatch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.s2fp8 import (FMT_MAX_FINITE, FMT_QDTYPE, FMT_TARGET_MAX,
                              TARGET_MAX_LOG2, stats_from_reduction)
from repro.kernels import auto_interpret

DEFAULT_BLOCK = (256, 512)
_NEG_INF = -jnp.inf


def _resolve(interpret):
    return auto_interpret() if interpret is None else interpret


def _stats_kernel(x_ref, sum_ref, max_ref, cnt_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        sum_ref[0, 0] = 0.0
        max_ref[0, 0] = _NEG_INF
        cnt_ref[0, 0] = 0.0

    x = x_ref[...].astype(jnp.float32)
    absx = jnp.abs(x)
    nz = absx > 0.0
    logx = jnp.where(nz, jnp.log2(jnp.where(nz, absx, 1.0)), 0.0)
    sum_ref[0, 0] += jnp.sum(logx)
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], jnp.max(jnp.where(nz, logx, _NEG_INF)))
    cnt_ref[0, 0] += jnp.sum(nz.astype(jnp.float32))


def _apply_kernel(alpha_ref, beta_ref, x_ref, out_ref, *, fmt):
    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    absx = jnp.abs(x)
    nz = absx > 0.0
    ylog = alpha * jnp.log2(jnp.where(nz, absx, 1.0)) + beta
    y = jnp.where(nz, jnp.sign(x) * jnp.exp2(ylog), 0.0)
    # clamp at the format's max finite, mirroring core/s2fp8.py quantize:
    # a no-op for fresh stats, saturation (not inf) under stale bank stats
    y = jnp.clip(y, -FMT_MAX_FINITE[fmt], FMT_MAX_FINITE[fmt])
    out_ref[...] = y.astype(FMT_QDTYPE[fmt])


def _dequant_kernel(alpha_ref, beta_ref, y_ref, out_ref):
    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    y = y_ref[...].astype(jnp.float32)
    absy = jnp.abs(y)
    nz = absy > 0.0
    xlog = (jnp.log2(jnp.where(nz, absy, 1.0)) - beta) / alpha
    out_ref[...] = jnp.where(nz, jnp.sign(y) * jnp.exp2(xlog), 0.0)


def _truncate_body(x, alpha, beta, fmt):
    """Forward map -> clamp -> FP8 RNE -> inverse map, elementwise
    in-register.

    The op sequence mirrors core/s2fp8.py's truncate_value exactly so that
    (given identical alpha, beta) the result is bitwise identical to the
    reference path.  The clamp at the format's max finite is a no-op for
    fresh stats and saturates (instead of inf) under stale delayed stats.
    """
    qdtype = FMT_QDTYPE[fmt]
    fmax = FMT_MAX_FINITE[fmt]
    absx = jnp.abs(x)
    nz = absx > 0.0
    ylog = alpha * jnp.log2(jnp.where(nz, absx, 1.0)) + beta
    y = jnp.where(nz, jnp.sign(x) * jnp.exp2(ylog), 0.0).astype(jnp.float32)
    y = jnp.clip(y, -fmax, fmax)
    yq = y.astype(qdtype).astype(jnp.float32)
    absyq = jnp.abs(yq)
    nzq = absyq > 0.0
    xlog = (jnp.log2(jnp.where(nzq, absyq, 1.0)) - beta) / alpha
    return jnp.where(nzq, jnp.sign(yq) * jnp.exp2(xlog), 0.0)


def _truncate_kernel(alpha_ref, beta_ref, x_ref, out_ref, *, fmt):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = _truncate_body(x, alpha_ref[0, 0], beta_ref[0, 0], fmt)


def _truncate_fused_kernel(x_ref, out_ref, stats_ref, *, fmt, target_max):
    """Two-phase grid (phase, i, j): phase 0 reduces stats into the
    persistent (1, 3) stats output [sum, max, count]; phase 1 re-reads the
    tensor and applies the fused truncate round-trip."""
    phase = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((phase == 0) & (i == 0) & (j == 0))
    def _init():
        stats_ref[0, 0] = 0.0
        stats_ref[0, 1] = _NEG_INF
        stats_ref[0, 2] = 0.0

    x = x_ref[...].astype(jnp.float32)
    absx = jnp.abs(x)
    nz = absx > 0.0
    logx = jnp.where(nz, jnp.log2(jnp.where(nz, absx, 1.0)), 0.0)

    @pl.when(phase == 0)
    def _reduce():
        stats_ref[0, 0] += jnp.sum(logx)
        stats_ref[0, 1] = jnp.maximum(stats_ref[0, 1],
                                      jnp.max(jnp.where(nz, logx, _NEG_INF)))
        stats_ref[0, 2] += jnp.sum(nz.astype(jnp.float32))

    @pl.when(phase == 1)
    def _apply():
        # Shared scalar epilogue — pure jnp, runs fine in-kernel, and any
        # change to the degenerate-case conventions propagates here.
        alpha, beta = stats_from_reduction(stats_ref[0, 0], stats_ref[0, 1],
                                           stats_ref[0, 2], target_max)
        out_ref[...] = _truncate_body(x, alpha, beta, fmt)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stats_pallas(x: jnp.ndarray, *, block=DEFAULT_BLOCK, interpret: bool | None = None):
    """Blocked (sum_log, max_log, count) reduction. x must be 2-D, block-divisible."""
    interpret = _resolve(interpret)
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    s, mx, c = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[scalar_spec, scalar_spec, scalar_spec],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(x)
    return s[0, 0], mx[0, 0], c[0, 0]


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def quant_apply_pallas(x: jnp.ndarray, alpha, beta, *, fmt: str = "e5m2",
                       block=DEFAULT_BLOCK, interpret: bool | None = None):
    """Forward map + FP8 cast with externally supplied (alpha, beta)."""
    interpret = _resolve(interpret)
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        functools.partial(_apply_kernel, fmt=fmt),
        grid=grid,
        in_specs=[scalar_spec, scalar_spec,
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), FMT_QDTYPE[fmt]),
        interpret=interpret,
    )(jnp.asarray(alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(beta, jnp.float32).reshape(1, 1), x)


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def quant_pallas(x: jnp.ndarray, *, fmt: str = "e5m2", block=DEFAULT_BLOCK,
                 interpret: bool | None = None):
    """Full S2FP8 quantization: returns (payload, alpha, beta)."""
    interpret = _resolve(interpret)
    s, mx, c = stats_pallas(x, block=block, interpret=interpret)
    alpha, beta = stats_from_reduction(s, mx, c, FMT_TARGET_MAX[fmt])
    payload = quant_apply_pallas(x, alpha, beta, fmt=fmt, block=block,
                                 interpret=interpret)
    return payload, alpha, beta


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_pallas(payload, alpha, beta, *, block=DEFAULT_BLOCK,
                   interpret: bool | None = None):
    """Inverse map back to f32."""
    interpret = _resolve(interpret)
    m, n = payload.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec,
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(alpha.reshape(1, 1), beta.reshape(1, 1), payload)


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def truncate_apply_pallas(x: jnp.ndarray, alpha, beta, *, fmt: str = "e5m2",
                          block=DEFAULT_BLOCK, interpret: bool | None = None):
    """Fused Eq. 5 round-trip with externally supplied (alpha, beta):
    ONE elementwise kernel (one HBM read, one HBM write).  This is the
    delayed-stats fast path and the bitwise-parity path (stats from the
    same reduction the reference uses -> bitwise-identical output)."""
    interpret = _resolve(interpret)
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        functools.partial(_truncate_kernel, fmt=fmt),
        grid=grid,
        in_specs=[scalar_spec, scalar_spec,
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(beta, jnp.float32).reshape(1, 1), x)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "target_max", "block", "interpret"))
def truncate_fused_pallas(x: jnp.ndarray, *, fmt: str = "e5m2",
                          target_max: float = TARGET_MAX_LOG2,
                          block=DEFAULT_BLOCK, interpret: bool | None = None):
    """Single-``pallas_call`` fused truncate: in-kernel stats reduction
    (phase 0) + fused apply->RNE->inverse (phase 1).  Two HBM passes over
    the tensor instead of the reference path's ~five.  Returns
    (truncated_f32, alpha, beta).

    The blocked reduction order differs from the monolithic jnp reduction,
    so alpha/beta (and hence the output) match the reference to float
    tolerance, not bit-for-bit — use ``truncate_apply_pallas`` with exact
    stats when bitwise parity matters.
    """
    interpret = _resolve(interpret)
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (2, m // bm, n // bn)
    out, stats = pl.pallas_call(
        functools.partial(_truncate_fused_kernel, fmt=fmt,
                          target_max=target_max),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda p, i, j: (i, j))],
        out_specs=[pl.BlockSpec((bm, bn), lambda p, i, j: (i, j)),
                   pl.BlockSpec((1, 3), lambda p, i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32),
                   jax.ShapeDtypeStruct((1, 3), jnp.float32)],
        interpret=interpret,
    )(x)
    alpha, beta = stats_from_reduction(stats[0, 0], stats[0, 1], stats[0, 2],
                                       target_max)
    return out, alpha, beta
