"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth the kernels/tests compare
against (assert_allclose in tests/test_kernels.py).  No pallas imports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fp8, s2fp8


# --------------------------------------------------------------------------
# s2fp8_quant: stats + forward map + e5m2 cast
# --------------------------------------------------------------------------

def s2fp8_quant_ref(x: jnp.ndarray):
    """Returns (payload_e5m2, alpha, beta) for a 2-D tensor."""
    t = s2fp8.quantize(x)
    return t.payload, t.alpha, t.beta


def s2fp8_dequant_ref(payload, alpha, beta, dtype=jnp.float32):
    return s2fp8.dequantize(s2fp8.S2FP8Tensor(payload, alpha, beta), dtype)


def s2fp8_truncate_ref(x, stats=None, fmt: str = "e5m2"):
    """Eq. 5 round-trip oracle for the fused truncate kernel (any rank)."""
    if fmt == "e4m3":
        return s2fp8.truncate_value_e4m3(x, stats=stats)
    return s2fp8.truncate_value(x, stats=stats)


# --------------------------------------------------------------------------
# s2fp8_matmul: C = dequant(A) @ dequant(B), f32 accumulation
# --------------------------------------------------------------------------

# GEMM operand layouts.  The payload-domain training path (core/qdot.py)
# computes the backward GEMMs dA = g·Bᵀ and dB = Aᵀ·g directly from the
# payloads the forward saved — the layout selects which operand is consumed
# transposed via dot_general dimension numbers (the Pallas kernel swaps
# BlockSpec index maps to match), so no payload transpose is ever
# materialized in HBM.
#
#   "nn": C[M,N] = A[M,K]  @ B[K,N]
#   "nt": C[M,N] = A[M,K]  @ B[N,K]ᵀ      (B stored row-major [N,K])
#   "tn": C[M,N] = A[K,M]ᵀ @ B[K,N]       (A stored row-major [K,M])
GEMM_LAYOUTS = ("nn", "nt", "tn")
GEMM_CONTRACT = {
    "nn": (((1,), (0,)), ((), ())),
    "nt": (((1,), (1,)), ((), ())),
    "tn": (((0,), (0,)), ((), ())),
}


def gemm_dims(layout: str, a_shape, b_shape):
    """(m, k, n) of the logical GEMM for stored operand shapes."""
    if layout == "nn":
        (m, k), (k2, n) = a_shape, b_shape
    elif layout == "nt":
        (m, k), (n, k2) = a_shape, b_shape
    elif layout == "tn":
        (k, m), (k2, n) = a_shape, b_shape
    else:
        raise ValueError(f"unknown GEMM layout {layout!r}; want {GEMM_LAYOUTS}")
    if k != k2:
        raise ValueError(f"contraction mismatch: {a_shape} x {b_shape} "
                         f"under layout {layout!r}")
    return m, k, n


def s2fp8_matmul_ref(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                     out_alpha=None, out_beta=None, *, layout: str = "nn",
                     fmt: str = "e5m2"):
    """Dequant-GEMM oracle with optional fused-epilogue semantics.

    ``out_alpha/out_beta`` — when given, the output is Eq. 5-truncated with
    those stats (the kernel's in-VMEM epilogue, expressed elementwise)."""
    a = s2fp8_dequant_ref(a_payload, a_alpha, a_beta)
    b = s2fp8_dequant_ref(b_payload, b_alpha, b_beta)
    y = jax.lax.dot_general(a, b, GEMM_CONTRACT[layout],
                            preferred_element_type=jnp.float32)
    if out_alpha is not None:
        y = s2fp8_truncate_ref(y, stats=(out_alpha, out_beta), fmt=fmt)
    return y


# Batched variants: one leading batch axis on both operands, per-slice
# contraction per GEMM_CONTRACT, dot_general batch dims (0, 0).
GEMM_CONTRACT_BATCHED = {
    "nn": (((2,), (1,)), ((0,), (0,))),
    "nt": (((2,), (2,)), ((0,), (0,))),
    "tn": (((1,), (1,)), ((0,), (0,))),
}


def _expand_batch(x, g: int):
    """[Gx, ...] -> [G, ...] where slice ``g_i`` is ``x[g_i % Gx]`` — the
    trailing-aligned broadcast of the batched payload GEMM."""
    gx = x.shape[0]
    if gx == g:
        return x
    return jnp.broadcast_to(x[None], (g // gx,) + x.shape
                            ).reshape((g,) + x.shape[1:])


def s2fp8_matmul_batched_ref(a_payload, a_alpha, a_beta,
                             b_payload, b_alpha, b_beta,
                             out_alpha=None, out_beta=None, *,
                             layout: str = "nn", out_batch=None,
                             fmt: str = "e5m2"):
    """Batched dequant-GEMM oracle: ``a [Ga, ., .] x b [Gb, ., .]`` over
    combined batch ``G = max(Ga, Gb)`` (operand slice for step ``g`` is
    ``g % Gx``); ``out_batch < G`` sums groups of ``G // out_batch``
    (``g // out_batch`` constant within a group) — the broadcast-operand
    gradient reduction.  Per-slice layout semantics match
    :func:`s2fp8_matmul_ref`."""
    g = max(a_payload.shape[0], b_payload.shape[0])
    if g % a_payload.shape[0] or g % b_payload.shape[0]:
        raise ValueError(f"batch sizes {a_payload.shape[0]} / "
                         f"{b_payload.shape[0]} do not divide evenly")
    go = g if out_batch is None else out_batch
    if g % go:
        raise ValueError(f"out_batch {go} does not divide batch {g}")
    a = _expand_batch(s2fp8_dequant_ref(a_payload, a_alpha, a_beta), g)
    b = _expand_batch(s2fp8_dequant_ref(b_payload, b_alpha, b_beta), g)
    y = jax.lax.dot_general(a, b, GEMM_CONTRACT_BATCHED[layout],
                            preferred_element_type=jnp.float32)
    if go != g:
        y = y.reshape((g // go, go) + y.shape[1:]).sum(axis=0)
    if out_alpha is not None:
        y = s2fp8_truncate_ref(y, stats=(out_alpha, out_beta), fmt=fmt)
    return y


# --------------------------------------------------------------------------
# selective_scan (Mamba-1 recurrence)
# --------------------------------------------------------------------------

def selective_scan_ref(x, dt, bmat, cmat, a, d_skip):
    """x, dt: [B,S,di]; bmat, cmat: [B,S,n]; a: [di,n]; d_skip: [di].
    Returns (y [B,S,di], h_final [B,di,n]).  Pure lax.scan oracle."""
    b, s, di = x.shape
    n = bmat.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * a)
        h = h * da + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + d_skip * xt
        return h, y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = tuple(jnp.moveaxis(v.astype(jnp.float32), 1, 0)
               for v in (x, dt, bmat, cmat))
    hn, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hn


# --------------------------------------------------------------------------
# flash_attention: causal / full softmax(QK^T/sqrt(d)) V
# --------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: [B,H,Sq,D], k/v: [B,H,Sk,D] (kv heads already broadcast). f32 math."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(jnp.float32)
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (decode-friendly)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
