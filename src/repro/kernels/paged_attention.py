"""Pallas TPU kernel: paged S2FP8 decode attention via block-table gather.

One decode step attends a single query token per slot against that slot's
KV blocks in the shared payload pool (serving/paged_cache.py).  The block
table and per-slot positions are **scalar-prefetched**
(``pltpu.PrefetchScalarGridSpec``) so the pool's BlockSpec index map can
read ``table[slot, j]`` directly: the grid walks each slot's logical
blocks, the DMA engine fetches exactly that slot's payload blocks
HBM->VMEM at 1 byte/element, and the Eq. 4 inverse map (shared
``_dequant``) runs on the VPU right before the MXU contractions.  No dense
fp32 cache — and nothing proportional to the whole pool — is ever
materialized; per (slot, kv-head) the resident set is one (G, hd) query
tile, one (block, hd) K/V payload block pair and the (G, ·) running
max/denominator/accumulator scratch of the online softmax.

Positions mask per-slot: block j covers cache positions [j*block,
(j+1)*block); rows past ``positions[slot]`` — right-padding, not-yet-
written tail, the trash block 0 that dead slots' table rows point at —
are masked to -1e30 before the softmax update.  Position 0 is always
"valid" even for dead slots; the pool's zero-init and the encode clamp
keep every maskable value finite, so dead-slot outputs are finite garbage
the host discards.

TPU-tiling note: payload blocks are (block, hd) fp8 tiles — production
block sizes should respect the fp8 minimum tile (32, 128); the serving
default (16, 64) targets the interpret-mode CI path and small heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import auto_interpret
from repro.kernels.s2fp8_matmul import _dequant

_MASK_VALUE = -1e30


def _scalar(v):
    return jnp.asarray(v, jnp.float32).reshape(1, 1)


def _paged_kernel(table_ref, pos_ref, ka, kb, va, vb,
                  q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, blk):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = _dequant(k_ref[0, 0], ka[0, 0], kb[0, 0])     # (blk, hd)
    v = _dequant(v_ref[0, 0], va[0, 0], vb[0, 0])
    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / jnp.sqrt(jnp.float32(d)))          # (G, blk)

    kpos = j * blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos <= pos_ref[b]
    s = jnp.where(mask, s, _MASK_VALUE)

    m_prev = m_s[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_s[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(j == nb - 1)
    def _fin():
        denom = l_s[:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def paged_decode_attention(q, kp, vp, k_alpha, k_beta, v_alpha, v_beta,
                           table, positions, *, fmt: str = "e5m2",
                           interpret: bool | None = None):
    """q: [B, KV, G, hd] f32; kp/vp: [n_blocks, KV, block, hd] fp8 payload;
    table: [B, max_blocks] int32 (0 = trash); positions: [B] int32 current
    per-slot cache position.  Returns [B, KV, G, hd] f32.

    ``fmt`` documents the payload grid; the dequant map itself is driven by
    the payload dtype.  ``interpret=None`` auto-detects (compiled on TPU).
    """
    del fmt
    interpret = auto_interpret() if interpret is None else interpret
    b, kvh, g, hd = q.shape
    _, kvh2, blk, hd2 = kp.shape
    assert (kvh, hd) == (kvh2, hd2), (q.shape, kp.shape)
    slots, max_b = table.shape
    assert slots == b, (slots, b)

    scalar = pl.BlockSpec((1, 1), lambda bi, h, j, tr, pr: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, max_b),
        in_specs=[
            scalar, scalar, scalar, scalar,
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, h, j, tr, pr: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, blk, hd),
                         lambda bi, h, j, tr, pr: (tr[bi, j], h, 0, 0)),
            pl.BlockSpec((1, 1, blk, hd),
                         lambda bi, h, j, tr, pr: (tr[bi, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, h, j, tr, pr: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, blk=blk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(table, jnp.int32), jnp.asarray(positions, jnp.int32),
      _scalar(k_alpha), _scalar(k_beta), _scalar(v_alpha), _scalar(v_beta),
      q, kp, vp)


def paged_decode_reference(q, kp, vp, k_alpha, k_beta, v_alpha, v_beta,
                           table, positions):
    """Pure-jnp gather + dequant + masked softmax oracle for the kernel."""
    b, kvh, g, hd = q.shape
    blk = kp.shape[2]
    max_b = table.shape[1]
    kg = jnp.moveaxis(kp[table], 1, 2).reshape(b, kvh, max_b * blk, hd)
    vg = jnp.moveaxis(vp[table], 1, 2).reshape(b, kvh, max_b * blk, hd)
    kf = _dequant(kg, k_alpha, k_beta)
    vf = _dequant(vg, v_alpha, v_beta)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32), kf)
    s = s / jnp.sqrt(jnp.float32(hd))
    kpos = jnp.arange(max_b * blk)
    mask = kpos[None, :] <= positions[:, None]        # [B, S]
    s = jnp.where(mask[:, None, None, :], s, _MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p, vf)
