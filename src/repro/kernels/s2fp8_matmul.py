"""Pallas TPU kernel: S2FP8 GEMM with in-tile dequantization, f32 accumulation,
transposed operand layouts, and a fused output-truncation epilogue.

This is the paper's "tensor processing engine which requires the alpha and
beta factors while doing the calculations" (§5), adapted to the TPU memory
hierarchy: FP8 payload tiles stream HBM->VMEM at 1 byte/element (the
bandwidth win), the inverse shift/squeeze map runs on the VPU per tile, and
the dequantized f32 tiles feed the MXU with f32 accumulation (the paper's
FP32-accumulate requirement, native on TPU).

Three additions make the kernel the *training* GEMM (core/qdot.py):

  * ``layout`` in {"nn", "nt", "tn"} — the backward GEMMs dA = g·Bᵀ and
    dB = Aᵀ·g consume the forward's saved payloads transposed.  A layout is
    purely a BlockSpec index-map swap plus matching dot_general dimension
    numbers inside the tile; no payload transpose ever touches HBM.
  * ``out_alpha/out_beta`` — a fused Eq. 5 epilogue: on the last K step the
    accumulated f32 output tile is truncated in VMEM with the output site's
    (alpha, beta) (forward map -> clamp at format max -> FP8 RNE -> inverse
    map, shared ``_truncate_body``), so Fig. 4's separate output-truncation
    pass disappears.  The clamp turns stale-bank-stats overflow into
    saturation, never inf.
  * a (M, K, N, platform)-keyed block heuristic (``pick_gemm_block``) with a
    ``REPRO_GEMM_BLOCK=bm,bk,bn`` env override, replacing the fixed
    (256, 256, 256) tiles — see kernels/README.md for the sweep.

Grid is (M/bm, N/bn, K/bk) with K innermost; the output tile lives in VMEM
across the K loop (constant index_map) and acts as the accumulator.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import auto_interpret
from repro.kernels.ref import GEMM_CONTRACT, GEMM_LAYOUTS, gemm_dims
from repro.kernels.s2fp8_quant import _truncate_body


def _dequant(y, alpha, beta):
    y = y.astype(jnp.float32)
    absy = jnp.abs(y)
    nz = absy > 0.0
    xlog = (jnp.log2(jnp.where(nz, absy, 1.0)) - beta) / alpha
    return jnp.where(nz, jnp.sign(y) * jnp.exp2(xlog), 0.0)


def _matmul_kernel(aa_ref, ab_ref, ba_ref, bb_ref, oa_ref, ob_ref,
                   a_ref, b_ref, o_ref, *, layout, epilogue, fmt):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _dequant(a_ref[...], aa_ref[0, 0], ab_ref[0, 0])
    b = _dequant(b_ref[...], ba_ref[0, 0], bb_ref[0, 0])
    o_ref[...] += jax.lax.dot_general(a, b, GEMM_CONTRACT[layout],
                                      preferred_element_type=jnp.float32)
    if epilogue:
        @pl.when(k == pl.num_programs(2) - 1)
        def _epilogue():
            # Eq. 5 on the finished accumulator tile, in VMEM: the output
            # never crosses HBM untruncated.
            o_ref[...] = _truncate_body(o_ref[...], oa_ref[0, 0],
                                        ob_ref[0, 0], fmt)


def _operand_specs(layout, bm, bk, bn):
    """BlockSpecs realizing the layout as pure index-map swaps."""
    if layout == "nn":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    elif layout == "nt":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk))
    else:  # tn
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    return a_spec, b_spec


# ---------------------------------------------------------------------------
# batched variant: third (leading) data axis, broadcast/reduce via index maps
# ---------------------------------------------------------------------------

def _batched_matmul_kernel(aa_ref, ab_ref, ba_ref, bb_ref, oa_ref, ob_ref,
                           a_ref, b_ref, o_ref, *, layout, epilogue, fmt):
    gr = pl.program_id(3)
    k = pl.program_id(4)

    @pl.when(jnp.logical_and(gr == 0, k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _dequant(a_ref[...][0], aa_ref[0, 0], ab_ref[0, 0])
    b = _dequant(b_ref[...][0], ba_ref[0, 0], bb_ref[0, 0])
    o_ref[...] += jax.lax.dot_general(a, b, GEMM_CONTRACT[layout],
                                      preferred_element_type=jnp.float32
                                      )[None]
    if epilogue:
        @pl.when(jnp.logical_and(gr == pl.num_programs(3) - 1,
                                 k == pl.num_programs(4) - 1))
        def _epilogue():
            o_ref[...] = _truncate_body(o_ref[...], oa_ref[0, 0],
                                        ob_ref[0, 0], fmt)


def _batched_operand_specs(layout, bm, bk, bn, go, ga, gb):
    """Batched BlockSpecs: the per-slice index maps of ``_operand_specs``
    plus a leading batch coordinate.  Grid axes are (g_out, i, j, g_red,
    kk); the combined batch step is ``g = g_red * go + g_out`` and each
    operand contributes its slice ``g % Gx`` (``Gx < G``: the
    trailing-aligned broadcast; block batch size is 1, so block index ==
    slice index)."""
    def amap(two_d):
        return lambda g, i, j, gr, kk: ((gr * go + g) % ga,) + two_d(i, kk)

    def bmap(two_d):
        return lambda g, i, j, gr, kk: ((gr * go + g) % gb,) + two_d(kk, j)

    if layout == "nn":
        a_spec = pl.BlockSpec((1, bm, bk), amap(lambda i, kk: (i, kk)))
        b_spec = pl.BlockSpec((1, bk, bn), bmap(lambda kk, j: (kk, j)))
    elif layout == "nt":
        a_spec = pl.BlockSpec((1, bm, bk), amap(lambda i, kk: (i, kk)))
        b_spec = pl.BlockSpec((1, bn, bk), bmap(lambda kk, j: (j, kk)))
    else:  # tn
        a_spec = pl.BlockSpec((1, bk, bm), amap(lambda i, kk: (kk, i)))
        b_spec = pl.BlockSpec((1, bk, bn), bmap(lambda kk, j: (kk, j)))
    return a_spec, b_spec


# ---------------------------------------------------------------------------
# block-size heuristic
# ---------------------------------------------------------------------------

# (platform, size-class) -> (bm, bk, bn).  Chosen by the sweep recorded in
# kernels/README.md ("GEMM block heuristic"); VMEM budget per entry =
# fp8 operand tiles (bm*bk + bk*bn bytes) + their f32 dequant images (x4)
# + the f32 accumulator (bm*bn*4), double-buffered on the operand side.
#   tpu/small : K often fits one step; modest tiles keep the grid >= core
#               count for pipelining.
#   tpu/large : widen K to 512 (1-byte payload tiles make deep-K cheap:
#               512*256 fp8 = 128 KiB/operand tile) to cut accumulator
#               revisits; ~3.5 MiB resident, safe with double buffering.
#   interpret : grid iterations are Python-speed, so prefer the fewest,
#               fattest tiles that divide the padded problem.
_BLOCK_TABLE = {
    ("tpu", "s"): (128, 256, 128),
    ("tpu", "m"): (256, 256, 256),
    ("tpu", "l"): (256, 512, 256),
    ("interpret", "s"): (256, 256, 256),
    ("interpret", "m"): (256, 512, 256),
    ("interpret", "l"): (512, 512, 512),
}


def pick_gemm_block(m: int, k: int, n: int, platform: str | None = None):
    """(bm, bk, bn) for a logical (M, K, N) GEMM on ``platform``.

    ``REPRO_GEMM_BLOCK=bm,bk,bn`` overrides the table globally (perf
    triage / sweeps without a code edit)."""
    env = os.environ.get("REPRO_GEMM_BLOCK")
    if env:
        try:
            bm, bk, bn = (int(v) for v in env.split(","))
        except ValueError:
            raise ValueError(
                f"REPRO_GEMM_BLOCK must be 'bm,bk,bn' ints, got {env!r}")
        return bm, bk, bn
    if platform is None:
        platform = "tpu" if jax.default_backend() == "tpu" else "interpret"
    size = max(m, k, n)
    cls = "s" if size <= 512 else ("m" if size <= 2048 else "l")
    return _BLOCK_TABLE[(platform, cls)]


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("layout", "fmt", "bm", "bk",
                                             "bn", "interpret"))
def s2fp8_matmul_pallas(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                        out_alpha=None, out_beta=None, *, layout: str = "nn",
                        fmt: str = "e5m2", bm=256, bk=256, bn=256,
                        interpret: bool | None = None):
    """C[M,N] = dequant(A) x dequant(B) under ``layout``; payloads are FP8.

    ``out_alpha/out_beta`` enable the fused Eq. 5 output-truncation
    epilogue (stats of the OUTPUT site; ``fmt`` is the epilogue's payload
    format).  ``interpret=None`` auto-detects (compiled on TPU, interpreter
    off-TPU).  Shapes must be block-divisible; ragged shapes are
    zero-padded one layer up in ``repro.kernels.dispatch.qmatmul_nd``.
    """
    interpret = auto_interpret() if interpret is None else interpret
    m, k, n = gemm_dims(layout, a_payload.shape, b_payload.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    epilogue = out_alpha is not None
    oa = out_alpha if epilogue else 1.0
    ob = out_beta if epilogue else 0.0
    scalar = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    a_spec, b_spec = _operand_specs(layout, bm, bk, bn)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, layout=layout, epilogue=epilogue,
                          fmt=fmt),
        grid=grid,
        in_specs=[scalar] * 6 + [a_spec, b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(a_alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(a_beta, jnp.float32).reshape(1, 1),
      jnp.asarray(b_alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(b_beta, jnp.float32).reshape(1, 1),
      jnp.asarray(oa, jnp.float32).reshape(1, 1),
      jnp.asarray(ob, jnp.float32).reshape(1, 1),
      a_payload, b_payload)


@functools.partial(jax.jit, static_argnames=("layout", "out_batch", "fmt",
                                             "bm", "bk", "bn", "interpret"))
def s2fp8_matmul_batched_pallas(a_payload, a_alpha, a_beta,
                                b_payload, b_alpha, b_beta,
                                out_alpha=None, out_beta=None, *,
                                layout: str = "nn", out_batch=None,
                                fmt: str = "e5m2", bm=256, bk=256, bn=256,
                                interpret: bool | None = None):
    """Batched payload GEMM: ``C[Go,M,N]`` from ``A[Ga,.,.] x B[Gb,.,.]``.

    The combined batch is ``G = max(Ga, Gb)``; an operand's slice for
    combined step ``g`` is ``g % Gx`` (trailing-aligned broadcast — the
    ``becd,edf`` weight reuse), and ``out_batch < G`` accumulates the
    ``G // out_batch`` broadcast groups into one output slice (the
    broadcast operand's gradient).  Grid is (g_out, M/bm, N/bn, g_red,
    K/bk) with the two reduction axes innermost, so each output tile
    stays resident in VMEM across its whole reduction (revisit
    accumulation) and the Eq. 5 epilogue still runs on the finished tile
    before it ever crosses HBM.  Per-slice layout/epilogue semantics
    match :func:`s2fp8_matmul_pallas`; trailing dims must be
    block-divisible (padded one layer up in ``dispatch``)."""
    interpret = auto_interpret() if interpret is None else interpret
    ga, gb = a_payload.shape[0], b_payload.shape[0]
    g = max(ga, gb)
    assert g % ga == 0 and g % gb == 0, (ga, gb)
    go = g if out_batch is None else out_batch
    assert g % go == 0, (g, go)
    m, k, n = gemm_dims(layout, a_payload.shape[1:], b_payload.shape[1:])
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (go, m // bm, n // bn, g // go, k // bk)
    epilogue = out_alpha is not None
    oa = out_alpha if epilogue else 1.0
    ob = out_beta if epilogue else 0.0
    scalar = pl.BlockSpec((1, 1), lambda gi, i, j, gr, kk: (0, 0))
    a_spec, b_spec = _batched_operand_specs(layout, bm, bk, bn, go, ga, gb)
    return pl.pallas_call(
        functools.partial(_batched_matmul_kernel, layout=layout,
                          epilogue=epilogue, fmt=fmt),
        grid=grid,
        in_specs=[scalar] * 6 + [a_spec, b_spec],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, gr, kk: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((go, m, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(a_alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(a_beta, jnp.float32).reshape(1, 1),
      jnp.asarray(b_alpha, jnp.float32).reshape(1, 1),
      jnp.asarray(b_beta, jnp.float32).reshape(1, 1),
      jnp.asarray(oa, jnp.float32).reshape(1, 1),
      jnp.asarray(ob, jnp.float32).reshape(1, 1),
      a_payload, b_payload)
