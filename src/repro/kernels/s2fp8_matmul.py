"""Pallas TPU kernel: S2FP8 GEMM with in-tile dequantization, f32 accumulation.

This is the paper's "tensor processing engine which requires the alpha and
beta factors while doing the calculations" (§5), adapted to the TPU memory
hierarchy: FP8 payload tiles stream HBM->VMEM at 1 byte/element (the
bandwidth win), the inverse shift/squeeze map runs on the VPU per tile, and
the dequantized f32 tiles feed the MXU with f32 accumulation (the paper's
FP32-accumulate requirement, native on TPU).

Grid is (M/bm, N/bn, K/bk) with K innermost; the output tile lives in VMEM
across the K loop (constant index_map) and acts as the accumulator.
Default tiles (bm, bk, bn) = (256, 256, 256): VMEM use =
2 * 256*256 B (fp8 operands) + 2 * 256*256*4 B (dequantized) + 256*256*4 B
(acc) ~= 0.9 MiB, MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import auto_interpret


def _dequant(y, alpha, beta):
    y = y.astype(jnp.float32)
    absy = jnp.abs(y)
    nz = absy > 0.0
    xlog = (jnp.log2(jnp.where(nz, absy, 1.0)) - beta) / alpha
    return jnp.where(nz, jnp.sign(y) * jnp.exp2(xlog), 0.0)


def _matmul_kernel(aa_ref, ab_ref, ba_ref, bb_ref, a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _dequant(a_ref[...], aa_ref[0, 0], ab_ref[0, 0])
    b = _dequant(b_ref[...], ba_ref[0, 0], bb_ref[0, 0])
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def s2fp8_matmul_pallas(a_payload, a_alpha, a_beta, b_payload, b_alpha, b_beta,
                        *, bm=256, bk=256, bn=256, interpret: bool | None = None):
    """C[M,N] = dequant(A[M,K]) @ dequant(B[K,N]); payloads are e5m2.

    ``interpret=None`` auto-detects (compiled on TPU, interpreter off-TPU).
    Shapes must be block-divisible; ragged shapes are zero-padded one layer
    up in ``repro.kernels.dispatch.qmatmul_nd``.
    """
    interpret = auto_interpret() if interpret is None else interpret
    m, k = a_payload.shape
    k2, n = b_payload.shape
    assert k == k2, (a_payload.shape, b_payload.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    scalar = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            scalar, scalar, scalar, scalar,
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a_alpha.reshape(1, 1), a_beta.reshape(1, 1),
      b_alpha.reshape(1, 1), b_beta.reshape(1, 1),
      a_payload, b_payload)
