"""Pallas TPU flash attention (online softmax), causal / sliding-window.

Target kernel for the prefill hot-spot.  Memory-hierarchy reasoning:
Q/K/V tiles stream HBM->VMEM; the (bq, bk) score tile lives only in
registers/VMEM (never HBM — this is the flash insight, reexpressed for TPU);
running max / denominator / output accumulator live in VMEM scratch across
the sequential kv-grid.  Default tiles (bq, bk) = (512, 512) with d<=256:
~ (2*512*d*4 + 512*512*4 + 512*d*4) bytes ~= 2.6 MiB for d=128 — fits VMEM
with double buffering.  MXU dims are multiples of 128.

The masked logit fill is -1e30 (finite) instead of -inf so the online
rescaling never produces NaN; fully-masked tiles are additionally zeroed
via the mask on the probability tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_MASK_VALUE = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
                  *, sq, sk, bq, bk, causal, window):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, d)
    d = q.shape[-1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / jnp.sqrt(jnp.float32(d)))      # (bq, bk)

    # Position mask. Query rows are aligned to the END of the kv axis so the
    # same kernel serves self-attention (sq == sk) and chunked decode.
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, _MASK_VALUE)

    m_prev = m_s[:, :1]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (bq, bk)
    l_new = l_s[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(ik == nk - 1)
    def _fin():
        denom = l_s[:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           bq=512, bk=512, interpret: bool = True):
    """q: [B,H,Sq,D]; k,v: [B,H,Sk,D] (kv heads pre-broadcast). Returns like q."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * h, sk, d)
    vr = v.reshape(b * h, sk, d)
    grid = (b * h, sq // bq, sk // bk)
    kernel = functools.partial(_flash_kernel, sq=sq, sk=sk, bq=bq, bk=bk,
                               causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
