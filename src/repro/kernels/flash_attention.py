"""Pallas TPU flash attention (online softmax), causal / sliding-window.

Target kernel for the prefill hot-spot.  Memory-hierarchy reasoning:
Q/K/V tiles stream HBM->VMEM; the (bq, bk) score tile lives only in
registers/VMEM (never HBM — this is the flash insight, reexpressed for TPU);
running max / denominator / output accumulator live in VMEM scratch across
the sequential kv-grid.  Default tiles (bq, bk) = (512, 512) with d<=256:
~ (2*512*d*4 + 512*512*4 + 512*d*4) bytes ~= 2.6 MiB for d=128 — fits VMEM
with double buffering.  MXU dims are multiples of 128.

The masked logit fill is -1e30 (finite) instead of -inf so the online
rescaling never produces NaN; fully-masked tiles are additionally zeroed
via the mask on the probability tile.

The second half of this module is the *payload-domain* variant (ISSUE 6):
Q/K/V arrive as 1-byte S2FP8 payloads with per-site bank (alpha, beta)
scalars, are dequantized in-tile on the VPU right before the MXU issue,
and the output tile gets the fused Eq. 5 truncation epilogue
(s2fp8_matmul.py idiom) before it ever leaves VMEM.  The backward is the
recompute schedule of models/flash.py split into two kernels (dq, and
per-head dk/dv) so no output block is revisited after its flush.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import pad_to_lane
from repro.kernels.s2fp8_matmul import _dequant
from repro.kernels.s2fp8_quant import _truncate_body

_MASK_VALUE = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
                  *, sq, sk, bq, bk, causal, window):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, d)
    d = q.shape[-1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / jnp.sqrt(jnp.float32(d)))      # (bq, bk)

    # Position mask. Query rows are aligned to the END of the kv axis so the
    # same kernel serves self-attention (sq == sk) and chunked decode.
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, _MASK_VALUE)

    m_prev = m_s[:, :1]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (bq, bk)
    l_new = l_s[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(ik == nk - 1)
    def _fin():
        denom = l_s[:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           bq=512, bk=512, interpret: bool = True):
    """q: [B,H,Sq,D]; k,v: [B,H,Sk,D] (kv heads pre-broadcast). Returns like q."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * h, sk, d)
    vr = v.reshape(b * h, sk, d)
    grid = (b * h, sq // bq, sk // bk)
    kernel = functools.partial(_flash_kernel, sq=sq, sk=sk, bq=bq, bk=bk,
                               causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)


# ===========================================================================
# Payload-domain flash attention (ISSUE 6)
# ===========================================================================
#
# Tile lifecycle (forward): per (head, iq) output block, the sequential
# inner kv-grid streams one (bq, d) Q payload tile and (bk, d) K/V payload
# tiles HBM->VMEM at 1 byte/element, dequantizes them on the VPU with the
# site's (alpha, beta), issues QK^T on the MXU, and keeps the (bq, bk)
# score/prob tile plus the running (max, denom, acc) entirely in
# VMEM scratch.  At the last kv step the accumulator is normalized, the
# rowwise logsumexp is emitted (the only O(S) residual), and — when the
# output site's stats are fused — the tile is truncated in-register via
# Eq. 5 before the single HBM writeback.  Nothing O(S^2) ever touches HBM.


def _attn_mask(iq, ik, bq, bk, sq, sk, causal, window):
    """(bq, bk) position mask; query rows END-aligned to the kv axis."""
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    return mask


def _qflash_fwd_kernel(qa, qb, ka, kb, va, vb, oa, ob,
                       q_ref, k_ref, v_ref, o_ref, lse_ref,
                       m_s, l_s, acc_s,
                       *, sq, sk, bq, bk, causal, window, scale, fmt,
                       epilogue):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # 1-byte HBM->VMEM stream; Eq. 4 inverse map on the VPU, straight into
    # the MXU contraction.
    q = _dequant(q_ref[0], qa[0, 0], qb[0, 0])     # (bq, d) f32
    k = _dequant(k_ref[0], ka[0, 0], kb[0, 0])     # (bk, d) f32
    v = _dequant(v_ref[0], va[0, 0], vb[0, 0])     # (bk, d) f32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _attn_mask(iq, ik, bq, bk, sq, sk, causal, window)
    s = jnp.where(mask, s, _MASK_VALUE)

    m_prev = m_s[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_s[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
    l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(ik == nk - 1)
    def _fin():
        l_fin = l_s[:, :1]
        denom = jnp.where(l_fin == 0.0, 1.0, l_fin)
        acc = acc_s[...] / denom
        lse_ref[0] = m_s[:, 0] + jnp.log(jnp.maximum(l_s[:, 0], 1e-30))
        if epilogue:
            # fused Eq. 5 epilogue: the output tile leaves VMEM already in
            # the out site's representable set (s2fp8_matmul.py idiom)
            acc = _truncate_body(acc, oa[0, 0], ob[0, 0], fmt)
        o_ref[0] = acc


def _qflash_dq_kernel(qa, qb, ka, kb, va, vb, ga, gb,
                      q_ref, k_ref, v_ref, g_ref, lse_ref, del_ref,
                      dq_ref, acc_s,
                      *, sq, sk, bq, bk, causal, window, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)

    q = _dequant(q_ref[0], qa[0, 0], qb[0, 0])
    k = _dequant(k_ref[0], ka[0, 0], kb[0, 0])
    v = _dequant(v_ref[0], va[0, 0], vb[0, 0])
    do = _dequant(g_ref[0], ga[0, 0], gb[0, 0])
    lse = lse_ref[0]                               # (bq,)
    dlt = del_ref[0]                               # (bq,)

    # score-tile recompute from the 1-byte payloads (no saved probs)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _attn_mask(iq, ik, bq, bk, sq, sk, causal, window)
    s = jnp.where(mask, s, _MASK_VALUE)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)

    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dlt[:, None]) * scale
    acc_s[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        dq_ref[0] = acc_s[...]


def _qflash_dkdv_kernel(qa, qb, ka, kb, va, vb, ga, gb,
                        q_ref, k_ref, v_ref, g_ref, lse_ref, del_ref,
                        dk_ref, dv_ref, dk_s, dv_s,
                        *, sq, sk, bq, bk, causal, window, scale):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    q = _dequant(q_ref[0], qa[0, 0], qb[0, 0])
    k = _dequant(k_ref[0], ka[0, 0], kb[0, 0])
    v = _dequant(v_ref[0], va[0, 0], vb[0, 0])
    do = _dequant(g_ref[0], ga[0, 0], gb[0, 0])
    lse = lse_ref[0]
    dlt = del_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _attn_mask(iq, ik, bq, bk, sq, sk, causal, window)
    s = jnp.where(mask, s, _MASK_VALUE)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)

    dv_s[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dlt[:, None]) * scale
    dk_s[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _fin():
        dk_ref[0] = dk_s[...]
        dv_ref[0] = dv_s[...]


def _scalar(v):
    return jnp.asarray(v, jnp.float32).reshape(1, 1)


def _chunk(block, s):
    """Largest block <= `block` that divides the sequence length."""
    return math.gcd(min(block, s), s)


def qflash_fwd_pallas(qp, kp, vp, q_stats, k_stats, v_stats, *, g,
                      causal=True, window=None, scale=None, out_stats=None,
                      fmt="e5m2", bq=512, bk=512, interpret=None):
    """Payload-domain flash forward.

    qp: [BH, Sq, d] FP8 payload with BH = B*KV*G; kp/vp: [BKV, Sk, d]
    payloads.  Grouped-query K/V blocks are re-read per query group via the
    `bh // g` index map — never materialized per head.  ``*_stats`` are
    the bank (alpha, beta) scalar pairs.  Ragged head dims are zero-padded
    to the 128-lane grid (exact for S2FP8); ``scale`` is the caller's true
    1/sqrt(d).  Returns (out f32 [BH, Sq, d], lse f32 [BH, Sq]); when
    ``out_stats`` is given the output tile gets the fused Eq. 5 truncation
    epilogue before leaving VMEM.
    """
    if interpret is None:
        from repro.kernels import auto_interpret
        interpret = auto_interpret()
    bh, sq, d0 = qp.shape
    bkv, sk, _ = kp.shape
    assert bh == bkv * g, (qp.shape, kp.shape, g)
    if scale is None:
        scale = 1.0 / math.sqrt(d0)
    qp, kp, vp = pad_to_lane(qp), pad_to_lane(kp), pad_to_lane(vp)
    d = qp.shape[-1]
    bq = _chunk(bq, sq)
    bk = _chunk(bk, sk)
    epilogue = out_stats is not None
    oa, ob = out_stats if epilogue else (1.0, 0.0)
    kernel = functools.partial(
        _qflash_fwd_kernel, sq=sq, sk=sk, bq=bq, bk=bk, causal=causal,
        window=window, scale=float(scale), fmt=fmt, epilogue=epilogue)
    scal = pl.BlockSpec((1, 1), lambda h, iq, ik: (0, 0))
    out, lse = pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, sk // bk),
        in_specs=[scal] * 8 + [
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda h, iq, ik: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda h, iq, ik: (h // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(_scalar(q_stats[0]), _scalar(q_stats[1]),
      _scalar(k_stats[0]), _scalar(k_stats[1]),
      _scalar(v_stats[0]), _scalar(v_stats[1]),
      _scalar(oa), _scalar(ob), qp, kp, vp)
    return out[..., :d0], lse


def qflash_bwd_pallas(qp, kp, vp, gp, q_stats, k_stats, v_stats, g_stats,
                      lse, delta, *, g, causal=True, window=None, scale=None,
                      bq=512, bk=512, interpret=None):
    """Recompute-based payload flash backward (two kernels).

    Residual inputs are the 1-byte Q/K/V payloads plus the quantized
    output cotangent ``gp`` [BH, Sq, d] and the rowwise ``lse``/``delta``
    [BH, Sq] f32 vectors; score tiles are recomputed per (bq, bk) block.
    The dq kernel accumulates over the sequential kv grid; the dk/dv
    kernel accumulates over the sequential q grid and emits PER-HEAD
    [BH, Sk, d] gradients (each output block written exactly once — the
    TPU revisit constraint); the caller reduces the query-group axis.
    Returns raw f32 (dq, dk_per_head, dv_per_head).
    """
    if interpret is None:
        from repro.kernels import auto_interpret
        interpret = auto_interpret()
    bh, sq, d0 = qp.shape
    bkv, sk, _ = kp.shape
    assert bh == bkv * g and gp.shape == qp.shape, (qp.shape, kp.shape, g)
    if scale is None:
        scale = 1.0 / math.sqrt(d0)
    qp, kp, vp, gp = (pad_to_lane(t) for t in (qp, kp, vp, gp))
    d = qp.shape[-1]
    bq = _chunk(bq, sq)
    bk = _chunk(bk, sk)
    common = dict(sq=sq, sk=sk, bq=bq, bk=bk, causal=causal, window=window,
                  scale=float(scale))
    scalars = (_scalar(q_stats[0]), _scalar(q_stats[1]),
               _scalar(k_stats[0]), _scalar(k_stats[1]),
               _scalar(v_stats[0]), _scalar(v_stats[1]),
               _scalar(g_stats[0]), _scalar(g_stats[1]))

    scal_q = pl.BlockSpec((1, 1), lambda h, iq, ik: (0, 0))
    dq = pl.pallas_call(
        functools.partial(_qflash_dq_kernel, **common),
        grid=(bh, sq // bq, sk // bk),
        in_specs=[scal_q] * 8 + [
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda h, iq, ik: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda h, iq, ik: (h // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(*scalars, qp, kp, vp, gp, lse, delta)

    scal_k = pl.BlockSpec((1, 1), lambda h, ik, iq: (0, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_qflash_dkdv_kernel, **common),
        grid=(bh, sk // bk, sq // bq),
        in_specs=[scal_k] * 8 + [
            pl.BlockSpec((1, bq, d), lambda h, ik, iq: (h, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda h, ik, iq: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda h, ik, iq: (h // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda h, ik, iq: (h, iq, 0)),
            pl.BlockSpec((1, bq), lambda h, ik, iq: (h, iq)),
            pl.BlockSpec((1, bq), lambda h, ik, iq: (h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda h, ik, iq: (h, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda h, ik, iq: (h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sk, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(*scalars, qp, kp, vp, gp, lse, delta)
    return dq[..., :d0], dk[..., :d0], dv[..., :d0]


# ---------------------------------------------------------------------------
# pure-jnp grouped flash references (CPU / ref-backend path)
# ---------------------------------------------------------------------------
# Op-for-op ports of models/flash.py's forward/backward schedule, kept in
# lockstep on purpose: tests pin the payload node's VJP against it, and the
# zero-reduction jaxpr assertion counts on the backward containing no
# reduce primitives besides the delta identity (computed by the caller).
# Inputs here are DEQUANTIZED payloads, so with shared site stats these
# equal the Fig. 4 truncate->flash->truncate chain on f32 tensors.


def _chunk_mask(iq, ik, q_chunk, kv_chunk, sq, sk, causal, window):
    qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None] + (sk - sq)
    kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


def flash_fwd_reference(q, k, v, *, causal=True, window=None,
                        q_chunk=512, kv_chunk=512):
    """Grouped flash forward, f32 in/out; returns (out, lse [B,KV,G,Sq,1])."""
    b, kvh, g, sq, d = q.shape
    sk = k.shape[2]
    q_chunk = _chunk(q_chunk, sq)
    kv_chunk = _chunk(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)
    kc = k.reshape(b, kvh, nk, kv_chunk, d)
    vc = v.reshape(b, kvh, nk, kv_chunk, d)
    qc = q.reshape(b, kvh, g, nq, q_chunk, d)

    def q_step(iq):
        qi = jax.lax.dynamic_index_in_dim(qc, iq, 3, keepdims=False) \
            .astype(jnp.float32)

        def kv_step(carry, ik):
            m, l, acc = carry
            ki = jax.lax.dynamic_index_in_dim(kc, ik, 2, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(vc, ik, 2, keepdims=False)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi,
                           ki.astype(jnp.float32)) * scale
            mask = _chunk_mask(iq, ik, q_chunk, kv_chunk, sq, sk, causal,
                               window)
            s = jnp.where(mask[None, None, None], s, _MASK_VALUE)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum("bkgqs,bksd->bkgqd", p,
                                              vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk, 1), _MASK_VALUE, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        l = jnp.where(l == 0.0, 1.0, l)
        return acc / l, lse

    outs = jax.lax.map(q_step, jnp.arange(nq))
    out = jnp.moveaxis(outs[0], 0, 3).reshape(b, kvh, g, sq, d)
    lse = jnp.moveaxis(outs[1], 0, 3).reshape(b, kvh, g, sq, 1)
    return out, lse


def flash_bwd_reference(q, k, v, dout, lse, delta, *, causal=True,
                        window=None, q_chunk=512, kv_chunk=512):
    """Grouped flash backward over precomputed (lse, delta); f32 in/out.

    Contains NO reduce primitives — every contraction is a dot_general and
    delta (the flash-2 rowwise identity) is supplied by the caller.
    """
    b, kvh, g, sq, d = q.shape
    sk = k.shape[2]
    q_chunk = _chunk(q_chunk, sq)
    kv_chunk = _chunk(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)

    kc = k.reshape(b, kvh, nk, kv_chunk, d)
    vc = v.reshape(b, kvh, nk, kv_chunk, d)
    qc = q.reshape(b, kvh, g, nq, q_chunk, d)
    dc = dout.astype(jnp.float32).reshape(b, kvh, g, nq, q_chunk, d)
    lc = lse.reshape(b, kvh, g, nq, q_chunk, 1)
    dl = delta.reshape(b, kvh, g, nq, q_chunk, 1)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry
        qi = jax.lax.dynamic_index_in_dim(qc, iq, 3, keepdims=False) \
            .astype(jnp.float32)
        di = jax.lax.dynamic_index_in_dim(dc, iq, 3, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(lc, iq, 3, keepdims=False)
        deli = jax.lax.dynamic_index_in_dim(dl, iq, 3, keepdims=False)

        def kv_step(inner, ik):
            dq_acc, dk_a, dv_a = inner
            ki = jax.lax.dynamic_index_in_dim(kc, ik, 2, keepdims=False) \
                .astype(jnp.float32)
            vi = jax.lax.dynamic_index_in_dim(vc, ik, 2, keepdims=False) \
                .astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, ki) * scale
            mask = _chunk_mask(iq, ik, q_chunk, kv_chunk, sq, sk, causal,
                               window)
            s = jnp.where(mask[None, None, None], s, _MASK_VALUE)
            p = jnp.exp(s - li)
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv_blk = jnp.einsum("bkgqs,bkgqd->bksd", p, di)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", di, vi)
            ds = p * (dp - deli) * scale
            dq_blk = jnp.einsum("bkgqs,bksd->bkgqd", ds, ki)
            dk_blk = jnp.einsum("bkgqs,bkgqd->bksd", ds, qi)
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, jax.lax.dynamic_index_in_dim(dk_a, ik, 2,
                                                   keepdims=False)
                + dk_blk, ik, 2)
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, jax.lax.dynamic_index_in_dim(dv_a, ik, 2,
                                                   keepdims=False)
                + dv_blk, ik, 2)
            return (dq_acc + dq_blk, dk_a, dv_a), None

        dq0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (dqi, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dqi

    dk0 = jnp.zeros((b, kvh, nk, kv_chunk, d), jnp.float32)
    dv0 = jnp.zeros((b, kvh, nk, kv_chunk, d), jnp.float32)
    (dkc, dvc), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, kvh, g, sq, d)
    dk = dkc.reshape(b, kvh, sk, d)
    dv = dvc.reshape(b, kvh, sk, d)
    return dq, dk, dv
