"""Export a frozen serving StatsBank from trained params.

Serving never updates stats: every (alpha, beta) a request sees was fixed
at export time.  :func:`export_serving_bank` replays the doctor's probe
machinery (obs/doctor.py) against the *serving* computation graphs —
prefill and decode, not the train step — so the bank holds exactly the
sites those graphs mint (including the ``kv_cache`` truncation sites whose
fwd moments become the paged pool's per-layer (alpha, beta)), warmed on
representative traffic.  The engine then runs both graphs under
``statsbank.freeze(bank, ...)``: entries fold into the jitted programs as
constants and the decode steady state performs **zero** stats reductions
(asserted on the jaxpr in tests/test_serving.py).

Discovery quirks worth knowing:
  * prefill and decode mint overlapping-but-different site sets (decode
    attention runs through einsum sites, prefill through the flash site),
    so each graph gets its own ``init_bank`` trace and the dicts merge.
  * the probe losses add a vanishing ``1e-30 * sum(cache**2)`` term: the
    kv-cache truncations only feed the *cache* outputs, and their refreshed
    states ride the custom_vjp cotangent — a logits-only loss would let
    the transpose drop them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import statsbank
from repro.core.policy import Policy
from repro.models import transformer as tlm


def _cache_term(caches) -> jnp.ndarray:
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(caches):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            total = total + jnp.sum(leaf.astype(jnp.float32) ** 2)
    return total


def export_serving_bank(params, cfg: ArchConfig, policy: Policy, *,
                        prompt_len: int = 16, batch: int = 2,
                        passes: int = 2, seed: int = 0,
                        train_bank: Optional[Dict[str, Any]] = None,
                        stats_cfg: Optional[statsbank.StatsConfig] = None,
                        ) -> Dict[str, Any]:
    """Build and warm the frozen serving bank for ``(params, cfg, policy)``.

    Probes ``passes`` alternating prefill/decode refreshes on synthetic
    prompts of ``prompt_len`` tokens (stats are scale statistics of the
    *weights and activations*; random-token traffic is the standard
    export-calibration stand-in).  ``train_bank`` optionally seeds entries
    shared with the training graph (e.g. mlp/attn qdot sites) before the
    probe; serving-only sites (kv_cache, decode einsum) are still warmed
    here.  Returns the bank dict to pass to the engine and persist next to
    the checkpoint.
    """
    if cfg.enc_dec:
        raise ValueError("export_serving_bank covers decoder-only LMs")
    base = stats_cfg or statsbank.StatsConfig()
    probe_cfg = dataclasses.replace(base, refresh_every=1, ema_decay=0.5)
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                jnp.int32)

    def prefill_loss(p, b, pol):
        logits, new_caches = tlm.prefill(p, b["tokens"], cfg, pol,
                                         b["caches"])
        loss = jnp.mean(logits.astype(jnp.float32) ** 2)
        return loss + 1e-30 * _cache_term(new_caches), {}

    def decode_loss(p, b, pol):
        logits, new_caches = tlm.decode_step(p, b["token"], cfg, pol,
                                             b["caches"], b["pos"])
        loss = jnp.mean(logits.astype(jnp.float32) ** 2)
        return loss + 1e-30 * _cache_term(new_caches), {}

    max_len = prompt_len + 4
    fresh = tlm.init_caches(cfg, batch, max_len, dtype=jnp.float32)
    pre_batch = {"tokens": tokens, "caches": fresh}
    # Real (sessionless) prefill supplies the decode probe's cache state so
    # decode stats see realistic magnitudes, not zeros.
    logits, filled = jax.jit(
        lambda p, t, c: tlm.prefill(p, t, cfg, policy, c)
    )(params, tokens, fresh)
    dec_batch = {
        "token": jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32),
        "caches": filled,
        "pos": jnp.full((batch,), prompt_len, jnp.int32),
    }

    bank: Dict[str, Any] = {}
    bank.update(statsbank.init_bank(prefill_loss, params, pre_batch,
                                    policy, probe_cfg))
    bank.update(statsbank.init_bank(decode_loss, params, dec_batch,
                                    policy, probe_cfg))

    if train_bank:
        for k, v in train_bank.items():
            if k in bank and jax.tree_util.tree_structure(v) == \
                    jax.tree_util.tree_structure(bank[k]):
                bank[k] = v

    def banked(loss_f, b):
        def run(p, bk):
            with statsbank.bind(bk, 0, probe_cfg):
                loss, _ = loss_f(p, b, policy)
            return loss
        return run

    for _ in range(max(1, passes)):
        for loss_f, b in ((prefill_loss, pre_batch),
                          (decode_loss, dec_batch)):
            _, (_, updates) = jax.jit(
                jax.value_and_grad(banked(loss_f, b), argnums=(0, 1))
            )(params, bank)
            bank = statsbank.merge_updates(bank, updates)
    return jax.device_get(bank)
