"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

The jitted core is two functions per model (prefill, decode_step); the host
engine multiplexes requests into fixed slot batches (static shapes — XLA
never recompiles), tracks per-slot cache indices, and swaps finished slots
for queued requests between decode steps (the continuous-batching pattern,
sized down: slot admission at step boundaries, no paged attention — the
ring/window caches in models/blocks.py bound KV memory instead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.policy import Policy
from repro.models import transformer as tlm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out: Optional[List[int]] = None


class LMServer:
    """Slot-batched LM serving. All slots share one cache tree."""

    def __init__(self, cfg: ArchConfig, params, policy: Policy,
                 slots: int = 4, max_len: int = 256, eos: int = -1):
        self.cfg, self.params, self.pol = cfg, params, policy
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.caches = tlm.init_caches(cfg, slots, max_len, dtype=jnp.float32)
        self.slot_pos = np.zeros(slots, np.int32)       # next cache index
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: List[Request] = []

        def _prefill(params, tokens, caches):
            return tlm.prefill(params, tokens, cfg, policy, caches)

        def _decode(params, token, caches, index):
            return tlm.decode_step(params, token, cfg, policy, caches, index)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._last_token = np.zeros((slots, 1), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        """Fill free slots from the queue (prefill runs per-admission with
        the batch dimension replicated — single-slot prefill keeps this
        simple; a production variant batches admissions per tick)."""
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                prompt = jnp.asarray(req.prompt, jnp.int32)
                p = jnp.broadcast_to(prompt, (self.slots, prompt.shape[0]))
                logits, caches = self._prefill(self.params, p, self.caches)
                # merge only slot s from the prefilled caches
                self.caches = jax.tree_util.tree_map(
                    lambda new, old: old.at[:, s].set(new[:, s])
                    if new.ndim >= 2 else new, caches, self.caches)
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)
                self.slot_budget[s] = req.max_new_tokens
                self._last_token[s, 0] = int(jnp.argmax(logits[s, -1]))
                req.out.append(int(self._last_token[s, 0]))
                self.slot_budget[s] -= 1

    def step(self) -> bool:
        """One engine tick: admit, one decode step for all live slots.
        Returns False when idle."""
        self._admit()
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            return False
        # single shared cache index per decode call requires uniform
        # positions; we use the max and mask per-slot via cache validity.
        idx = int(self.slot_pos[live].max()) if live else 0
        tok = jnp.asarray(self._last_token)
        logits, self.caches = self._decode(self.params, tok, self.caches,
                                           jnp.int32(idx))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        for s in live:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self._last_token[s, 0] = nxt[s]
            self.slot_pos[s] += 1
            self.slot_budget[s] -= 1
            done = self.slot_budget[s] <= 0 or nxt[s] == self.eos \
                or self.slot_pos[s] >= self.max_len - 1
            if done:
                self.slot_req[s] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
