"""Payload-native serving engine: paged S2FP8 KV caches + continuous batching.

Two engines share the host scheduling machinery:

* :class:`LMServer` — the dense-cache engine (any block pattern, including
  window rings and ssm states).  Slot-batched continuous batching over one
  ``[slots, max_len, ...]`` fp32 cache tree.
* :class:`PayloadLMServer` — the payload engine.  KV lives as S2FP8
  payloads (1 byte/element + frozen per-layer (alpha, beta)) in a paged
  block pool (serving/paged_cache.py); stats come from an export-time
  frozen bank (serving/bank.py) so prefill and decode run **zero** stats
  reductions; prefill GEMMs/attention route through the payload planner and
  ``qflash_attention``; decode attention gathers payload blocks through the
  block table (kernels/paged_attention.py on a Pallas backend, a bitwise-
  matching jnp gather on the reference backend).

Both engines admit per tick in **batched, bucketed** prefills: every free
slot is filled from the FCFS queue, admissions are grouped by
next-power-of-two prompt bucket, and each bucket runs one prefill at a
fixed batch width — the compiled prefill shape set is bounded by the
number of buckets (``log2(max_len)``-ish), not the number of requests.
Decode always runs the full slot batch with a **per-slot position
vector**: slots at different depths attend to exactly their own prefix (no
shared-max position, no cross-slot validity bleed).

The payload engine adds a token-budget scheduler: admission stops at a
per-tick prefill-token cap (padded bucket tokens, the actual FLOP cost),
and when the block pool runs dry the youngest live slot is preempted
(blocks released, request requeued at the queue head for a clean restart).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import statsbank
from repro.core.policy import Policy
from repro.models import transformer as tlm
from repro.serving import paged_cache


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out: Optional[List[int]] = None


def _bucket(n: int, lo: int = 1, hi: Optional[int] = None) -> int:
    """Smallest lo * 2**k >= n (capped at hi): the prompt padding bucket."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


class LMServer:
    """Slot-batched LM serving over a dense fp32 cache tree."""

    def __init__(self, cfg: ArchConfig, params, policy: Policy,
                 slots: int = 4, max_len: int = 256, eos: int = -1):
        self.cfg, self.params, self.pol = cfg, params, policy
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.caches = tlm.init_caches(cfg, slots, max_len, dtype=jnp.float32)
        self.slot_pos = np.zeros(slots, np.int32)       # next cache index
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: List[Request] = []
        self.prefill_shapes: set = set()                # compiled (A, P) pairs

        def _prefill(params, tokens, caches, last_index):
            return tlm.prefill(params, tokens, cfg, policy, caches,
                               last_index=last_index)

        def _decode(params, token, caches, index):
            return tlm.decode_step(params, token, cfg, policy, caches, index)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._last_token = np.zeros((slots, 1), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    @property
    def max_prefill_shapes(self) -> int:
        """Upper bound on distinct compiled prefill shapes (bucket count)."""
        return int(math.log2(self.max_len)) + 1

    def _admit(self):
        """Fill every free slot from the queue, then run **one prefill per
        prompt bucket** at batch width = slots: admitted prompts sit in
        their own slot rows (right-padded to the bucket), logits are read
        at each row's true last index, and only admitted columns merge back
        into the shared cache tree."""
        adm = []
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                adm.append((s, self.queue.pop(0)))
        if not adm:
            return
        groups: Dict[int, list] = {}
        for s, req in adm:
            assert len(req.prompt) < self.max_len, "prompt exceeds max_len"
            groups.setdefault(
                _bucket(len(req.prompt), hi=self.max_len), []).append((s, req))
        for P, group in sorted(groups.items()):
            toks = np.zeros((self.slots, P), np.int32)
            last = np.zeros((self.slots,), np.int32)
            for s, req in group:
                toks[s, :len(req.prompt)] = req.prompt
                last[s] = len(req.prompt) - 1
            logits, caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(last))
            self.prefill_shapes.add((self.slots, P))
            assert len(self.prefill_shapes) <= self.max_prefill_shapes
            cols = np.asarray([s for s, _ in group])
            self.caches = jax.tree_util.tree_map(
                lambda new, old: old.at[:, cols].set(new[:, cols])
                if new.ndim >= 2 else new, caches, self.caches)
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
            for s, req in group:
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)
                self.slot_budget[s] = req.max_new_tokens
                self._last_token[s, 0] = int(nxt[s])
                req.out.append(int(nxt[s]))
                self.slot_budget[s] -= 1

    def step(self) -> bool:
        """One engine tick: admit, one decode step for all live slots.
        Returns False when idle."""
        self._admit()
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            return False
        # per-slot position vector: each slot writes and attends at its own
        # depth (dead slots decode garbage at position 0, discarded here).
        pos = np.zeros((self.slots,), np.int32)
        for s in live:
            pos[s] = self.slot_pos[s]
        tok = jnp.asarray(self._last_token)
        logits, self.caches = self._decode(self.params, tok, self.caches,
                                           jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        for s in live:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self._last_token[s, 0] = nxt[s]
            self.slot_pos[s] += 1
            self.slot_budget[s] -= 1
            done = self.slot_budget[s] <= 0 or nxt[s] == self.eos \
                or self.slot_pos[s] >= self.max_len - 1
            if done:
                self.slot_req[s] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks


class PayloadLMServer:
    """Paged-payload serving engine (see module docstring).

    ``bank``: exported frozen StatsBank (serving/bank.py); None runs
    without a frozen session (identity cache stats) — the fp32-baseline
    configuration for the zero-reduction jaxpr diff.
    ``cache_fmt``: pool storage format (paged_cache.CACHE_FMTS); "e5m2" /
    "e4m3" are the payload pools, "f32_e5m2" / "f32_e4m3" the grid-snapped
    parity comparators, "f32" the raw baseline.
    ``n_blocks``: pool size incl. the trash block; default sizes for zero
    memory pressure (slots * max_blocks + 1) — pass less to exercise
    preemption.
    ``prefill_token_budget``: per-tick cap on padded prefill tokens.
    """

    def __init__(self, cfg: ArchConfig, params, policy: Policy, *,
                 bank: Optional[Dict[str, Any]] = None, slots: int = 8,
                 max_len: int = 256, block: int = 16,
                 n_blocks: Optional[int] = None, cache_fmt: str = "e5m2",
                 eos: int = -1, admit_width: Optional[int] = None,
                 prefill_token_budget: Optional[int] = None,
                 stats_cfg: Optional[statsbank.StatsConfig] = None,
                 sink=None):
        if max_len % block:
            raise ValueError(f"max_len {max_len} not a multiple of "
                             f"block {block}")
        self.cfg, self.params, self.pol = cfg, params, policy
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.block = block
        self.max_blocks = max_len // block
        self.n_blocks = n_blocks or slots * self.max_blocks + 1
        self.cache_fmt = cache_fmt
        self.bank = bank
        self.admit_width = admit_width or min(slots, 8)
        self.prefill_token_budget = (prefill_token_budget
                                     or self.admit_width * max_len)
        self.sink = sink
        scfg = stats_cfg or statsbank.StatsConfig()

        kv_stats = (paged_cache.kv_stats_from_bank(bank, cfg, cache_fmt)
                    if bank is not None else None)
        self.caches = paged_cache.init_paged_caches(
            cfg, slots=slots, n_blocks=self.n_blocks, block=block,
            max_blocks=self.max_blocks, cache_fmt=cache_fmt,
            kv_stats=kv_stats)
        self.alloc = paged_cache.BlockAllocator(self.n_blocks, slots,
                                                self.max_blocks)

        self.slot_pos = np.zeros(slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.slot_seq = np.zeros(slots, np.int64)       # admission order
        self.queue: List[Request] = []
        self.prefill_shapes: set = set()
        self.preemptions = 0
        self._seq = 0
        self._tick = 0
        self._last_token = np.zeros((slots, 1), np.int32)

        use_freeze = bank is not None

        def _prefill_fn(params, tokens, last_index):
            dense = tlm.init_caches(cfg, tokens.shape[0], tokens.shape[1],
                                    dtype=jnp.float32)
            if use_freeze:
                with statsbank.freeze(bank, scfg):
                    return tlm.prefill(params, tokens, cfg, policy, dense,
                                       last_index=last_index)
            return tlm.prefill(params, tokens, cfg, policy, dense,
                               last_index=last_index)

        def _pack_fn(caches, dense, bids):
            return paged_cache.pack_dense_caches(caches, dense, bids,
                                                 cache_fmt)

        def _decode_fn(params, token, caches, pos):
            if use_freeze:
                with statsbank.freeze(bank, scfg):
                    return tlm.decode_step(params, token, cfg, policy,
                                           caches, pos, cache_fmt=cache_fmt)
            return tlm.decode_step(params, token, cfg, policy, caches, pos,
                                   cache_fmt=cache_fmt)

        self._prefill = jax.jit(_prefill_fn)
        self._pack = jax.jit(_pack_fn)
        self._decode = jax.jit(_decode_fn)
        self._decode_raw = _decode_fn

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    @property
    def max_prefill_shapes(self) -> int:
        return int(math.log2(self.max_len)) + 1

    def decode_jaxpr(self):
        """Jaxpr of one steady-state decode tick — tests assert its stats-
        reduction count matches an unfrozen fp32 baseline (zero extra)."""
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = jnp.zeros((self.slots,), jnp.int32)
        return jax.make_jaxpr(self._decode_raw)(self.params, tok,
                                                self.caches, pos)

    def cache_bytes(self):
        """(pool_bytes, stats_bytes) of the paged cache."""
        return paged_cache.cache_payload_bytes(self.caches)

    # ------------------------------------------------------------------
    def _sync_tables(self):
        tb = jnp.asarray(self.alloc.table)
        self.caches = [
            dict(seg, table=jnp.broadcast_to(
                tb[None], (seg["table"].shape[0],) + tb.shape))
            for seg in self.caches]

    def _preempt(self, s: int):
        """Release slot s and requeue its request (head of queue) for a
        clean restart."""
        req = self.slot_req[s]
        self.alloc.release(s)
        self.slot_req[s] = None
        if req is not None:
            req.out = []
            self.queue.insert(0, req)
        self.preemptions += 1

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Youngest live slot other than ``exclude`` (LIFO preemption:
        oldest admissions keep their progress)."""
        live = [s for s in range(self.slots)
                if s != exclude and self.slot_req[s] is not None]
        return max(live, key=lambda s: self.slot_seq[s]) if live else None

    # ------------------------------------------------------------------
    def _admit(self) -> int:
        """Batched, budgeted admission.  FCFS: take queue heads while a
        slot, the prefill-token budget, and pool blocks all allow; then one
        prefill + pack per prompt bucket at fixed width ``admit_width``."""
        free = [s for s in range(self.slots) if self.slot_req[s] is None]
        picked = []                                  # (slot, req)
        used = 0
        while self.queue and free and len(picked) < self.admit_width:
            req = self.queue[0]
            plen = len(req.prompt)
            if plen >= self.max_len:
                self.queue.pop(0)
                req.out = []
                continue                             # drop oversize request
            P = _bucket(plen, lo=self.block, hi=self.max_len)
            if picked and used + P > self.prefill_token_budget:
                break                                # token budget: next tick
            s = free[0]
            if not self.alloc.alloc(s, -(-plen // self.block)):
                break                                # pool dry: wait / preempt
            free.pop(0)
            self.queue.pop(0)
            used += P
            self._seq += 1
            self.slot_seq[s] = self._seq
            picked.append((s, req))
        if not picked:
            return 0

        groups: Dict[int, list] = {}
        for s, req in picked:
            groups.setdefault(
                _bucket(len(req.prompt), lo=self.block, hi=self.max_len),
                []).append((s, req))
        A = self.admit_width
        for P, group in sorted(groups.items()):
            toks = np.zeros((A, P), np.int32)
            last = np.zeros((A,), np.int32)
            bids = np.zeros((A, P // self.block), np.int32)  # 0 = trash
            for r, (s, req) in enumerate(group):
                plen = len(req.prompt)
                toks[r, :plen] = req.prompt
                last[r] = plen - 1
                nb = -(-plen // self.block)
                bids[r, :nb] = self.alloc.table[s, :nb]
            logits, dense = self._prefill(self.params, jnp.asarray(toks),
                                          jnp.asarray(last))
            self.prefill_shapes.add((A, P))
            assert len(self.prefill_shapes) <= self.max_prefill_shapes
            self.caches = self._pack(self.caches, dense, jnp.asarray(bids))
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
            for r, (s, req) in enumerate(group):
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)
                self.slot_budget[s] = req.max_new_tokens
                self._last_token[s, 0] = int(nxt[r])
                req.out.append(int(nxt[r]))
                self.slot_budget[s] -= 1
        return len(picked)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One tick: admit, grow blocks at decode boundaries (preempting
        the youngest slot when the pool runs dry), one batched decode."""
        self._tick += 1
        n_admit = self._admit()
        preempted_this_tick = 0
        for s in range(self.slots):
            if self.slot_req[s] is None:
                continue
            need = int(self.slot_pos[s]) // self.block + 1
            while int(self.alloc.nalloc[s]) < need:
                if self.alloc.alloc(s, 1):
                    continue
                victim = self._pick_victim(exclude=s)
                if victim is None:
                    self._preempt(s)                 # nothing else to evict
                else:
                    self._preempt(victim)
                preempted_this_tick += 1
                if self.slot_req[s] is None:
                    break
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            self._emit_tick(n_admit, 0, preempted_this_tick)
            return bool(n_admit or self.queue)
        self._sync_tables()
        pos = np.zeros((self.slots,), np.int32)
        for s in live:
            pos[s] = self.slot_pos[s]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self._last_token), self.caches,
            jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        for s in live:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self._last_token[s, 0] = nxt[s]
            self.slot_pos[s] += 1
            self.slot_budget[s] -= 1
            done = self.slot_budget[s] <= 0 or nxt[s] == self.eos \
                or self.slot_pos[s] >= self.max_len - 1
            if done:
                self.alloc.release(s)
                self.slot_req[s] = None
        self._emit_tick(n_admit, len(live), preempted_this_tick)
        return True

    def _emit_tick(self, admitted: int, decoded: int, preempted: int):
        if self.sink is None:
            return
        self.sink.emit({
            "kind": "event", "event": "serving_tick", "tick": self._tick,
            "admitted": admitted, "decode_tokens": decoded,
            "preempted": preempted, "preemptions_total": self.preemptions,
            "live": sum(r is not None for r in self.slot_req),
            "queue_depth": len(self.queue),
            "free_blocks": self.alloc.free_blocks,
        })

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        if self.sink is not None:
            self.sink.flush()
        return ticks
