"""Paged S2FP8 KV cache: fixed-size blocks + block table + free-list allocator.

The serving engine (serving/engine.py) stores KV caches as S2FP8 payloads —
1 byte/element plus one frozen per-layer (alpha, beta) pair per tensor —
in a block pool instead of dense per-slot ``[slots, max_len, ...]`` arrays.
HBM then holds ~4x the decode slots (or 4x the context) of an fp32 dense
cache, and fragmentation is bounded by one partial block per slot.

Layout, per attention segment (leaves stacked over the segment's L layers so
they ride the model's layer scan ``xs`` like every other cache leaf):

    kp / vp : [L, n_blocks, KV, block, hd]   pool (payload or f32)
    kab/vab : [L, 2]                          frozen (alpha, beta) per layer
    table   : [L, slots, max_blocks] int32    block table (same rows every
                                              layer; duplicated so it scans)

Block 0 is a reserved **trash block**: never allocated, all dead-slot /
dummy-row writes land there, and every value it could hold is finite (the
pool is zero-initialized and the encode clamps at the format max), so trash
reads are always safely masked by the attention validity mask.

``cache_fmt`` (static, threaded through models/transformer.py):

    "e5m2" / "e4m3"         : fp8 payload pool (the serving engine)
    "f32_e5m2" / "f32_e4m3" : f32 pool holding grid-snapped values — the
        parity comparator.  Because ``dequantize(quantize(x, s)) ==
        truncate_value(x, s)`` elementwise (core/s2fp8.py), a payload engine
        and an f32_* comparator sharing one frozen bank read bit-identical
        K/V and decode token-identical greedy outputs.
    "f32"                   : raw f32, no truncation (the fp32 baseline on
        the same paged structure — used for the zero-reduction jaxpr diff)

All encode/decode math goes through core/s2fp8.py directly (not a backend
object), so pack-time and decode-time writes are bitwise the same program.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import backend as nbackend
from repro.core import s2fp8, statsbank

CACHE_FMTS = ("e5m2", "e4m3", "f32_e5m2", "f32_e4m3", "f32")

# Segment block types that use the paged KV layout (global attention only;
# sliding-window rings and mamba conv/ssm states keep their dense layout).
PAGED_BLOCK_TYPES = ("dense", "moe", "attn", "dense_first")


def base_fmt(cache_fmt: str) -> Optional[str]:
    """The fp8 grid a cache format snaps to (None for raw f32)."""
    if cache_fmt == "f32":
        return None
    return cache_fmt.split("_")[-1]


def is_payload(cache_fmt: str) -> bool:
    return cache_fmt in ("e5m2", "e4m3")


def pool_dtype(cache_fmt: str):
    if is_payload(cache_fmt):
        return s2fp8.FMT_QDTYPE[cache_fmt]
    return jnp.float32


def _encode(x: jnp.ndarray, stats, cache_fmt: str) -> jnp.ndarray:
    """f32 values -> pool storage (payload bytes, or grid-snapped f32)."""
    fmt = base_fmt(cache_fmt)
    if fmt is None:
        return x.astype(jnp.float32)
    if is_payload(cache_fmt):
        return s2fp8.quantize(x, stats=stats, fmt=fmt).payload
    if fmt == "e5m2":
        return s2fp8.truncate_value(x.astype(jnp.float32), stats=stats)
    return s2fp8.truncate_value_e4m3(x.astype(jnp.float32), stats=stats)


def _decode(g: jnp.ndarray, stats, cache_fmt: str) -> jnp.ndarray:
    """Pool storage -> f32 values (identity for the f32 pools)."""
    if not is_payload(cache_fmt):
        return g
    t = s2fp8.S2FP8Tensor(payload=g, alpha=stats[0], beta=stats[1],
                          fmt=base_fmt(cache_fmt))
    return s2fp8.dequantize(t, jnp.float32)


# =========================================================================
# Cache construction
# =========================================================================

def identity_stats(n_layers: int) -> jnp.ndarray:
    """[L, 2] (alpha=1, beta=0) — the f32 / no-bank configuration."""
    return jnp.tile(jnp.asarray([1.0, 0.0], jnp.float32), (n_layers, 1))


def kv_stats_from_bank(bank: Dict[str, Any], cfg: ArchConfig,
                       cache_fmt: str) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Per-segment (kab, vab) [L, 2] frozen stats from an exported serving
    bank's ``seg{i}:{btype}/kv_cache/t{0,1}`` sites (t0 = K, t1 = V).

    Uses :func:`statsbank.frozen_stats` — the same derivation the frozen
    session applies at every other site — so the cache's (alpha, beta) are
    bit-identical to what an in-model truncation at that site would use.
    """
    from repro.models import transformer as tlm
    fmt = base_fmt(cache_fmt) or "e5m2"
    out = []
    for i, (btype, length) in enumerate(tlm.segments_of(cfg)):
        if btype not in PAGED_BLOCK_TYPES:
            out.append(None)
            continue
        abs_ = []
        for t in ("t0", "t1"):
            key = f"seg{i}:{btype}/kv_cache/{t}"
            if bank is None or key not in bank:
                abs_.append(identity_stats(length))
            else:
                a, b = statsbank.frozen_stats(bank[key]["fwd"], fmt)
                abs_.append(jnp.stack([a, b], axis=-1))
        out.append((abs_[0], abs_[1]))
    return out


def init_paged_caches(cfg: ArchConfig, *, slots: int, n_blocks: int,
                      block: int, max_blocks: int, cache_fmt: str,
                      kv_stats=None) -> List[Dict[str, jnp.ndarray]]:
    """Per-segment paged cache pytrees (see module docstring for layout).

    ``kv_stats``: per-segment (kab, vab) [L, 2] from
    :func:`kv_stats_from_bank`, or None for identity stats.
    """
    from repro.models import transformer as tlm
    assert cache_fmt in CACHE_FMTS, cache_fmt
    hd = cfg.resolved_head_dim
    dt = pool_dtype(cache_fmt)
    caches = []
    for i, (btype, length) in enumerate(tlm.segments_of(cfg)):
        if btype not in PAGED_BLOCK_TYPES:
            raise ValueError(
                f"paged serving supports global-attention blocks only, got "
                f"{btype!r} (segment {i}); window rings / ssm states need "
                f"the dense engine")
        st = kv_stats[i] if kv_stats is not None else None
        kab, vab = st if st is not None else (identity_stats(length),
                                              identity_stats(length))
        shape = (length, n_blocks, cfg.kv_heads, block, hd)
        caches.append({
            "kp": jnp.zeros(shape, dt),
            "vp": jnp.zeros(shape, dt),
            "kab": jnp.asarray(kab, jnp.float32),
            "vab": jnp.asarray(vab, jnp.float32),
            "table": jnp.zeros((length, slots, max_blocks), jnp.int32),
        })
    return caches


def cache_payload_bytes(caches) -> Tuple[int, int]:
    """(pool_bytes, stats_bytes) of a paged cache list — the acceptance
    check that the payload pools store <= 1 byte/element + stats."""
    pool = stats = 0
    for seg in caches:
        for key in ("kp", "vp"):
            pool += seg[key].size * seg[key].dtype.itemsize
        for key in ("kab", "vab"):
            stats += seg[key].size * 4
    return pool, stats


# =========================================================================
# Decode-path update + attend (called per layer from models/blocks.py)
# =========================================================================

def update_and_attend(qg, k, v, cache, cache_index, *, policy,
                      cache_fmt: str):
    """Write the new K/V token into the slot's current block, then attend
    over the slot's gathered blocks.

    qg: [B, KV, G, 1, hd]; k, v: [B, KV, 1, hd]; ``cache`` is one layer's
    slice {kp, vp, kab, vab, table}; ``cache_index``: [B] per-slot positions
    (a scalar is broadcast).  B must equal the table's slot count.

    On a Pallas backend with a payload pool the attention runs the
    block-table gather kernel (kernels/paged_attention.py) — payload blocks
    dequantize in VMEM and no dense fp32 cache is ever materialized.  The
    reference path gathers + dequantizes in jnp and reuses
    ``decode_attention`` so its numerics match the dense comparator
    bit-for-bit.
    """
    assert cache_fmt in CACHE_FMTS, cache_fmt
    kp, vp, table = cache["kp"], cache["vp"], cache["table"]
    nb, kvh, blk, hd = kp.shape
    slots, max_b = table.shape
    b = qg.shape[0]
    assert b == slots, (b, slots)
    kst = (cache["kab"][0], cache["kab"][1])
    vst = (cache["vab"][0], cache["vab"][1])
    ci = jnp.asarray(cache_index, jnp.int32)
    if ci.ndim == 0:
        ci = jnp.full((b,), ci, jnp.int32)

    bi = jnp.arange(b)
    bid = table[bi, ci // blk]                       # [B] current block
    off = ci % blk
    qk = _encode(k[:, :, 0].astype(jnp.float32), kst, cache_fmt)
    qv = _encode(v[:, :, 0].astype(jnp.float32), vst, cache_fmt)
    kp = kp.at[bid, :, off].set(qk.astype(kp.dtype))
    vp = vp.at[bid, :, off].set(qv.astype(vp.dtype))
    new_cache = dict(cache, kp=kp, vp=vp)

    use_kernel = (policy is not None and is_payload(cache_fmt)
                  and isinstance(policy.backend_obj, nbackend.PallasBackend))
    if use_kernel:
        from repro.kernels import paged_attention as _pk
        out = _pk.paged_decode_attention(
            qg[:, :, :, 0].astype(jnp.float32), kp, vp,
            kst[0], kst[1], vst[0], vst[1], table, ci,
            fmt=base_fmt(cache_fmt))
        return out[:, :, :, None, :].astype(qg.dtype), new_cache

    from repro.models import blocks as _blocks

    def gathered(pool, stats):
        g = pool[table]                              # [B, max_b, KV, blk, hd]
        g = jnp.moveaxis(g, 1, 2).reshape(b, kvh, max_b * blk, hd)
        return _decode(g, stats, cache_fmt)

    kpos = jnp.arange(max_b * blk)
    valid = kpos[None, :] <= ci[:, None]
    attn = _blocks.decode_attention(qg, gathered(kp, kst), gathered(vp, vst),
                                    valid, policy=policy)
    return attn, new_cache


# =========================================================================
# Prefill pack: dense bucket caches -> pool blocks
# =========================================================================

def _encode_layers(x, ab, cache_fmt: str):
    """Per-layer encode: x [L, ...], ab [L, 2] -> pool storage [L, ...]."""
    if base_fmt(cache_fmt) is None:
        return x.astype(jnp.float32)
    return jax.vmap(lambda xl, abl: _encode(xl, (abl[0], abl[1]),
                                            cache_fmt))(
        x.astype(jnp.float32), ab)


def pack_dense_caches(paged_caches, dense_caches, bids, cache_fmt: str):
    """Scatter a bucket-width dense prefill cache into the block pools.

    ``dense_caches``: per-segment {"k","v"} [L, A, KV, P, hd] from a
    prefill at admission width A and bucket length P (P % block == 0).
    ``bids``: [A, P // block] int32 block ids per admitted row — dummy rows
    and beyond-prompt blocks point at the trash block 0.  Returns the
    updated paged cache list (tables unchanged; the host refreshes those).
    """
    out = []
    for seg_p, seg_d in zip(paged_caches, dense_caches):
        kp = seg_p["kp"]
        length, nb, kvh, blk, hd = kp.shape
        a_w, nb_p = bids.shape
        flat = bids.reshape(-1)                       # [A * nbP]
        seg = dict(seg_p)
        for pool_key, dense_key, ab in (("kp", "k", seg_p["kab"]),
                                        ("vp", "v", seg_p["vab"])):
            enc = _encode_layers(seg_d[dense_key], ab, cache_fmt)
            # [L, A, KV, P, hd] -> [L, A * nbP, KV, blk, hd]
            enc = enc.reshape(length, a_w, kvh, nb_p, blk, hd)
            enc = enc.transpose(0, 1, 3, 2, 4, 5).reshape(
                length, a_w * nb_p, kvh, blk, hd)
            seg[pool_key] = seg_p[pool_key].at[:, flat].set(
                enc.astype(seg_p[pool_key].dtype))
        out.append(seg)
    return out


# =========================================================================
# Host-side free-list block allocator
# =========================================================================

class BlockAllocator:
    """Free-list allocator over one pool's blocks (block 0 = trash,
    never handed out).  Pure host/numpy; the engine mirrors ``table``
    into each segment's device cache after every change."""

    def __init__(self, n_blocks: int, slots: int, max_blocks: int):
        self.n_blocks = n_blocks
        self.max_blocks = max_blocks
        # LIFO free list; block 0 reserved as trash
        self.free: List[int] = list(range(n_blocks - 1, 0, -1))
        self.table = np.zeros((slots, max_blocks), np.int32)
        self.nalloc = np.zeros((slots,), np.int32)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def alloc(self, slot: int, n: int) -> bool:
        """Append n blocks to ``slot``; False (nothing allocated) on OOB
        or free-list exhaustion."""
        have = int(self.nalloc[slot])
        if n <= 0:
            return True
        if have + n > self.max_blocks or n > len(self.free):
            return False
        for i in range(n):
            self.table[slot, have + i] = self.free.pop()
        self.nalloc[slot] = have + n
        return True

    def release(self, slot: int):
        """Return all of ``slot``'s blocks to the free list."""
        for i in range(int(self.nalloc[slot])):
            self.free.append(int(self.table[slot, i]))
        self.table[slot, :] = 0
        self.nalloc[slot] = 0
