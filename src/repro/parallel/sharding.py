"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Models annotate tensors with *logical* axis names; a rule table maps those to
physical mesh axes.  The table is process-global state set by the launcher
(``use_rules``); when unset (unit tests, single-device smoke runs) every
annotation is a no-op, so model code is mesh-agnostic.

Physical meshes (launch/mesh.py):
    single-pod: ("data", "model") = (16, 16)
    multi-pod : ("pod", "data", "model") = (2, 16, 16)

Default rule tables:

  TRAIN_RULES                             DECODE_RULES
    batch   -> (pod,) data                  batch   -> (pod,) data
    fsdp    -> data          (ZeRO-3)       fsdp    -> None (params gathered
    embed   -> None                                    once, then reused)
    heads   -> model                        heads   -> model
    kv      -> model                        kv      -> model
    mlp     -> model                        mlp     -> model
    expert  -> model (EP)                   expert  -> model
    vocab   -> model                        vocab   -> model
    seq     -> None                         kv_seq  -> model  (SP flash-decode
                                                      for the 500k cells)

Pipeline parallelism growth path (1000+ nodes): the segment structure in
models/transformer.py (list of scanned layer-runs) is already the natural
stage boundary — a "stage" axis would map segment k to mesh slice k with
``jax.lax.ppermute`` activations between stages.  Not enabled for the
assigned 512-chip meshes, where FSDP+TP saturates ICI first (see DESIGN.md).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

TRAIN_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "embed": None,
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": None,
    "kv_seq": None,
    "conv": None,
    "state": None,
}

DECODE_RULES = dict(TRAIN_RULES)
DECODE_RULES.update({
    # flash-decode style: the KV-cache *sequence* axis carries the model
    # axis (SP); head axes stay replicated so the one-token attention is a
    # clean partial-softmax over sharded S (heads are tiny at S=1).
    "heads": None,
    "kv": None,
    "kv_seq": ("model",),
})


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def _mesh_axes() -> Tuple[str, ...]:
    return getattr(_state, "mesh_axes", ())


def _axis_sizes() -> Dict[str, int]:
    return getattr(_state, "axis_sizes", {})


@contextlib.contextmanager
def use_rules(rules: dict, mesh_axes, axis_sizes: Optional[Dict[str, int]] = None):
    """Activate a logical->physical table for model tracing in this thread.

    ``mesh_axes`` may be a tuple of names or a dict name->size; sizes enable
    the divisibility guard in :func:`shard` (a logical axis whose tensor dim
    does not divide by the mapped mesh axes is silently replicated — e.g.
    36 attention heads on a 16-way model axis).
    """
    if isinstance(mesh_axes, dict):
        axis_sizes = dict(mesh_axes)
        mesh_axes = tuple(mesh_axes)
    prev = (_rules(), _mesh_axes(), _axis_sizes())
    _state.rules = rules
    _state.mesh_axes = tuple(mesh_axes)
    _state.axis_sizes = axis_sizes or {}
    try:
        yield
    finally:
        _state.rules, _state.mesh_axes, _state.axis_sizes = prev


@contextlib.contextmanager
def suspend_rules():
    """Deactivate the logical->physical table for the current thread.

    Inside a ``shard_map`` body every tensor is a LOCAL shard and the
    mesh axes are manual — a ``with_sharding_constraint`` emitted by
    :func:`shard` would name axes already claimed as manual and fail to
    trace.  The mesh-native train step (training/trainer.py) wraps its
    body in this, so models keep their annotations for the pjit/GSPMD
    launchers while tracing cleanly under shard_map."""
    prev = (_rules(), _mesh_axes(), _axis_sizes())
    _state.rules, _state.mesh_axes, _state.axis_sizes = None, (), {}
    try:
        yield
    finally:
        _state.rules, _state.mesh_axes, _state.axis_sizes = prev


def resolve(*logical: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec under the active rules."""
    rules = _rules()
    mesh_axes = set(_mesh_axes())
    if rules is None:
        return P()
    spec, used = [], set()
    for name in logical:
        if name is None:
            spec.append(None)
            continue
        phys = rules.get(name)
        if phys is None:
            spec.append(None)
            continue
        # keep only axes present on this mesh and not already consumed
        keep = tuple(a for a in phys if a in mesh_axes and a not in used)
        used.update(keep)
        if not keep:
            spec.append(None)
        elif len(keep) == 1:
            spec.append(keep[0])
        else:
            spec.append(keep)
    return P(*spec)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation; no-op outside an active rule table.

    Applies the divisibility guard: any dim that does not divide evenly by
    the product of its mapped mesh axes is replicated instead.
    """
    if _rules() is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"shard(): {len(logical)} axes for rank-{x.ndim} tensor")
    spec = resolve(*logical)
    sizes = _axis_sizes()
    guarded = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            guarded.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        guarded.append(entry if (prod > 0 and dim % prod == 0) else None)
    return jax.lax.with_sharding_constraint(x, P(*guarded))


def guarded_spec(shape, *logical: Optional[str]) -> P:
    """Like shard()'s guard but returns the PartitionSpec (for in_shardings)."""
    if _rules() is None:
        return P()
    spec = resolve(*logical)
    sizes = _axis_sizes()
    guarded = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            guarded.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        guarded.append(entry if (prod > 0 and dim % prod == 0) else None)
    return P(*guarded)


def active() -> bool:
    return _rules() is not None


# ---------------------------------------------------------------------------
# mesh-level spec resolution for the shard_map train step
# (training/trainer.py ``make_train_step(mesh=...)``)
# ---------------------------------------------------------------------------

def mesh_batch_axes(mesh) -> Tuple[str, ...]:
    """Physical mesh axes that carry the batch under the active rule table
    (``TRAIN_RULES`` when none is active): the axes the mesh-native train
    step shards its batch over, syncs gradients across, and all-reduces
    StatsBank partials on.  Only axes present on ``mesh`` are returned —
    ``("data",)`` for the host/single-pod meshes, ``("pod", "data")``
    multi-pod."""
    rules = _rules() or TRAIN_RULES
    phys = rules.get("batch") or ()
    return tuple(a for a in phys if a in mesh.axis_names)


def mesh_batch_size(mesh) -> int:
    """Product of the batch-carrying mesh axis sizes (number of data
    shards the global batch splits into)."""
    n = 1
    for a in mesh_batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def batch_is_sharded(tree, mesh) -> bool:
    """Whether the batch tree actually splits over the mesh's batch axes:
    the ALL-OR-NOTHING divisibility guard of :func:`mesh_batch_specs`.
    False means every shard computes the full batch (replication
    fallback) — callers that aggregate per-shard SUMS (integer count
    metrics) must divide back by the shard count in that case."""
    axes = mesh_batch_axes(mesh)
    n = mesh_batch_size(mesh)
    leaves = [l for l in jax.tree_util.tree_leaves(tree)
              if getattr(l, "ndim", 0) >= 1]
    return bool(axes) and bool(leaves) and all(
        leaf.shape[0] % n == 0 for leaf in leaves)


def mesh_batch_specs(tree, mesh):
    """Per-leaf PartitionSpecs sharding dim 0 of every batch leaf over the
    mesh's batch axes — the train step's batch ``in_specs``.  Applies the
    divisibility guard of :func:`shard` ALL-OR-NOTHING across the tree
    (:func:`batch_is_sharded`): if any >=1-D leaf's leading dim does not
    divide by the combined batch-axis size, the WHOLE batch is replicated
    (every shard computes the full batch — correct, just unsplit).
    Per-leaf guarding would silently pair a sharded leaf's shard with
    another leaf's full batch inside the shard_map body.  0-d leaves are
    always replicated."""
    axes = mesh_batch_axes(mesh)
    entry = axes[0] if len(axes) == 1 else axes
    shardable = batch_is_sharded(tree, mesh)

    def spec(leaf):
        if not shardable or getattr(leaf, "ndim", 0) == 0:
            return P()
        return P(entry)

    return jax.tree_util.tree_map(spec, tree)


def fsdp_axis_entry(mesh) -> Optional[str]:
    """The physical mesh axis carrying the ``fsdp`` logical axis under the
    active rule table (``TRAIN_RULES`` when none is active), or None when
    the mesh has no such axis.  The rule table maps fsdp to a single
    physical axis (``data``); param/opt leaves shard dim 0 over it."""
    rules = _rules() or TRAIN_RULES
    phys = rules.get("fsdp") or ()
    axes = tuple(a for a in phys if a in mesh.axis_names)
    return axes[0] if axes else None


def fsdp_axis_size(mesh) -> int:
    """Size of the fsdp-carrying mesh axis (1 when the mesh has none)."""
    axis = fsdp_axis_entry(mesh)
    return mesh.shape[axis] if axis is not None else 1


def fsdp_leaf_eligible(shape, dtype, axis_size: int) -> bool:
    """Whether one param/opt leaf shards over the fsdp axis: float dtype
    (integer leaves like the opt step counter stay replicated — they are
    0-d anyway), rank >= 1, and dim 0 divisible by the axis size.  Pure
    function of static shape/dtype so the trainer evaluates it OUTSIDE
    the shard_map (inside, dim 0 is already divided and the predicate
    would be ambiguous) and per-leaf specs/gathers stay in lockstep."""
    import jax.numpy as jnp
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return False
    if len(shape) == 0 or shape[0] == 0:
        return False
    return shape[0] % axis_size == 0


def fsdp_param_specs(tree, mesh):
    """Per-leaf PartitionSpecs sharding dim 0 of every eligible param (or
    optimizer-state) leaf over the fsdp axis; ineligible leaves replicate.
    Applied per-leaf (unlike the batch's all-or-nothing guard): each param
    leaf gathers/scatters independently, so a non-divisible bias staying
    replicated next to a sharded weight is correct by construction."""
    axis = fsdp_axis_entry(mesh)
    if axis is None:
        return jax.tree_util.tree_map(lambda _: P(), tree)
    n = mesh.shape[axis]

    def spec(leaf):
        if fsdp_leaf_eligible(leaf.shape, leaf.dtype, n):
            return P(axis)
        return P()

    return jax.tree_util.tree_map(spec, tree)


def train_step_specs(batch, mesh, with_stats: bool = False,
                     with_guard: bool = False,
                     param_sharding: str = "replicated",
                     params=None, opt_state=None):
    """(in_specs, out_specs) for the mesh-native train step's shard_map.

    The step is data-parallel: StatsBank carry / StepGuard carry / step
    counter are replicated, the batch shards per :func:`mesh_batch_specs`,
    and metrics/bank/guard outputs are replicated (tiny scalar pytrees
    whose values are identical on every shard — they integrate post-psum
    globals).  The guard carry rides after the bank.

    Params and optimizer state are replicated (``P()``) in the default
    ``param_sharding="replicated"`` mode.  Under ``"fsdp"``/``"fsdp_q"``
    they shard dim 0 over the rule table's fsdp axis per
    :func:`fsdp_param_specs` (pass the concrete ``params``/``opt_state``
    trees so per-leaf eligibility resolves) — the step then gathers
    just-in-time inside the differentiated loss and reduce-scatters grads
    back, so the updated leaves come OUT sharded too."""
    # params, opt_state[, bank][, guard]
    carry = 2 + int(with_stats) + int(with_guard)
    tail = int(with_stats) + int(with_guard)
    if param_sharding == "replicated":
        carry_in = (P(), P())
    else:
        if params is None or opt_state is None:
            raise ValueError("param_sharding != 'replicated' needs the "
                             "concrete params/opt_state trees for per-leaf "
                             "spec resolution")
        carry_in = (fsdp_param_specs(params, mesh),
                    fsdp_param_specs(opt_state, mesh))
    in_specs = carry_in + (P(),) * tail \
        + (mesh_batch_specs(batch, mesh), P())
    out_specs = carry_in + (P(),) * (tail + 1)      # carry + metrics
    return in_specs, out_specs
