"""StepGuard: in-step numerics sentinels + the snapshot ring they protect.

The paper's premise is that S2FP8 trains *without* hand-tuned loss-scale
knobs — but a single divergent step still poisons params, optimizer
moments, AND the StatsBank EMAs (stale (alpha, beta) then mis-truncates
every subsequent tensor).  The guard closes that loop in two halves:

* **In-trace** (this module + trainer.py): a verdict evaluated inside the
  jitted step from scalars the step already computes —

    - non-finite loss / gradient (the global grad norm is NaN/Inf iff any
      leaf is),
    - global-grad-norm spike vs. a carried EMA (``guard_state``, a
      two-scalar pytree riding the step carry exactly like the StatsBank),
    - bank saturation read from PR 7's telemetry leaves (``sat_frac``),
      fused into the trainer's existing bookkeeping ``min`` probe so the
      steady-state jaxpr reduction budget is UNCHANGED (fp32 baseline + 1,
      asserted in tests/test_resilience.py).

  On a bad verdict :func:`reject_update` passes the pre-step trees through
  a ``lax.cond`` select — bit-identical, no recompile, and mesh-global for
  free because every input scalar is already post-psum/post-sync.

* **Host-side** (:class:`SnapshotRing` + TrainLoop's escalation ladder):
  skip step -> force a StatsBank refresh -> roll back to an in-memory
  snapshot -> restore from checkpoint.  The ring keeps the last-good
  (params, opt, bank, guard) on the HOST every k steps, optionally
  S2FP8-compressed through the same codec the checkpoint manager uses.

The wire diagram and the chaos spec grammar that exercises all of this
live in kernels/README.md ("Resilience dataflow").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import s2fp8
from repro.core import statsbank


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """In-step sentinel thresholds.

    * ``spike_factor`` — trip when the (global) grad norm exceeds
      ``spike_factor * EMA``; the EMA only integrates ACCEPTED steps, so a
      rejected spike cannot drag the baseline up after it.
    * ``ema_decay``    — grad-norm EMA decay (first accepted step seeds it).
    * ``warmup``       — accepted steps before the spike sentinel arms
      (early training legitimately moves the norm around).
    * ``sat_threshold`` — trip when any bank site's ``sat_frac`` telemetry
      leaf exceeds this fraction; 0 disables the sentinel (it needs a
      telemetry-enabled StatsBank to have anything to read).  A saturation
      trip rejects the param/optimizer update but NOT the bank: the
      refresh that measured the saturation is the remedy, and discarding
      it would wedge the guard in a reject loop.
    """
    spike_factor: float = 10.0
    ema_decay: float = 0.9
    warmup: int = 8
    sat_threshold: float = 0.0

    def __post_init__(self):
        if self.spike_factor <= 1.0:
            raise ValueError("guard spike_factor must be > 1")
        if not (0.0 <= self.ema_decay < 1.0):
            raise ValueError("guard ema_decay must be in [0, 1)")


def init_state() -> Dict[str, jnp.ndarray]:
    """Fresh guard carry: no grad-norm history, spike sentinel disarmed."""
    return {"gnorm_ema": jnp.float32(0.0), "steps": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# bank probes — fused into the trainer's single bookkeeping reduction
# ---------------------------------------------------------------------------

def saturation_leaves(bank: Dict[str, Any]) -> Optional[jnp.ndarray]:
    """Every site-direction's ``sat_frac`` telemetry scalar, concatenated
    (None for a telemetry-off bank).  Mirrors
    :func:`statsbank.bookkeeping_last`'s structure-agnostic walk."""
    leaves = [jnp.ravel(st["sat_frac"]) for e in bank.values()
              for st in e.values() if "sat_frac" in st]
    if not leaves:
        return None
    return leaves[0] if len(leaves) == 1 else jnp.concatenate(leaves)


def bank_probe(input_bank: Dict[str, Any], new_bank: Dict[str, Any],
               sat_threshold: float
               ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """``(cold_min, sat_margin)`` from ONE reduce_min.

    The cold row reads the INPUT bank (did any site bootstrap-refresh
    this step — the trainer's pre-existing probe); the saturation row
    reads the NEW bank (the health the step just measured, so a forced
    refresh clears the verdict the same step it lands).  Both rows pad to
    a common length with +inf and reduce in a single ``jnp.min(axis=1)``
    — the same one-reduction budget as the plain cold probe, which is
    what keeps the fp32+1 jaxpr invariant intact with the guard enabled.
    ``sat_margin`` is ``sat_threshold - max(sat_frac)``: negative means
    some site saturates past the threshold.  None when the bank carries
    no telemetry or the sentinel is disabled.
    """
    cold = statsbank.bookkeeping_last(input_bank)
    sat = saturation_leaves(new_bank) if sat_threshold > 0 else None
    if sat is None:
        return jnp.min(cold), None
    margin = jnp.float32(sat_threshold) - sat
    n = max(cold.shape[0], margin.shape[0])

    def pad(v):
        if v.shape[0] == n:
            return v
        return jnp.concatenate(
            [v, jnp.full((n - v.shape[0],), jnp.inf, jnp.float32)])

    mins = jnp.min(jnp.stack([pad(cold), pad(margin)]), axis=1)
    return mins[0], mins[1]


# ---------------------------------------------------------------------------
# verdict
# ---------------------------------------------------------------------------

def evaluate(cfg: GuardConfig, state: Dict[str, jnp.ndarray],
             loss: jnp.ndarray, grad_norm: jnp.ndarray,
             sat_margin: Optional[jnp.ndarray] = None,
             force_reject: Optional[jnp.ndarray] = None
             ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """One step's verdict: ``(flags, new_guard_state)``.

    Every input is a scalar the step already computed (loss and grad norm
    are post-psum/post-sync, so the verdict is mesh-global with no new
    collectives); every check is elementwise — zero added reductions.

    ``flags``:
      * ``ok``        — accept the param/optimizer update
      * ``ok_bank``   — accept the bank update (saturation exempted, see
                        :class:`GuardConfig`)
      * ``nonfinite`` / ``spike`` / ``sat`` / ``forced`` — the cause bits
        the host ladder reads to pick its rung.

    The carry only integrates accepted steps: on a rejected step the EMA
    and the warmup counter pass through unchanged (a NaN grad norm never
    touches the baseline; the step "didn't happen").
    """
    finite = jnp.logical_and(jnp.isfinite(loss), jnp.isfinite(grad_norm))
    nonfinite = jnp.logical_not(finite)
    armed = state["steps"] >= cfg.warmup
    spike = jnp.logical_and(
        jnp.logical_and(armed, finite),
        grad_norm > cfg.spike_factor * state["gnorm_ema"])
    sat = (sat_margin < 0.0) if sat_margin is not None else jnp.bool_(False)
    forced = (force_reject if force_reject is not None
              else jnp.bool_(False))
    bad_numerics = jnp.logical_or(jnp.logical_or(nonfinite, spike), forced)
    ok = jnp.logical_not(jnp.logical_or(bad_numerics, sat))
    ok_bank = jnp.logical_not(bad_numerics)

    # where() with the EMA fallback keeps a NaN grad_norm out of the
    # arithmetic even before the ok-gate (NaN * 0 is still NaN)
    gn_safe = jnp.where(finite, grad_norm, state["gnorm_ema"])
    first = state["steps"] == 0
    ema_next = jnp.where(
        first, gn_safe,
        cfg.ema_decay * state["gnorm_ema"] + (1.0 - cfg.ema_decay) * gn_safe)
    new_state = {
        "gnorm_ema": jnp.where(ok, ema_next, state["gnorm_ema"]),
        "steps": state["steps"] + ok.astype(jnp.float32),
    }
    flags = {"ok": ok, "ok_bank": ok_bank, "nonfinite": nonfinite,
             "spike": spike, "sat": sat, "forced": forced}
    return flags, new_state


def reject_update(ok: jnp.ndarray, new_tree: Any, old_tree: Any) -> Any:
    """The in-trace rejection: ``lax.cond`` select between the candidate
    and the pre-step tree.  Both branches are pure picks (no reductions,
    nothing recomputed), so a rejected step passes params/opt/bank through
    BIT-IDENTICALLY and the compiled program is the same either way."""
    return jax.lax.cond(ok, lambda p: p[0], lambda p: p[1],
                        (new_tree, old_tree))


def flag_metrics(flags: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Verdict bits as f32 metric leaves (host reads ``guard_ok < 0.5``).
    The inputs are already replicated-global scalars, so these need no
    psum on the mesh path."""
    return {f"guard_{k}": v.astype(jnp.float32) for k, v in flags.items()
            if k != "ok_bank"}


# ---------------------------------------------------------------------------
# host-side snapshot ring (escalation ladder rung 3)
# ---------------------------------------------------------------------------

class _CompressedLeaf:
    """Host-side S2FP8-compressed leaf: 1-byte payload + (alpha, beta)."""

    __slots__ = ("payload", "alpha", "beta", "shape", "dtype")

    def __init__(self, leaf: np.ndarray):
        t = s2fp8.quantize(leaf)
        self.payload = np.asarray(t.payload)
        self.alpha = float(t.alpha)
        self.beta = float(t.beta)
        self.shape = leaf.shape
        self.dtype = leaf.dtype

    def decode(self) -> np.ndarray:
        t = s2fp8.S2FP8Tensor(self.payload, jnp.float32(self.alpha),
                              jnp.float32(self.beta))
        return np.asarray(s2fp8.dequantize(t)).astype(self.dtype)


class SnapshotRing:
    """Last-good train state on the HOST, every k steps, bounded depth.

    ``push(step, tree)`` device_gets the carry (mesh-agnostic logical
    arrays, same as the checkpoint manager) and appends it; the ring keeps
    the newest ``size`` entries.  ``compress=True`` routes big f32 leaves
    through the S2FP8 codec (~4x smaller residency — the paper's format
    reused as an in-memory codec); scalars/small/int leaves stay raw so
    optimizer counters and bank bookkeeping restore bit-exact.  Note a
    compressed rollback is NOT bitwise for the big leaves — leave it off
    when the run must replay exactly (the default).
    """

    def __init__(self, size: int = 4, compress: bool = False):
        if size < 1:
            raise ValueError("snapshot ring size must be >= 1")
        self.size = int(size)
        self.compress = compress
        self._ring: List[Tuple[int, Any]] = []

    def __len__(self) -> int:
        return len(self._ring)

    def _encode(self, leaf: np.ndarray):
        if (self.compress and leaf.dtype == np.float32
                and leaf.size >= 4096 and leaf.ndim >= 2):
            return _CompressedLeaf(leaf)
        return leaf

    @staticmethod
    def _decode(leaf):
        return leaf.decode() if isinstance(leaf, _CompressedLeaf) else leaf

    def push(self, step: int, tree: Any) -> None:
        host = [np.asarray(x) for x in
                jax.device_get(jax.tree_util.tree_leaves(tree))]
        treedef = jax.tree_util.tree_structure(tree)
        leaves = [self._encode(x) for x in host]
        self._ring.append((int(step), (treedef, leaves)))
        if len(self._ring) > self.size:
            del self._ring[:len(self._ring) - self.size]

    def latest(self) -> Optional[Tuple[int, Any]]:
        """Newest ``(step, tree)`` — the state ENTERING ``step`` — or None."""
        if not self._ring:
            return None
        step, (treedef, leaves) = self._ring[-1]
        return step, jax.tree_util.tree_unflatten(
            treedef, [self._decode(x) for x in leaves])
