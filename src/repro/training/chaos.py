"""Deterministic fault injection — the harness that proves the guardrails.

``launch/train.py --chaos <spec>`` arms a :class:`ChaosPlan`; the tests
use it to demonstrate that every rung of the resilience ladder actually
fires (tests/test_chaos.py runs the full matrix).  Spec grammar::

    spec    := item ("," item)*
    item    := name "@" step ("x" count)? (":" param)?

    nan_grad@5          NaN into every grad leaf at step 5
    inf_loss@5          loss := +inf at step 5
    reject@5            force the guard verdict to reject at step 5
    nan_grad@5x3        ... at steps 5, 6 and 7 (count consecutive steps)
    saturating_bank@8   sat_frac := 1.0 on every telemetry leaf before
                        step 8 (stale/saturating carried stats)
    corrupt_ckpt@10     corrupt the newest on-disk checkpoint after step
                        10; param picks the flavor — :truncate (default),
                        :bitflip, :manifest (delete MANIFEST.json)
    slow_step@12:0.5    sleep 0.5 s inside step 12's timed span (straggler
                        for the watchdog; default 0.75 s)
    corrupt_batch@3     zero every int leaf / NaN every float leaf of
                        step 3's batch

Two delivery channels:

* **In-trace** (nan_grad / inf_loss / reject): the schedule travels as
  int32 scalars in ``batch["_chaos"]`` (the fault step, or -1).  The
  compiled program is therefore IDENTICAL across schedules — injection is
  a data-dependent ``where`` — which is what makes the acceptance test
  meaningful: a ``nan_grad@t`` run and a ``reject@t`` run execute the same
  executable and must end with bitwise-equal params.
* **Host-side** (saturating_bank / corrupt_ckpt / slow_step /
  corrupt_batch): hooks TrainLoop calls at the matching point in the
  step lifecycle.

Every event is SINGLE-FIRE: once delivered it is spent, so a rollback
that rewinds past step t replays t clean instead of re-injecting — the
property that lets a chaos run converge through its own faults.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# injectors delivered as batch["_chaos"] data (see module docstring)
IN_TRACE = ("nan_grad", "inf_loss", "reject")
HOST_SIDE = ("saturating_bank", "corrupt_ckpt", "slow_step", "corrupt_batch")
NAMES = IN_TRACE + HOST_SIDE


@dataclasses.dataclass
class ChaosEvent:
    name: str
    step: int
    param: Optional[str] = None
    fired: bool = False


def parse_spec(spec: str) -> List[ChaosEvent]:
    """Parse the grammar above; ``xN`` expands to N consecutive steps
    (consecutive faults are how the ladder is driven past its first rung)."""
    events: List[ChaosEvent] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"chaos item {item!r}: expected name@step")
        name, _, rest = item.partition("@")
        name = name.strip()
        if name not in NAMES:
            raise ValueError(f"unknown chaos injector {name!r} "
                             f"(known: {', '.join(NAMES)})")
        param = None
        if ":" in rest:
            rest, _, param = rest.partition(":")
        count = 1
        if "x" in rest:
            rest, _, cnt = rest.partition("x")
            count = int(cnt)
            if count < 1:
                raise ValueError(f"chaos item {item!r}: count must be >= 1")
        step = int(rest)
        if step < 0:
            raise ValueError(f"chaos item {item!r}: step must be >= 0")
        for k in range(count):
            events.append(ChaosEvent(name, step + k, param))
    return events


class ChaosPlan:
    """The armed schedule plus its fired-state; one per run."""

    def __init__(self, events: List[ChaosEvent]):
        self.events = list(events)

    @classmethod
    def parse(cls, spec: str) -> "ChaosPlan":
        return cls(parse_spec(spec))

    def has_in_trace(self) -> bool:
        return any(e.name in IN_TRACE for e in self.events)

    def _take(self, name: str, step: int) -> Optional[ChaosEvent]:
        """Pop-semantics lookup: the unfired event for (name, step), marked
        fired — the single-shot contract."""
        for e in self.events:
            if e.name == name and e.step == step and not e.fired:
                e.fired = True
                return e
        return None

    # -- in-trace channel ---------------------------------------------------
    def batch_fields(self, step: int) -> Dict[str, jnp.ndarray]:
        """The ``batch["_chaos"]`` payload for ``step``: every in-trace
        injector always present (constant pytree structure, so schedules
        never recompile), value = this step if it fires now else -1."""
        out = {}
        for name in IN_TRACE:
            e = self._take(name, step)
            out[name] = jnp.int32(step if e is not None else -1)
        return out

    # -- host-side hooks (TrainLoop lifecycle order) ------------------------
    def corrupt_batch(self, step: int, batch: Any) -> Any:
        if self._take("corrupt_batch", step) is None:
            return batch

        def garble(x):
            x = np.asarray(x)
            if np.issubdtype(x.dtype, np.floating):
                return jnp.full(x.shape, np.nan, x.dtype)
            return jnp.zeros(x.shape, x.dtype)

        return jax.tree_util.tree_map(garble, batch)

    def mutate_bank(self, step: int, bank: Optional[Dict[str, Any]]
                    ) -> Optional[Dict[str, Any]]:
        """saturating_bank: pin every ``sat_frac`` telemetry leaf at 1.0 —
        the signature of carried (alpha, beta) gone stale enough that the
        whole tensor lands past the format max.  Returns the mutated bank
        or None (no event / no bank / no telemetry leaves)."""
        if bank is None or self._take("saturating_bank", step) is None:
            return None
        mutated, hit = {}, False
        for site, entry in bank.items():
            mutated[site] = {}
            for d, st in entry.items():
                if "sat_frac" in st:
                    hit = True
                    mutated[site][d] = dict(
                        st, sat_frac=jnp.full_like(st["sat_frac"], 1.0))
                else:
                    mutated[site][d] = st
        return mutated if hit else None

    def sleep_s(self, step: int) -> float:
        e = self._take("slow_step", step)
        if e is None:
            return 0.0
        return float(e.param) if e.param else 0.75

    def maybe_sleep(self, step: int) -> float:
        dt = self.sleep_s(step)
        if dt > 0:
            time.sleep(dt)
        return dt

    def corrupt_checkpoint(self, step: int, manager
                           ) -> Optional[Dict[str, Any]]:
        """corrupt_ckpt: damage the newest COMMITTED checkpoint dir.
        Flavors: truncate the first leaf file (default), flip a byte
        (:bitflip — the checksum must catch it), or delete the manifest
        (:manifest).  Returns a description of what was damaged, None if
        no event fired or there is nothing on disk yet."""
        e = self._take("corrupt_ckpt", step)
        if e is None:
            return None
        manager.wait()                      # damage a finished write only
        latest = manager.latest_step()
        if latest is None:
            return None
        import os
        d = manager._step_dir(latest)
        flavor = e.param or "truncate"
        if flavor == "manifest":
            path = os.path.join(d, "MANIFEST.json")
            if os.path.exists(path):
                os.remove(path)
            return {"ckpt_step": latest, "flavor": flavor, "file": path}
        leaves = sorted(n for n in os.listdir(d) if n.endswith(".npy"))
        if not leaves:
            return None
        path = os.path.join(d, leaves[0])
        if flavor == "bitflip":
            with open(path, "r+b") as f:
                f.seek(-1, 2)
                byte = f.read(1)
                f.seek(-1, 2)
                f.write(bytes([byte[0] ^ 0xFF]))
        else:                               # truncate
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 1))
        return {"ckpt_step": latest, "flavor": flavor, "file": path}


def wrap_data_fn(data_fn: Callable[[int], Any], plan: Optional[ChaosPlan]
                 ) -> Callable[[int], Any]:
    """Attach the in-trace schedule (and batch corruption) to a data_fn.
    With ``plan=None`` the batch is returned untouched — the step then
    compiles WITHOUT the ``_chaos`` operand, so chaos-off runs carry zero
    overhead."""
    if plan is None:
        return data_fn

    def fn(step: int):
        batch = plan.corrupt_batch(step, data_fn(step))
        batch = dict(batch)
        batch["_chaos"] = plan.batch_fields(step)
        return batch

    return fn


# ---------------------------------------------------------------------------
# in-trace injection points (called from trainer.py inside the jitted step)
# ---------------------------------------------------------------------------

def split_batch(batch: Any) -> Tuple[Any, Optional[Dict[str, jnp.ndarray]]]:
    """Pop the ``_chaos`` schedule off the batch (None when absent)."""
    if not isinstance(batch, dict) or "_chaos" not in batch:
        return batch, None
    batch = dict(batch)
    return batch, batch.pop("_chaos")


def _fires(chaos: Optional[Dict[str, jnp.ndarray]], name: str, step
           ) -> Optional[jnp.ndarray]:
    if chaos is None or name not in chaos:
        return None
    return chaos[name] == step


def inject_loss(chaos, loss, step):
    f = _fires(chaos, "inf_loss", step)
    if f is None:
        return loss
    return jnp.where(f, jnp.full_like(loss, jnp.inf), loss)


def inject_grads(chaos, grads, step):
    f = _fires(chaos, "nan_grad", step)
    if f is None:
        return grads
    return jax.tree_util.tree_map(
        lambda g: jnp.where(f, jnp.full_like(g, jnp.nan), g), grads)


def forced_reject(chaos, step) -> Optional[jnp.ndarray]:
    return _fires(chaos, "reject", step)
