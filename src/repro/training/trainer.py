"""Train-step factory: numerics policy + loss scaling + master-FP32 update,
single-device or mesh-native.

Implements the paper's Figure 4 training procedure for any model whose loss
is a closure over a Policy, plus the FP8+LS baselines (Eq. 6: scale the loss
by lambda, unscale the grads) and S2FP8 statistics tracking (Fig. 5).

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit.  With ``mesh=...`` the SAME step body runs under
``shard_map``: the batch shards over the mesh's data axes
(parallel/sharding.py rules), gradients synchronize through
``core/collectives.grad_sync_axis`` — a plain f32 psum or the
S2FP8-compressed reduce-scatter/all-gather schedule (``grad_sync_mode``) —
and StatsBank refreshes all-reduce their (sum, max, count) partials so
bank statistics are GLOBAL.  ``mesh=None`` degrades exactly to the
single-device step (no collectives traced, bit-identical programs).

The distributed-mean convention: the local loss is scaled by
``1 / n_data_shards`` INSIDE the differentiated function, so per-shard
gradients are contributions to the global batch mean and the sync is a
pure SUM.  Folding the normalization into the loss (instead of pmean-ing
the grads) keeps every per-element cotangent numerically identical to the
single-device run — the property the bitwise parity suite in
tests/test_mesh_train.py pins down.  ``loss_fn`` must therefore return a
batch-MEAN loss (every loss in models/ does).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import io_callback
from jax.experimental.shard_map import shard_map

from repro.core import collectives
from repro.core import s2fp8
from repro.core import statsbank
from repro.core.policy import Policy
from repro.obs import telemetry as obs_telemetry
from repro.optim import optimizers as optim_mod
from repro.optim.optimizers import Optimizer, global_norm
from repro.parallel import sharding as shd
from repro.training import chaos as chaos_mod
from repro.training import fault
from repro.training import guard as guard_mod

GRAD_SYNC_MODES = ("f32", "s2fp8")
PARAM_SHARDING_MODES = ("replicated", "fsdp", "fsdp_q")


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    schedule: Callable, policy: Policy,
                    track_stats: bool = False,
                    grad_sync: Optional[Callable] = None,
                    stats: Optional[statsbank.StatsConfig] = None,
                    mesh=None, grad_sync_mode: str = "f32",
                    grad_sync_min_size: int = 1 << 16,
                    grad_sync_backend: Optional[str] = None,
                    telemetry: Optional[obs_telemetry.Telemetry] = None,
                    guard: Optional[guard_mod.GuardConfig] = None,
                    param_sharding: str = "replicated"):
    """loss_fn(params, batch, policy) -> (loss, metrics_dict).

    * fp8_ls mode: loss scaled by policy.loss_scale before grad, grads
      unscaled after (paper Eq. 6).
    * grad_sync: optional cross-replica synchronizer for the meshless
      step (legacy hook; under ``mesh=...`` synchronization is built in
      and this must be None).
    * track_stats: returns (mu, m, alpha, beta) of a probe gradient tensor
      (paper Fig. 5 evolution plots).
    * stats: a ``statsbank.StatsConfig`` enables the jit-carried StatsBank
      — the returned step grows a ``stats_state`` carry::

          (params, opt_state, stats_state, batch, step)
              -> (params, opt_state, stats_state, metrics)

      Every Policy truncation reuses its bank entry; the Eq. 3–4 stats
      reduction runs under ``lax.cond`` only on ``refresh_every`` steps
      (and the bootstrap step).  The bank is an extra differentiated
      argument whose gradient IS the refreshed bank (statsbank docstring),
      so the carry is pure data flow — jit/pjit/scan/remat safe.  Build
      the initial carry with ``statsbank.init_bank(loss_fn, params,
      batch, policy, cfg)``.
    * mesh: a ``jax.sharding.Mesh`` makes the step mesh-native: the body
      runs under ``shard_map`` with the batch sharded over the mesh's
      batch axes (``parallel/sharding.mesh_batch_specs``), params /
      optimizer state / bank replicated, gradients SUM-synced across the
      data shards, loss/metrics psum'd to global means, and — with
      ``stats`` — the bank's refresh reductions made global via
      ``statsbank.for_mesh``.  A 1-device mesh reproduces the meshless
      step bitwise; ``mesh=None`` builds the meshless step itself.
    * grad_sync_mode: ``"f32"`` — plain f32 psum per gradient leaf;
      ``"s2fp8"`` — S2FP8-compressed all-reduce (bf16 reduce-scatter +
      1-byte payload all-gather) for every leaf
      ``collectives.leaf_sync_route`` deems compressible, plain psum for
      the rest (small / integer / 0-d / non-divisible leaves).
      ``grad_sync_min_size`` is the compression floor (elements);
      ``grad_sync_backend`` picks the encode/decode numerics engine.
    * telemetry: a ``repro.obs.Telemetry`` drains the bank's per-site
      health metrics host-side via ``io_callback`` each step (requires
      ``stats`` with ``telemetry=True`` for non-empty metrics).  The
      drain is a pure elementwise extraction — it adds ZERO reduce
      primitives, preserving the steady-state jaxpr invariant.  Under a
      mesh it runs on the replicated post-shard_map bank, so each step
      emits exactly once.
    * guard: a ``training/guard.GuardConfig`` arms the in-step StepGuard —
      the step grows a ``guard_state`` carry (after the bank when both are
      on)::

          (params, opt_state[, stats_state], guard_state, batch, step)
              -> (params, opt_state[, stats_state], guard_state, metrics)

      Non-finite loss/grad, grad-norm-spike-vs-EMA, and (with a
      telemetry bank and ``sat_threshold > 0``) bank-saturation sentinels
      evaluate on scalars the step already computes; a bad verdict
      rejects the update in-trace via ``lax.cond`` (pre-step trees pass
      through bit-identically, no recompile) and raises ``guard_*``
      metric flags the TrainLoop escalation ladder acts on.  The
      saturation probe FUSES into the bank's existing bookkeeping ``min``
      (one ``[2, N]`` reduce), so the steady-state jaxpr reduction budget
      is unchanged: fp32 baseline + 1 outside ``lax.cond``.  Build the
      carry with ``guard.init_state()``.

    * param_sharding: how param and optimizer leaves live on the mesh.
      ``"replicated"`` (default) — every device holds full copies, as
      before.  ``"fsdp"`` — eligible leaves (float, rank >= 1, dim 0
      divisible by the fsdp axis size; ``sharding.fsdp_leaf_eligible``)
      shard dim 0 over the rule table's fsdp axis, ZeRO-3 style: the step
      all-gathers each leaf just-in-time INSIDE the differentiated loss
      (f32 wire), the gather's custom_vjp reduce-scatters the gradient
      back to the owner shard (psum over the other batch axes first; the
      compressed ``grad_sync_mode="s2fp8"`` path becomes just its bf16
      reduce-scatter leg, routed per leaf by ``leaf_sync_route`` on the
      FULL leaf shape), and the optimizer update runs shard-local —
      ``clip_by_global_norm`` sees the mixed global norm through the
      ``optim.optimizers.fsdp_grads`` scope.  ``"fsdp_q"`` — additionally
      streams payload-eligible leaves (2-D, consumed by ``Policy.dot``)
      as S2FP8 *payloads*: quantize-at-owner with leaf-global bank stats,
      1-byte all-gather straight into the payload GEMM B slot (no
      f32/bf16 copy of the leaf materializes; jaxpr-asserted in
      tests/test_mesh_train.py), other consumption of a wrapped leaf
      falls back to the f32 gather via ``FSDPPayloadParam.__jax_array__``.
      Non-replicated modes need ``mesh`` with an fsdp-carrying axis;
      ``fsdp_q`` additionally needs ``stats`` and a payload-GEMM policy.
      Updated params/opt leaves come OUT of the step sharded
      (``sharding.fsdp_param_specs``); checkpoints still gather to full
      host arrays, so save/restore stays topology-agnostic.

    A ``batch["_chaos"]`` entry (attached by ``training/chaos.py``'s
    data_fn wrapper) is popped off the batch inside the step and drives
    the in-trace fault injectors (NaN grads / Inf loss / forced reject)
    as pure data — every schedule runs the identical compiled program.

    The numerics backend (ref jnp vs fused Pallas kernels) rides on the
    policy: ``policy.backend`` is validated at Policy construction and
    resolved through core/backend.py inside each truncation.
    """
    scale = policy.loss_scale if policy.mode == "fp8_ls" else 1.0
    if stats is not None and policy.mode not in ("s2fp8", "s2fp8_e4m3"):
        raise ValueError(
            f"StatsBank requires an s2fp8-mode policy, got {policy.mode!r}")
    if telemetry is not None and stats is None:
        raise ValueError("telemetry requires a StatsBank (stats=...)")
    if grad_sync_mode not in GRAD_SYNC_MODES:
        raise ValueError(f"grad_sync_mode must be one of {GRAD_SYNC_MODES}, "
                         f"got {grad_sync_mode!r}")
    if mesh is not None and grad_sync is not None:
        raise ValueError("mesh=... builds its own gradient sync; the "
                         "legacy grad_sync callable must be None")

    if param_sharding not in PARAM_SHARDING_MODES:
        raise ValueError(f"param_sharding must be one of "
                         f"{PARAM_SHARDING_MODES}, got {param_sharding!r}")

    batch_axes = shd.mesh_batch_axes(mesh) if mesh is not None else ()
    axis_name = (None if not batch_axes
                 else batch_axes[0] if len(batch_axes) == 1 else batch_axes)
    n_shards = shd.mesh_batch_size(mesh) if mesh is not None else 1
    axis_sizes = ({a: mesh.shape[a] for a in batch_axes}
                  if mesh is not None else {})
    if stats is not None and mesh is not None:
        # mesh=None leaves the config untouched: a caller wrapping the
        # meshless step in their own pmap/shard_map may have set
        # axis_name themselves (the legacy grad_sync-hook path)
        stats = statsbank.for_mesh(stats, mesh)

    fsdp_axis = shd.fsdp_axis_entry(mesh) if mesh is not None else None
    gather_f32 = pay_info = None
    if param_sharding != "replicated":
        if mesh is None or fsdp_axis is None:
            raise ValueError(f"param_sharding={param_sharding!r} needs a "
                             f"mesh whose axes carry the rule table's "
                             f"'fsdp' logical axis")
        if param_sharding == "fsdp_q":
            if stats is None:
                raise ValueError("param_sharding='fsdp_q' quantizes at "
                                 "the owner with leaf-global bank stats — "
                                 "pass stats=StatsConfig(...)")
            if not policy.uses_payload_gemm:
                raise ValueError("param_sharding='fsdp_q' streams payload "
                                 "operands; the policy must route GEMMs "
                                 "through qdot_train (s2fp8 mode with "
                                 "gemm_mode='payload' or a pallas backend)")
        fsdp_n = mesh.shape[fsdp_axis]
        lead_axes = tuple(a for a in batch_axes if a != fsdp_axis)
        # one FSDPInfo + ONE custom_vjp gather per step factory, so the
        # custom_vjp identity (and the _qdot_banked cache key) is stable
        # across retraces
        base_info = collectives.FSDPInfo(
            fsdp_axis, fsdp_n, lead_axes, grad_sync_mode,
            grad_sync_min_size, grad_sync_backend)
        gather_f32 = collectives.make_param_gather(base_info)
        pay_info = base_info._replace(gather_f32=gather_f32)

    def _scale_loss(loss):
        # lambda-scaling (Eq. 6) and the DP mean-normalization both fold
        # INTO the differentiated function: per-shard grads come out as
        # contributions to the global batch mean, so the sync is a pure
        # sum and per-element cotangents match the single-device run.
        if scale != 1.0:
            loss = loss * scale
        if n_shards > 1:
            loss = loss / float(n_shards)
        return loss

    def _sync(grads, skip=None):
        if axis_name is not None:
            return collectives.grad_sync_axis(
                grads, axis_name, axis_sizes, mode=grad_sync_mode,
                min_size=grad_sync_min_size, backend=grad_sync_backend,
                skip=skip)
        if grad_sync is not None:
            return grad_sync(grads)
        return grads

    def _global(x):
        # scalar metrics are per-shard contributions (already 1/n-scaled):
        # psum them to the global mean; identity off-mesh.
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    def _drain_telemetry(bank, step):
        # ship the bank's telemetry leaves to the host sink; a pure
        # elementwise extraction (no reductions), ordered so records hit
        # the sink in step order.  Empty for telemetry-off banks.  Under
        # a mesh the callback must be PINNED to one device: the bank is
        # replicated, and an unplaced io_callback in a multi-device
        # program trips XLA's sharding propagation (and would otherwise
        # fire once per device).
        if telemetry is None:
            return
        state = obs_telemetry.telemetry_state(bank, step)
        if state:
            if mesh is None:
                io_callback(telemetry.drain, None, state, step,
                            ordered=True)
            else:
                # ordered effects are single-device only; records carry
                # their step, so cross-step ordering is recoverable
                io_callback(telemetry.drain, None, state, step,
                            sharding=jax.sharding.SingleDeviceSharding(
                                mesh.devices.flat[0]))

    def _make_reduce_metrics(int_div: int):
        # every metric leaf must leave the shard_map replicated (out_specs
        # P() with check_rep=False would silently report shard 0's local
        # value otherwise): float leaves psum to the global MEAN of the
        # per-shard means, integer leaves (counts) psum to the global SUM
        # — divided back by the shard count when the batch took the
        # replicated fallback (every shard counted the full batch).
        def _reduce_metrics(metrics):
            if axis_name is None:
                return metrics

            def red(v):
                v = jnp.asarray(v)
                if jnp.issubdtype(v.dtype, jnp.floating):
                    return _global(v / float(n_shards) if n_shards > 1
                                   else v)
                if jnp.issubdtype(v.dtype, jnp.integer):
                    s = _global(v)
                    return s // int_div if int_div > 1 else s
                if v.dtype == jnp.bool_:
                    # flags (diverged/overflow markers) reduce as ANY:
                    # a True on one shard must survive to the host
                    return _global(v.astype(jnp.int32)) > 0
                return v
            return jax.tree_util.tree_map(red, dict(metrics))

        return _reduce_metrics

    def _build_step(int_div: int = 1, elig=None, pay=None):
        reduce_metrics = _make_reduce_metrics(int_div)

        def _gather_params(p):
            # FSDP just-in-time gather, INSIDE the differentiated loss:
            # eligible leaves enter as dim-0 shards and leave either
            # through the f32 custom_vjp gather (grads reduce-scatter
            # back in its backward) or wrapped as FSDPPayloadParam (the
            # payload handoff Policy.dot/qdot_train consume — 1-byte
            # all-gather, same sharded-grad contract).
            if elig is None:
                return p

            def g(leaf, e, q):
                if not e:
                    return leaf
                if q:
                    return collectives.FSDPPayloadParam(leaf, pay_info)
                return gather_f32(leaf)

            return jax.tree_util.tree_map(g, p, elig, pay)

        def scaled_loss(params, batch):
            loss, metrics = loss_fn(_gather_params(params), batch, policy)
            return _scale_loss(loss), metrics

        def _core(params, opt_state, stats_state, guard_state, batch, step):
            # the chaos schedule (if armed) rides the batch as int32
            # scalars — popped here so loss_fn never sees it and every
            # schedule traces to the same program
            batch, chaos_fields = chaos_mod.split_batch(batch)
            if stats_state is None:
                (loss, metrics), grads = jax.value_and_grad(
                    scaled_loss, has_aux=True)(params, batch)
                new_bank = None
            else:
                def banked_loss(p, bank):
                    with statsbank.bind(bank, step, stats):
                        loss, metrics = loss_fn(_gather_params(p), batch,
                                                policy)
                    return _scale_loss(loss), metrics

                (loss, metrics), (grads, bank_cot) = jax.value_and_grad(
                    banked_loss, argnums=(0, 1), has_aux=True)(params,
                                                               stats_state)
                new_bank = statsbank.merge_updates(stats_state, bank_cot)
            if scale != 1.0:
                grads = jax.tree_util.tree_map(lambda g: g / scale, grads)
                loss = loss / scale
            # FSDP leaves exit value_and_grad already reduce-scattered to
            # the owner shard (the gather custom_vjp's backward) — the
            # replicated sync skips them
            grads = _sync(grads, skip=elig)
            metrics = reduce_metrics(metrics)
            loss = _global(loss)
            # in-trace fault injection points: data-driven `where`s on the
            # post-sync globals, so a fired injector perturbs exactly what
            # the guard must catch and nothing else
            loss = chaos_mod.inject_loss(chaos_fields, loss, step)
            grads = chaos_mod.inject_grads(chaos_fields, grads, step)

            sat_margin = None
            if stats_state is not None:
                # sites also refresh on bootstrap (last < 0), not just on
                # cadence; one O(n_sites) min over the concatenated
                # bookkeeping scalars — the single non-cond reduction the
                # bank step adds (asserted in tests/test_statsbank.py::
                # test_zero_stats_reductions_outside_cond).  With the
                # guard's saturation sentinel armed the probe widens to a
                # [2, N] stack (guard.bank_probe) — still ONE reduce_min.
                # The bank is replicated under the mesh (refreshes
                # all-reduce their partials), so no psum is needed here.
                thresh = guard.sat_threshold if guard is not None else 0.0
                cold_min, sat_margin = guard_mod.bank_probe(
                    stats_state, new_bank, thresh)
                metrics["stats_refreshed"] = jnp.maximum(
                    (step % stats.refresh_every == 0).astype(jnp.float32),
                    (cold_min < 0).astype(jnp.float32))

            lr = schedule(step)
            # the candidate update is computed UNconditionally (its clip
            # reductions stay outside lax.cond, matching the fp32
            # baseline's count); the guard's cond below is a pure select.
            # Under FSDP the update runs shard-local (ZeRO-3: opt state
            # only for owned shards) inside the fsdp_grads scope, so the
            # optimizer's clip — and the grad_norm metric below — psum
            # sharded-leaf sum-of-squares over the fsdp axis.
            norm_scope = (optim_mod.fsdp_grads(fsdp_axis, elig)
                          if elig is not None else contextlib.nullcontext())
            with norm_scope:
                new_params, new_opt = optimizer.update(grads, opt_state,
                                                       params, lr)
                # grads are post-sync (replicated-global under a mesh, or
                # owner shards under FSDP — the scope makes the norm
                # global either way), so no axis_name is needed here.
                grad_norm = global_norm(grads)
            out = dict(metrics)
            out["loss"] = loss
            out["grad_norm"] = grad_norm
            out["lr"] = lr
            if track_stats:
                probe = jax.tree_util.tree_leaves(grads)[-1]
                out["probe_stats"] = s2fp8.tensor_stats(probe)

            new_guard = None
            if guard is not None:
                flags, new_guard = guard_mod.evaluate(
                    guard, guard_state, loss, out["grad_norm"], sat_margin,
                    chaos_mod.forced_reject(chaos_fields, step))
                new_params, new_opt = guard_mod.reject_update(
                    flags["ok"], (new_params, new_opt),
                    (params, opt_state))
                if new_bank is not None:
                    new_bank = guard_mod.reject_update(
                        flags["ok_bank"], new_bank, stats_state)
                out.update(guard_mod.flag_metrics(flags))
            if new_bank is not None and mesh is None:
                # mesh path drains AFTER shard_map (replicated bank, one
                # callback) — see sharded_step
                _drain_telemetry(new_bank, step)
            return new_params, new_opt, new_bank, new_guard, out

        if stats is None and guard is None:
            def train_step(params, opt_state, batch, step):
                p, o, _, _, out = _core(params, opt_state, None, None,
                                        batch, step)
                return p, o, out
            return train_step
        if stats is None:
            def train_step_guarded(params, opt_state, guard_state, batch,
                                   step):
                p, o, _, g, out = _core(params, opt_state, None,
                                        guard_state, batch, step)
                return p, o, g, out
            return train_step_guarded
        if guard is None:
            def train_step_with_stats(params, opt_state, stats_state,
                                      batch, step):
                p, o, b, _, out = _core(params, opt_state, stats_state,
                                        None, batch, step)
                return p, o, b, out
            return train_step_with_stats

        def train_step_with_stats_guarded(params, opt_state, stats_state,
                                          guard_state, batch, step):
            p, o, b, g, out = _core(params, opt_state, stats_state,
                                    guard_state, batch, step)
            return p, o, b, g, out
        return train_step_with_stats_guarded

    if mesh is None:
        return _build_step()

    bodies = {}

    def sharded_step(*args):
        # specs resolve against the CONCRETE batch (divisibility guard
        # needs leaf shapes), so the shard_map is built per call — free
        # under jit, which retraces per input structure anyway.  When the
        # batch takes the replicated fallback, integer count metrics are
        # divided back by the shard count (every shard counted the full
        # batch).
        batch = args[-2]
        int_div = 1 if shd.batch_is_sharded(batch, mesh) else n_shards
        if param_sharding == "replicated":
            elig = pay = None
            key = int_div
        else:
            # eligibility resolves on the GLOBAL leaves out here — inside
            # the shard_map body dim 0 is already divided and the
            # predicate would be ambiguous.  The same predicate drives
            # train_step_specs, so specs and gathers stay in lockstep.
            elig = jax.tree_util.tree_map(
                lambda p: shd.fsdp_leaf_eligible(p.shape, p.dtype, fsdp_n),
                args[0])
            pay = jax.tree_util.tree_map(
                lambda p, e: bool(e and param_sharding == "fsdp_q"
                                  and p.ndim == 2), args[0], elig)
            key = (int_div, tuple(jax.tree_util.tree_leaves(elig)),
                   tuple(jax.tree_util.tree_leaves(pay)))
        if key not in bodies:
            step_fn = _build_step(int_div, elig, pay)

            def local_body(*a, _step_fn=step_fn):
                # inside shard_map every tensor is a local shard and the
                # mesh axes are manual: the models' logical-axis
                # annotations (sharding.shard) must not emit GSPMD
                # constraints here.
                with shd.suspend_rules():
                    return _step_fn(*a)

            bodies[key] = local_body
        in_specs, out_specs = shd.train_step_specs(
            batch, mesh, with_stats=stats is not None,
            with_guard=guard is not None, param_sharding=param_sharding,
            params=args[0], opt_state=args[1])
        out = shard_map(bodies[key], mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)(*args)
        if stats is not None:
            _drain_telemetry(out[2], args[-1])
        return out

    return sharded_step


def make_eval_step(loss_fn: Callable, policy: Policy):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, policy)
        return metrics
    return eval_step


class TrainLoop:
    """Host-side loop: prefetch, checkpoint-every-k, auto-resume, watchdog,
    and the resilience escalation ladder.

    Single-host here (1 or N local devices — the mesh-native step from
    ``make_train_step(mesh=...)`` drops in unchanged; jit lays the batch
    out per the step's shard_map specs).

    ``stats_bank``: the StatsBank carry for a step built with
    ``make_train_step(..., stats=...)``.  It is checkpointed alongside
    (params, opt_state) and restored by ``maybe_resume`` — a resumed run
    truncates with warm stats instead of silently bootstrapping cold.
    Checkpoints gather sharded leaves to host (checkpoint/manager.py), so
    a carry saved from an N-device mesh restores on any device count.

    ``guard_state``: the StepGuard carry for a step built with
    ``make_train_step(..., guard=...)``.  When the step's ``guard_ok``
    metric reports a trip (the update was already rejected IN-TRACE), the
    loop walks the escalation ladder, one rung per CONSECUTIVE trip:

        1. skip        — the rejection is the whole intervention
        2. force a StatsBank refresh (``statsbank.force_refresh``: every
           site bootstrap-refreshes next step, EMA re-seeded)
        3. roll back   — restore the newest :class:`guard.SnapshotRing`
           entry and rewind the step counter (deterministic data makes the
           replay exact; chaos injections are single-fire, so a replayed
           fault step runs clean)
        4. restore the newest VALID checkpoint (the manager quarantines
           corrupt ones on the way)

    Inapplicable rungs collapse (no bank -> 2 skipped; empty ring -> 3
    falls through to 4; no checkpoint -> keep skipping).  A clean step
    resets the rung.  Every intervention is emitted through ``sink`` as a
    structured event: ``guard_tripped``, ``stats_refresh_forced``,
    ``rollback``, ``checkpoint_restore`` (plus the manager's
    ``checkpoint_quarantined``).  ``max_interventions`` bounds a
    persistently-faulting run (RuntimeError instead of a silent loop).

    ``snapshot_every=k`` pushes (params, opt[, bank][, guard]) onto an
    in-memory :class:`guard.SnapshotRing` after every k-th clean step
    (``snapshot_compress=True`` routes big leaves through the S2FP8
    codec; lossy — leave off when replays must be bitwise).

    ``chaos``: a ``training/chaos.ChaosPlan`` — the loop calls its
    host-side hooks (bank mutation before the step, straggler sleep
    inside the timed span, checkpoint corruption after a save); the
    in-trace schedule must additionally ride the batch via
    ``chaos.wrap_data_fn``.

    ``watchdog_escalate_after=N``: N consecutive watchdog trips push a
    proactive snapshot and emit ``watchdog_escalated`` (0 disables; trips
    stay log-only).

    ``sink``: a ``repro.obs.MetricsSink`` receiving the loop's records —
    per-step ``"train_step"`` lines with span timings (data / device-
    sync'd step / checkpoint / refresh wall-clock) and ``"event"``
    records (watchdog trips, checkpoint saves, ladder interventions).
    Defaults to a ``ConsoleSink`` over ``run``'s ``print_fn``, which
    reproduces the historical log lines.
    """

    def __init__(self, train_step, params, opt_state, data_fn,
                 ckpt_manager=None, ckpt_every: int = 0,
                 log_every: int = 10, watchdog_factor: float = 3.0,
                 stats_bank=None, sink=None, guard_state=None,
                 chaos=None, snapshot_every: int = 0,
                 snapshot_ring: int = 4, snapshot_compress: bool = False,
                 watchdog_escalate_after: int = 0,
                 max_interventions: int = 32):
        donate = tuple(range(2 + (stats_bank is not None)
                             + (guard_state is not None)))
        self.train_step = jax.jit(train_step, donate_argnums=donate)
        self.params = params
        self.opt_state = opt_state
        self.stats_bank = stats_bank
        self.guard_state = guard_state
        self.data_fn = data_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.watchdog_factor = watchdog_factor
        self.watchdog_escalate_after = watchdog_escalate_after
        self.chaos = chaos
        self.snapshot_every = snapshot_every
        self.ring = (guard_mod.SnapshotRing(snapshot_ring,
                                            compress=snapshot_compress)
                     if snapshot_every else None)
        self.max_interventions = max_interventions
        self.sink = sink
        self.start_step = 0
        self.history = []

    # -- state tree plumbing ------------------------------------------------
    def _state_tree(self):
        """(params, opt[, bank][, guard]) — the checkpoint/snapshot unit."""
        tree = [self.params, self.opt_state]
        if self.stats_bank is not None:
            tree.append(self.stats_bank)
        if self.guard_state is not None:
            tree.append(self.guard_state)
        return tuple(tree)

    def _load_state(self, tree):
        tree = list(tree)
        self.params, self.opt_state = tree[0], tree[1]
        i = 2
        if self.stats_bank is not None:
            self.stats_bank = tree[i]
            i += 1
        if self.guard_state is not None:
            self.guard_state = tree[i]

    def _ckpt_tree(self):
        return self._state_tree()

    def _step_once(self, batch, step):
        args = [self.params, self.opt_state]
        if self.stats_bank is not None:
            args.append(self.stats_bank)
        if self.guard_state is not None:
            args.append(self.guard_state)
        out = self.train_step(*args, batch, jnp.int32(step))
        out = list(out)
        self.params, self.opt_state = out[0], out[1]
        i = 2
        if self.stats_bank is not None:
            self.stats_bank = out[i]
            i += 1
        if self.guard_state is not None:
            self.guard_state = out[i]
            i += 1
        return out[i]                        # metrics

    def maybe_resume(self):
        if self.ckpt is None:
            return
        try:
            # step=None walks newest -> oldest, quarantining corrupt dirs
            restored, latest = self.ckpt.restore(self._ckpt_tree())
        except FileNotFoundError:
            return
        self._load_state(restored)
        self.start_step = latest
        print(f"[trainer] resumed from step {latest}")

    # -- escalation ladder ---------------------------------------------------
    def _escalate(self, step: int, trips: int, sink) -> int:
        """One rung per consecutive trip; returns the next step to run
        (<= step means a rewind happened)."""
        if trips == 1:
            return step + 1                 # the in-trace rejection IS rung 1
        if trips == 2 and self.stats_bank is not None:
            self.stats_bank = statsbank.force_refresh(self.stats_bank)
            sink.emit({"kind": "event", "event": "stats_refresh_forced",
                       "step": step})
            return step + 1
        snap = self.ring.latest() if self.ring is not None else None
        if snap is not None:
            snap_step, tree = snap
            self._load_state(tree)
            sink.emit({"kind": "event", "event": "rollback", "step": step,
                       "to_step": snap_step,
                       "compressed": self.ring.compress})
            return snap_step
        if self.ckpt is not None:
            try:
                restored, s = self.ckpt.restore(self._ckpt_tree())
            except FileNotFoundError:
                return step + 1
            self._load_state(restored)
            sink.emit({"kind": "event", "event": "checkpoint_restore",
                       "step": step, "to_step": s})
            return s
        return step + 1

    def run(self, steps: int, print_fn=print):
        import time
        from repro.obs.sinks import ConsoleSink
        sink = self.sink if self.sink is not None else ConsoleSink(print_fn)
        watchdog = fault.Watchdog(self.watchdog_factor)
        wd_consecutive = 0
        trips = 0                # consecutive guard trips = ladder rung
        interventions = 0
        step = self.start_step
        while step < steps:
            t_fetch = time.perf_counter()
            batch = self.data_fn(step)
            data_s = time.perf_counter() - t_fetch
            if self.chaos is not None:
                mutated = self.chaos.mutate_bank(step, self.stats_bank)
                if mutated is not None:
                    self.stats_bank = mutated
            t0 = time.perf_counter()
            if self.chaos is not None:
                # straggler injection lands INSIDE the timed span so the
                # watchdog sees it
                self.chaos.maybe_sleep(step)
            metrics = self._step_once(batch, step)
            # device-sync the span: the step dispatches asynchronously, so
            # wall-clock without the barrier measures dispatch, not compute
            jax.block_until_ready((self.params, metrics))
            dt = time.perf_counter() - t0
            metrics = {k: (float(v) if hasattr(v, "item") and getattr(v, 'ndim', 1) == 0 else v)
                       for k, v in metrics.items()}
            # straggler watchdog: flag steps > factor x trailing median
            event = watchdog.observe(step, dt)
            if event is not None:
                sink.emit({"kind": "event", "event": "watchdog",
                           "step": step, **event})
                wd_consecutive += 1
                if self.watchdog_escalate_after and \
                        wd_consecutive >= self.watchdog_escalate_after:
                    if self.ring is not None:
                        self.ring.push(step + 1, self._state_tree())
                    sink.emit({"kind": "event", "event": "watchdog_escalated",
                               "step": step, "trips": wd_consecutive,
                               "snapshot": self.ring is not None})
                    wd_consecutive = 0
            else:
                wd_consecutive = 0
            self.history.append(metrics)
            tripped = (self.guard_state is not None
                       and metrics.get("guard_ok", 1.0) < 0.5)
            if tripped:
                trips += 1
                interventions += 1
                cause = ",".join(c for c in ("nonfinite", "spike", "sat",
                                             "forced")
                                 if metrics.get(f"guard_{c}", 0.0) >= 0.5)
                sink.emit({"kind": "event", "event": "guard_tripped",
                           "step": step, "trip": trips,
                           "cause": cause or "unknown",
                           "loss": metrics.get("loss"),
                           "grad_norm": metrics.get("grad_norm")})
                if interventions > self.max_interventions:
                    sink.flush()
                    raise RuntimeError(
                        f"StepGuard: {interventions} interventions without "
                        f"recovery (last trip at step {step}, cause "
                        f"{cause or 'unknown'}) — giving up")
                next_step = self._escalate(step, trips, sink)
                if next_step <= step:
                    trips = 0               # rewound: the ladder restarts
                step = next_step
                continue
            trips = 0
            if self.ring is not None and self.snapshot_every and \
                    (step + 1) % self.snapshot_every == 0:
                # the state ENTERING step+1 — last-good by construction
                # (this step just passed the guard)
                self.ring.push(step + 1, self._state_tree())
            t1 = time.perf_counter()
            saved = False
            if self.ckpt is not None and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, self._ckpt_tree(), blocking=False)
                saved = True
            ckpt_s = time.perf_counter() - t1
            if saved:
                sink.emit({"kind": "event", "event": "checkpoint_saved",
                           "step": step + 1, "blocking_s": ckpt_s,
                           "write_s": getattr(self.ckpt,
                                              "last_write_seconds", 0.0)})
            if self.chaos is not None and self.ckpt is not None:
                damage = self.chaos.corrupt_checkpoint(step, self.ckpt)
                if damage is not None:
                    sink.emit({"kind": "event",
                               "event": "chaos_corrupt_ckpt",
                               "step": step, **damage})
            if self.log_every and step % self.log_every == 0:
                refreshed = bool(metrics.get("stats_refreshed", 0.0))
                sink.emit({"kind": "train_step", "step": step,
                           "loss": metrics["loss"], "lr": metrics["lr"],
                           "grad_norm": metrics.get("grad_norm"),
                           "data_ms": data_s * 1e3, "step_ms": dt * 1e3,
                           "ckpt_ms": ckpt_s * 1e3 if saved else 0.0,
                           "refresh_ms": dt * 1e3 if refreshed else 0.0})
            step += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        sink.flush()
        return self.history
