"""Train-step factory: numerics policy + loss scaling + master-FP32 update,
single-device or mesh-native.

Implements the paper's Figure 4 training procedure for any model whose loss
is a closure over a Policy, plus the FP8+LS baselines (Eq. 6: scale the loss
by lambda, unscale the grads) and S2FP8 statistics tracking (Fig. 5).

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit.  With ``mesh=...`` the SAME step body runs under
``shard_map``: the batch shards over the mesh's data axes
(parallel/sharding.py rules), gradients synchronize through
``core/collectives.grad_sync_axis`` — a plain f32 psum or the
S2FP8-compressed reduce-scatter/all-gather schedule (``grad_sync_mode``) —
and StatsBank refreshes all-reduce their (sum, max, count) partials so
bank statistics are GLOBAL.  ``mesh=None`` degrades exactly to the
single-device step (no collectives traced, bit-identical programs).

The distributed-mean convention: the local loss is scaled by
``1 / n_data_shards`` INSIDE the differentiated function, so per-shard
gradients are contributions to the global batch mean and the sync is a
pure SUM.  Folding the normalization into the loss (instead of pmean-ing
the grads) keeps every per-element cotangent numerically identical to the
single-device run — the property the bitwise parity suite in
tests/test_mesh_train.py pins down.  ``loss_fn`` must therefore return a
batch-MEAN loss (every loss in models/ does).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import io_callback
from jax.experimental.shard_map import shard_map

from repro.core import collectives
from repro.core import s2fp8
from repro.core import statsbank
from repro.core.policy import Policy
from repro.obs import telemetry as obs_telemetry
from repro.optim.optimizers import Optimizer, global_norm
from repro.parallel import sharding as shd
from repro.training import fault

GRAD_SYNC_MODES = ("f32", "s2fp8")


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    schedule: Callable, policy: Policy,
                    track_stats: bool = False,
                    grad_sync: Optional[Callable] = None,
                    stats: Optional[statsbank.StatsConfig] = None,
                    mesh=None, grad_sync_mode: str = "f32",
                    grad_sync_min_size: int = 1 << 16,
                    grad_sync_backend: Optional[str] = None,
                    telemetry: Optional[obs_telemetry.Telemetry] = None):
    """loss_fn(params, batch, policy) -> (loss, metrics_dict).

    * fp8_ls mode: loss scaled by policy.loss_scale before grad, grads
      unscaled after (paper Eq. 6).
    * grad_sync: optional cross-replica synchronizer for the meshless
      step (legacy hook; under ``mesh=...`` synchronization is built in
      and this must be None).
    * track_stats: returns (mu, m, alpha, beta) of a probe gradient tensor
      (paper Fig. 5 evolution plots).
    * stats: a ``statsbank.StatsConfig`` enables the jit-carried StatsBank
      — the returned step grows a ``stats_state`` carry::

          (params, opt_state, stats_state, batch, step)
              -> (params, opt_state, stats_state, metrics)

      Every Policy truncation reuses its bank entry; the Eq. 3–4 stats
      reduction runs under ``lax.cond`` only on ``refresh_every`` steps
      (and the bootstrap step).  The bank is an extra differentiated
      argument whose gradient IS the refreshed bank (statsbank docstring),
      so the carry is pure data flow — jit/pjit/scan/remat safe.  Build
      the initial carry with ``statsbank.init_bank(loss_fn, params,
      batch, policy, cfg)``.
    * mesh: a ``jax.sharding.Mesh`` makes the step mesh-native: the body
      runs under ``shard_map`` with the batch sharded over the mesh's
      batch axes (``parallel/sharding.mesh_batch_specs``), params /
      optimizer state / bank replicated, gradients SUM-synced across the
      data shards, loss/metrics psum'd to global means, and — with
      ``stats`` — the bank's refresh reductions made global via
      ``statsbank.for_mesh``.  A 1-device mesh reproduces the meshless
      step bitwise; ``mesh=None`` builds the meshless step itself.
    * grad_sync_mode: ``"f32"`` — plain f32 psum per gradient leaf;
      ``"s2fp8"`` — S2FP8-compressed all-reduce (bf16 reduce-scatter +
      1-byte payload all-gather) for every leaf
      ``collectives.leaf_sync_route`` deems compressible, plain psum for
      the rest (small / integer / 0-d / non-divisible leaves).
      ``grad_sync_min_size`` is the compression floor (elements);
      ``grad_sync_backend`` picks the encode/decode numerics engine.
    * telemetry: a ``repro.obs.Telemetry`` drains the bank's per-site
      health metrics host-side via ``io_callback`` each step (requires
      ``stats`` with ``telemetry=True`` for non-empty metrics).  The
      drain is a pure elementwise extraction — it adds ZERO reduce
      primitives, preserving the steady-state jaxpr invariant.  Under a
      mesh it runs on the replicated post-shard_map bank, so each step
      emits exactly once.

    The numerics backend (ref jnp vs fused Pallas kernels) rides on the
    policy: ``policy.backend`` is validated at Policy construction and
    resolved through core/backend.py inside each truncation.
    """
    scale = policy.loss_scale if policy.mode == "fp8_ls" else 1.0
    if stats is not None and policy.mode not in ("s2fp8", "s2fp8_e4m3"):
        raise ValueError(
            f"StatsBank requires an s2fp8-mode policy, got {policy.mode!r}")
    if telemetry is not None and stats is None:
        raise ValueError("telemetry requires a StatsBank (stats=...)")
    if grad_sync_mode not in GRAD_SYNC_MODES:
        raise ValueError(f"grad_sync_mode must be one of {GRAD_SYNC_MODES}, "
                         f"got {grad_sync_mode!r}")
    if mesh is not None and grad_sync is not None:
        raise ValueError("mesh=... builds its own gradient sync; the "
                         "legacy grad_sync callable must be None")

    batch_axes = shd.mesh_batch_axes(mesh) if mesh is not None else ()
    axis_name = (None if not batch_axes
                 else batch_axes[0] if len(batch_axes) == 1 else batch_axes)
    n_shards = shd.mesh_batch_size(mesh) if mesh is not None else 1
    axis_sizes = ({a: mesh.shape[a] for a in batch_axes}
                  if mesh is not None else {})
    if stats is not None and mesh is not None:
        # mesh=None leaves the config untouched: a caller wrapping the
        # meshless step in their own pmap/shard_map may have set
        # axis_name themselves (the legacy grad_sync-hook path)
        stats = statsbank.for_mesh(stats, mesh)

    def _scale_loss(loss):
        # lambda-scaling (Eq. 6) and the DP mean-normalization both fold
        # INTO the differentiated function: per-shard grads come out as
        # contributions to the global batch mean, so the sync is a pure
        # sum and per-element cotangents match the single-device run.
        if scale != 1.0:
            loss = loss * scale
        if n_shards > 1:
            loss = loss / float(n_shards)
        return loss

    def scaled_loss(params, batch):
        loss, metrics = loss_fn(params, batch, policy)
        return _scale_loss(loss), metrics

    def _sync(grads):
        if axis_name is not None:
            return collectives.grad_sync_axis(
                grads, axis_name, axis_sizes, mode=grad_sync_mode,
                min_size=grad_sync_min_size, backend=grad_sync_backend)
        if grad_sync is not None:
            return grad_sync(grads)
        return grads

    def _global(x):
        # scalar metrics are per-shard contributions (already 1/n-scaled):
        # psum them to the global mean; identity off-mesh.
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    def _drain_telemetry(bank, step):
        # ship the bank's telemetry leaves to the host sink; a pure
        # elementwise extraction (no reductions), ordered so records hit
        # the sink in step order.  Empty for telemetry-off banks.  Under
        # a mesh the callback must be PINNED to one device: the bank is
        # replicated, and an unplaced io_callback in a multi-device
        # program trips XLA's sharding propagation (and would otherwise
        # fire once per device).
        if telemetry is None:
            return
        state = obs_telemetry.telemetry_state(bank, step)
        if state:
            if mesh is None:
                io_callback(telemetry.drain, None, state, step,
                            ordered=True)
            else:
                # ordered effects are single-device only; records carry
                # their step, so cross-step ordering is recoverable
                io_callback(telemetry.drain, None, state, step,
                            sharding=jax.sharding.SingleDeviceSharding(
                                mesh.devices.flat[0]))

    def _make_reduce_metrics(int_div: int):
        # every metric leaf must leave the shard_map replicated (out_specs
        # P() with check_rep=False would silently report shard 0's local
        # value otherwise): float leaves psum to the global MEAN of the
        # per-shard means, integer leaves (counts) psum to the global SUM
        # — divided back by the shard count when the batch took the
        # replicated fallback (every shard counted the full batch).
        def _reduce_metrics(metrics):
            if axis_name is None:
                return metrics

            def red(v):
                v = jnp.asarray(v)
                if jnp.issubdtype(v.dtype, jnp.floating):
                    return _global(v / float(n_shards) if n_shards > 1
                                   else v)
                if jnp.issubdtype(v.dtype, jnp.integer):
                    s = _global(v)
                    return s // int_div if int_div > 1 else s
                if v.dtype == jnp.bool_:
                    # flags (diverged/overflow markers) reduce as ANY:
                    # a True on one shard must survive to the host
                    return _global(v.astype(jnp.int32)) > 0
                return v
            return jax.tree_util.tree_map(red, dict(metrics))

        return _reduce_metrics

    def _finish(loss, metrics, grads, params, opt_state, step):
        lr = schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        out = dict(metrics)
        out["loss"] = loss
        # grads are post-sync (replicated-global under a mesh), so the
        # plain norm IS the global norm — no axis_name needed here.
        out["grad_norm"] = global_norm(grads)
        out["lr"] = lr
        if track_stats:
            probe = jax.tree_util.tree_leaves(grads)[-1]
            out["probe_stats"] = s2fp8.tensor_stats(probe)
        return new_params, new_opt, out

    def _build_step(int_div: int = 1):
        reduce_metrics = _make_reduce_metrics(int_div)

        def train_step(params, opt_state, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                scaled_loss, has_aux=True)(params, batch)
            if scale != 1.0:
                grads = jax.tree_util.tree_map(lambda g: g / scale, grads)
                loss = loss / scale
            grads = _sync(grads)
            return _finish(_global(loss), reduce_metrics(metrics), grads,
                           params, opt_state, step)

        def train_step_with_stats(params, opt_state, stats_state, batch,
                                  step):
            def banked_loss(p, bank):
                with statsbank.bind(bank, step, stats):
                    loss, metrics = loss_fn(p, batch, policy)
                return _scale_loss(loss), metrics

            (loss, metrics), (grads, bank_cot) = jax.value_and_grad(
                banked_loss, argnums=(0, 1), has_aux=True)(params,
                                                           stats_state)
            new_bank = statsbank.merge_updates(stats_state, bank_cot)
            grads = _sync(grads)
            metrics = reduce_metrics(metrics)
            # sites also refresh on bootstrap (last < 0), not just on
            # cadence; one O(n_sites) min over the concatenated
            # bookkeeping scalars — the single non-cond reduction the bank
            # step adds (asserted in tests/test_statsbank.py::
            # test_zero_stats_reductions_outside_cond).  bookkeeping_last
            # is structure-agnostic: plain truncation sites and
            # payload-GEMM nodes (qdot_train) alike.  The bank is
            # replicated under the mesh (refreshes all-reduce their
            # partials), so no psum is needed on the probe.
            cold = statsbank.bookkeeping_last(stats_state)
            metrics["stats_refreshed"] = jnp.maximum(
                (step % stats.refresh_every == 0).astype(jnp.float32),
                (jnp.min(cold) < 0).astype(jnp.float32))
            if mesh is None:
                # mesh path drains AFTER shard_map (replicated bank, one
                # callback) — see sharded_step
                _drain_telemetry(new_bank, step)
            new_params, new_opt, out = _finish(_global(loss), metrics,
                                               grads, params, opt_state,
                                               step)
            return new_params, new_opt, new_bank, out

        return train_step if stats is None else train_step_with_stats

    if mesh is None:
        return _build_step()

    bodies = {}

    def sharded_step(*args):
        # specs resolve against the CONCRETE batch (divisibility guard
        # needs leaf shapes), so the shard_map is built per call — free
        # under jit, which retraces per input structure anyway.  When the
        # batch takes the replicated fallback, integer count metrics are
        # divided back by the shard count (every shard counted the full
        # batch).
        batch = args[-2]
        int_div = 1 if shd.batch_is_sharded(batch, mesh) else n_shards
        if int_div not in bodies:
            step_fn = _build_step(int_div)

            def local_body(*a, _step_fn=step_fn):
                # inside shard_map every tensor is a local shard and the
                # mesh axes are manual: the models' logical-axis
                # annotations (sharding.shard) must not emit GSPMD
                # constraints here.
                with shd.suspend_rules():
                    return _step_fn(*a)

            bodies[int_div] = local_body
        in_specs, out_specs = shd.train_step_specs(
            batch, mesh, with_stats=stats is not None)
        out = shard_map(bodies[int_div], mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)(*args)
        if stats is not None:
            _drain_telemetry(out[2], args[-1])
        return out

    return sharded_step


def make_eval_step(loss_fn: Callable, policy: Policy):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, policy)
        return metrics
    return eval_step


class TrainLoop:
    """Host-side loop: prefetch, checkpoint-every-k, auto-resume, watchdog.

    Single-host here (1 or N local devices — the mesh-native step from
    ``make_train_step(mesh=...)`` drops in unchanged; jit lays the batch
    out per the step's shard_map specs); the multi-host story is in
    training/fault.py.

    ``stats_bank``: the StatsBank carry for a step built with
    ``make_train_step(..., stats=...)``.  It is checkpointed alongside
    (params, opt_state) and restored by ``maybe_resume`` — a resumed run
    truncates with warm stats instead of silently bootstrapping cold.
    Checkpoints gather sharded leaves to host (checkpoint/manager.py), so
    a carry saved from an N-device mesh restores on any device count.

    ``sink``: a ``repro.obs.MetricsSink`` receiving the loop's records —
    per-step ``"train_step"`` lines with span timings (data / device-
    sync'd step / checkpoint / refresh wall-clock) and ``"event"``
    records (watchdog trips, checkpoint saves).  Defaults to a
    ``ConsoleSink`` over ``run``'s ``print_fn``, which reproduces the
    historical log lines.
    """

    def __init__(self, train_step, params, opt_state, data_fn,
                 ckpt_manager=None, ckpt_every: int = 0,
                 log_every: int = 10, watchdog_factor: float = 3.0,
                 stats_bank=None, sink=None):
        donate = (0, 1) if stats_bank is None else (0, 1, 2)
        self.train_step = jax.jit(train_step, donate_argnums=donate)
        self.params = params
        self.opt_state = opt_state
        self.stats_bank = stats_bank
        self.data_fn = data_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.watchdog_factor = watchdog_factor
        self.sink = sink
        self.start_step = 0
        self.history = []

    def _ckpt_tree(self):
        if self.stats_bank is None:
            return (self.params, self.opt_state)
        return (self.params, self.opt_state, self.stats_bank)

    def maybe_resume(self):
        if self.ckpt is None:
            return
        latest = self.ckpt.latest_step()
        if latest is not None:
            restored, _ = self.ckpt.restore(self._ckpt_tree(), latest)
            if self.stats_bank is None:
                self.params, self.opt_state = restored
            else:
                self.params, self.opt_state, self.stats_bank = restored
            self.start_step = latest
            print(f"[trainer] resumed from step {latest}")

    def run(self, steps: int, print_fn=print):
        import time
        from repro.obs.sinks import ConsoleSink
        sink = self.sink if self.sink is not None else ConsoleSink(print_fn)
        watchdog = fault.Watchdog(self.watchdog_factor)
        for step in range(self.start_step, steps):
            t_fetch = time.perf_counter()
            batch = self.data_fn(step)
            data_s = time.perf_counter() - t_fetch
            t0 = time.perf_counter()
            if self.stats_bank is None:
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch, jnp.int32(step))
            else:
                self.params, self.opt_state, self.stats_bank, metrics = \
                    self.train_step(self.params, self.opt_state,
                                    self.stats_bank, batch, jnp.int32(step))
            # device-sync the span: the step dispatches asynchronously, so
            # wall-clock without the barrier measures dispatch, not compute
            jax.block_until_ready((self.params, metrics))
            dt = time.perf_counter() - t0
            metrics = {k: (float(v) if hasattr(v, "item") and getattr(v, 'ndim', 1) == 0 else v)
                       for k, v in metrics.items()}
            # straggler watchdog: flag steps > factor x trailing median
            event = watchdog.observe(step, dt)
            if event is not None:
                sink.emit({"kind": "event", "event": "watchdog",
                           "step": step, **event})
            self.history.append(metrics)
            t1 = time.perf_counter()
            saved = False
            if self.ckpt is not None and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, self._ckpt_tree(), blocking=False)
                saved = True
            ckpt_s = time.perf_counter() - t1
            if saved:
                sink.emit({"kind": "event", "event": "checkpoint_saved",
                           "step": step + 1, "blocking_s": ckpt_s,
                           "write_s": getattr(self.ckpt,
                                              "last_write_seconds", 0.0)})
            if self.log_every and step % self.log_every == 0:
                refreshed = bool(metrics.get("stats_refreshed", 0.0))
                sink.emit({"kind": "train_step", "step": step,
                           "loss": metrics["loss"], "lr": metrics["lr"],
                           "grad_norm": metrics.get("grad_norm"),
                           "data_ms": data_s * 1e3, "step_ms": dt * 1e3,
                           "ckpt_ms": ckpt_s * 1e3 if saved else 0.0,
                           "refresh_ms": dt * 1e3 if refreshed else 0.0})
        if self.ckpt is not None:
            self.ckpt.wait()
        sink.flush()
        return self.history
