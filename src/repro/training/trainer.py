"""Train-step factory: numerics policy + loss scaling + master-FP32 update.

Implements the paper's Figure 4 training procedure for any model whose loss
is a closure over a Policy, plus the FP8+LS baselines (Eq. 6: scale the loss
by lambda, unscale the grads) and S2FP8 statistics tracking (Fig. 5).

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with sharded in/out specs (launch/train.py) or plain
CPU execution (examples/, tests/).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import s2fp8
from repro.core.policy import Policy
from repro.optim.optimizers import Optimizer, global_norm


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    schedule: Callable, policy: Policy,
                    track_stats: bool = False,
                    grad_sync: Optional[Callable] = None):
    """loss_fn(params, batch, policy) -> (loss, metrics_dict).

    * fp8_ls mode: loss scaled by policy.loss_scale before grad, grads
      unscaled after (paper Eq. 6).
    * grad_sync: optional cross-replica synchronizer (e.g. the S2FP8-
      compressed DP all-reduce in core/collectives.py); under pjit the
      default all-reduce is inserted by GSPMD instead.
    * track_stats: returns (mu, m, alpha, beta) of a probe gradient tensor
      (paper Fig. 5 evolution plots).

    The numerics backend (ref jnp vs fused Pallas kernels) rides on the
    policy: ``policy.backend`` is validated at Policy construction and
    resolved through core/backend.py inside each truncation.
    """
    scale = policy.loss_scale if policy.mode == "fp8_ls" else 1.0

    def scaled_loss(params, batch):
        loss, metrics = loss_fn(params, batch, policy)
        return loss * scale, metrics

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            scaled_loss, has_aux=True)(params, batch)
        if scale != 1.0:
            grads = jax.tree_util.tree_map(lambda g: g / scale, grads)
            loss = loss / scale
        if grad_sync is not None:
            grads = grad_sync(grads)
        lr = schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        out = dict(metrics)
        out["loss"] = loss
        out["grad_norm"] = global_norm(grads)
        out["lr"] = lr
        if track_stats:
            probe = jax.tree_util.tree_leaves(grads)[-1]
            out["probe_stats"] = s2fp8.tensor_stats(probe)
        return new_params, new_opt, out

    return train_step


def make_eval_step(loss_fn: Callable, policy: Policy):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, policy)
        return metrics
    return eval_step


class TrainLoop:
    """Host-side loop: prefetch, checkpoint-every-k, auto-resume, watchdog.

    Single-host here; the multi-host story is in training/fault.py.
    """

    def __init__(self, train_step, params, opt_state, data_fn,
                 ckpt_manager=None, ckpt_every: int = 0,
                 log_every: int = 10, watchdog_factor: float = 3.0):
        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))
        self.params = params
        self.opt_state = opt_state
        self.data_fn = data_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.watchdog_factor = watchdog_factor
        self.start_step = 0
        self.history = []

    def maybe_resume(self):
        if self.ckpt is None:
            return
        latest = self.ckpt.latest_step()
        if latest is not None:
            (self.params, self.opt_state), _ = self.ckpt.restore(
                (self.params, self.opt_state), latest)
            self.start_step = latest
            print(f"[trainer] resumed from step {latest}")

    def run(self, steps: int, print_fn=print):
        import time
        times = []
        for step in range(self.start_step, steps):
            batch = self.data_fn(step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch, jnp.int32(step))
            metrics = {k: (float(v) if hasattr(v, "item") and getattr(v, 'ndim', 1) == 0 else v)
                       for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            # straggler watchdog: flag steps > factor x trailing median
            if len(times) >= 8:
                med = sorted(times[-32:])[len(times[-32:]) // 2]
                if dt > self.watchdog_factor * med:
                    print_fn(f"[watchdog] step {step} took {dt:.3f}s "
                             f"(median {med:.3f}s) — straggler suspected")
            times.append(dt)
            self.history.append(metrics)
            if self.log_every and step % self.log_every == 0:
                print_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                         f"lr {metrics['lr']:.2e} t {dt*1e3:.0f}ms")
            if self.ckpt is not None and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, (self.params, self.opt_state),
                               blocking=False)
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.history
