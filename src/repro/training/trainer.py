"""Train-step factory: numerics policy + loss scaling + master-FP32 update.

Implements the paper's Figure 4 training procedure for any model whose loss
is a closure over a Policy, plus the FP8+LS baselines (Eq. 6: scale the loss
by lambda, unscale the grads) and S2FP8 statistics tracking (Fig. 5).

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with sharded in/out specs (launch/train.py) or plain
CPU execution (examples/, tests/).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import s2fp8
from repro.core import statsbank
from repro.core.policy import Policy
from repro.optim.optimizers import Optimizer, global_norm


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    schedule: Callable, policy: Policy,
                    track_stats: bool = False,
                    grad_sync: Optional[Callable] = None,
                    stats: Optional[statsbank.StatsConfig] = None):
    """loss_fn(params, batch, policy) -> (loss, metrics_dict).

    * fp8_ls mode: loss scaled by policy.loss_scale before grad, grads
      unscaled after (paper Eq. 6).
    * grad_sync: optional cross-replica synchronizer (e.g. the S2FP8-
      compressed DP all-reduce in core/collectives.py); under pjit the
      default all-reduce is inserted by GSPMD instead.
    * track_stats: returns (mu, m, alpha, beta) of a probe gradient tensor
      (paper Fig. 5 evolution plots).
    * stats: a ``statsbank.StatsConfig`` enables the jit-carried StatsBank
      — the returned step grows a ``stats_state`` carry::

          (params, opt_state, stats_state, batch, step)
              -> (params, opt_state, stats_state, metrics)

      Every Policy truncation reuses its bank entry; the Eq. 3–4 stats
      reduction runs under ``lax.cond`` only on ``refresh_every`` steps
      (and the bootstrap step).  The bank is an extra differentiated
      argument whose gradient IS the refreshed bank (statsbank docstring),
      so the carry is pure data flow — jit/pjit/scan/remat safe.  Build
      the initial carry with ``statsbank.init_bank(loss_fn, params,
      batch, policy, cfg)``.

    The numerics backend (ref jnp vs fused Pallas kernels) rides on the
    policy: ``policy.backend`` is validated at Policy construction and
    resolved through core/backend.py inside each truncation.
    """
    scale = policy.loss_scale if policy.mode == "fp8_ls" else 1.0
    if stats is not None and policy.mode not in ("s2fp8", "s2fp8_e4m3"):
        raise ValueError(
            f"StatsBank requires an s2fp8-mode policy, got {policy.mode!r}")

    def scaled_loss(params, batch):
        loss, metrics = loss_fn(params, batch, policy)
        return loss * scale, metrics

    def _finish(loss, metrics, grads, params, opt_state, step):
        lr = schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        out = dict(metrics)
        out["loss"] = loss
        out["grad_norm"] = global_norm(grads)
        out["lr"] = lr
        if track_stats:
            probe = jax.tree_util.tree_leaves(grads)[-1]
            out["probe_stats"] = s2fp8.tensor_stats(probe)
        return new_params, new_opt, out

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            scaled_loss, has_aux=True)(params, batch)
        if scale != 1.0:
            grads = jax.tree_util.tree_map(lambda g: g / scale, grads)
            loss = loss / scale
        if grad_sync is not None:
            grads = grad_sync(grads)
        return _finish(loss, metrics, grads, params, opt_state, step)

    if stats is None:
        return train_step

    def train_step_with_stats(params, opt_state, stats_state, batch, step):
        def banked_loss(p, bank):
            with statsbank.bind(bank, step, stats):
                loss, metrics = loss_fn(p, batch, policy)
            return loss, metrics

        (loss, metrics), (grads, bank_cot) = jax.value_and_grad(
            banked_loss, argnums=(0, 1), has_aux=True)(params, stats_state)
        new_bank = statsbank.merge_updates(stats_state, bank_cot)
        if grad_sync is not None:
            grads = grad_sync(grads)
        metrics = dict(metrics)
        # sites also refresh on bootstrap (last < 0), not just on cadence;
        # one O(n_sites) min over the concatenated bookkeeping scalars —
        # the single non-cond reduction the bank step adds (asserted in
        # tests/test_statsbank.py::test_zero_stats_reductions_outside_cond).
        # bookkeeping_last is structure-agnostic: plain truncation sites
        # and payload-GEMM nodes (qdot_train) alike.
        cold = statsbank.bookkeeping_last(stats_state)
        metrics["stats_refreshed"] = jnp.maximum(
            (step % stats.refresh_every == 0).astype(jnp.float32),
            (jnp.min(cold) < 0).astype(jnp.float32))
        new_params, new_opt, out = _finish(loss, metrics, grads, params,
                                           opt_state, step)
        return new_params, new_opt, new_bank, out

    return train_step_with_stats


def make_eval_step(loss_fn: Callable, policy: Policy):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, policy)
        return metrics
    return eval_step


class TrainLoop:
    """Host-side loop: prefetch, checkpoint-every-k, auto-resume, watchdog.

    Single-host here; the multi-host story is in training/fault.py.

    ``stats_bank``: the StatsBank carry for a step built with
    ``make_train_step(..., stats=...)``.  It is checkpointed alongside
    (params, opt_state) and restored by ``maybe_resume`` — a resumed run
    truncates with warm stats instead of silently bootstrapping cold.
    """

    def __init__(self, train_step, params, opt_state, data_fn,
                 ckpt_manager=None, ckpt_every: int = 0,
                 log_every: int = 10, watchdog_factor: float = 3.0,
                 stats_bank=None):
        donate = (0, 1) if stats_bank is None else (0, 1, 2)
        self.train_step = jax.jit(train_step, donate_argnums=donate)
        self.params = params
        self.opt_state = opt_state
        self.stats_bank = stats_bank
        self.data_fn = data_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.watchdog_factor = watchdog_factor
        self.start_step = 0
        self.history = []

    def _ckpt_tree(self):
        if self.stats_bank is None:
            return (self.params, self.opt_state)
        return (self.params, self.opt_state, self.stats_bank)

    def maybe_resume(self):
        if self.ckpt is None:
            return
        latest = self.ckpt.latest_step()
        if latest is not None:
            restored, _ = self.ckpt.restore(self._ckpt_tree(), latest)
            if self.stats_bank is None:
                self.params, self.opt_state = restored
            else:
                self.params, self.opt_state, self.stats_bank = restored
            self.start_step = latest
            print(f"[trainer] resumed from step {latest}")

    def run(self, steps: int, print_fn=print):
        import time
        times = []
        for step in range(self.start_step, steps):
            batch = self.data_fn(step)
            t0 = time.perf_counter()
            if self.stats_bank is None:
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch, jnp.int32(step))
            else:
                self.params, self.opt_state, self.stats_bank, metrics = \
                    self.train_step(self.params, self.opt_state,
                                    self.stats_bank, batch, jnp.int32(step))
            metrics = {k: (float(v) if hasattr(v, "item") and getattr(v, 'ndim', 1) == 0 else v)
                       for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            # straggler watchdog: flag steps > factor x trailing median
            if len(times) >= 8:
                med = sorted(times[-32:])[len(times[-32:]) // 2]
                if dt > self.watchdog_factor * med:
                    print_fn(f"[watchdog] step {step} took {dt:.3f}s "
                             f"(median {med:.3f}s) — straggler suspected")
            times.append(dt)
            self.history.append(metrics)
            if self.log_every and step % self.log_every == 0:
                print_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                         f"lr {metrics['lr']:.2e} t {dt*1e3:.0f}ms")
            if self.ckpt is not None and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, self._ckpt_tree(), blocking=False)
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.history
