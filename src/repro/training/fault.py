"""Straggler detection for the training loop.

The fault-tolerance design contract that used to live in this docstring
(detect-fast/restart-fast, atomic checkpoints, deterministic data,
elastic re-sharding) is now implemented end to end and documented as the
"Resilience dataflow" section of ``kernels/README.md`` — sentinel ->
escalation ladder -> snapshot rollback -> checkpoint restore, plus the
chaos spec grammar that exercises every rung.  The moving parts:

  * in-step sentinels + snapshot ring . training/guard.py
  * escalation ladder ................ training/trainer.py (TrainLoop)
  * hardened checkpoint I/O .......... checkpoint/manager.py
  * fault injection harness .......... training/chaos.py

This module keeps the host-side straggler detector the loop feeds with
per-step wall times.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional


class Watchdog:
    """Per-step wall-time straggler detector.

    ``observe(step, dt)`` compares ``dt`` against ``factor`` times the
    median of the trailing ``window`` step times seen BEFORE this step
    (the current step must not dilute its own baseline), once at least
    ``min_history`` steps have accumulated.  Returns an event dict
    (``dt_s`` / ``median_s`` / ``factor``) on a trip, None otherwise —
    TrainLoop forwards trips to its metrics sink as ``"watchdog"``
    events (and, with ``watchdog_escalate_after``, escalates N
    consecutive trips into a proactive snapshot).  Trips are recorded in
    ``events`` for post-hoc inspection.

    ``times`` is a bounded deque (maxlen ``window``): the baseline only
    ever needs the trailing window, and an unbounded list on a
    million-step run is a slow memory leak.  The even-window median is
    the true midpoint average, not the upper-middle element.
    """

    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_history: int = 8):
        if factor <= 0:
            raise ValueError("watchdog factor must be > 0")
        if window < 1:
            raise ValueError("watchdog window must be >= 1")
        self.factor = float(factor)
        self.window = int(window)
        # the deque caps history at window, so a larger min_history would
        # never be reached — clamp it
        self.min_history = min(int(min_history), self.window)
        self.times: Deque[float] = deque(maxlen=self.window)
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> Optional[Dict[str, float]]:
        event = None
        if len(self.times) >= self.min_history:
            trail = sorted(self.times)      # already capped at window
            n = len(trail)
            if n % 2:
                med = trail[n // 2]
            else:
                med = 0.5 * (trail[n // 2 - 1] + trail[n // 2])
            if dt > self.factor * med:
                event = {"step": step, "dt_s": float(dt),
                         "median_s": float(med), "factor": self.factor}
                self.events.append(event)
        self.times.append(float(dt))
        return event
