"""Fault tolerance & straggler mitigation at 1000+ node scale — the design
contract implemented by the pieces in this repo.

1. Checkpoint/restart (implemented: checkpoint/manager.py)
   - atomic rename-commit; restore scans for the newest COMPLETE step.
   - per-leaf .npy shards: on a pod, each process writes its addressable
     shards; restore is mesh-shape-agnostic (leaves are logical arrays),
     so a job restarted on a DIFFERENT topology (elastic downscale after
     losing a pod) restores the same model — this is why checkpoints store
     unsharded leaves rather than device-local buffers.
   - async flush with single-slot backpressure: the train loop never waits
     on disk unless a previous write is still in flight.
   - optional S2FP8 compression (the paper's format reused as a storage
     codec) cuts checkpoint bytes ~4x, which at 1T params is the difference
     between a 4 TB and a 1 TB restart read.

2. Deterministic data (implemented: data/synthetic.py)
   - batches are pure functions of (seed, step): restart is bit-exact and
     any host can compute any slice, which makes both restart and elastic
     re-sharding trivial (no data-loader state to checkpoint).

3. Straggler mitigation (implemented: training/trainer.py watchdog)
   - per-step wall-time watchdog flags outliers vs. the trailing median.
   - at scale the launcher's response is: mark the slow host, restart the
     job from the last checkpoint excluding it (elastic mesh: the restore
     path above already handles the new topology). Synchronous SPMD has no
     per-step work stealing — the correct production lever is fast detect
     + fast restart, which the atomic-checkpoint + stateless-data design
     optimizes for (restart cost = one checkpoint read, no data replay).

4. Node failure during a step
   - jax distributed runtime surfaces a failed collective as a program
     error; the launcher (launch/train.py --resume auto) relaunches and
     auto-resumes from the newest complete checkpoint. Checkpoint cadence
     bounds lost work to ckpt_every steps; with async flush the cadence
     can be tight (every few minutes) without step-time cost.

5. Gradient-traffic reduction under degraded ICI (core/collectives.py)
   - the S2FP8-compressed all-gather leg cuts DP sync bytes ~2.7x; under
     a degraded link the same code path is the mitigation knob (enable
     compression, shrink the sync volume).
"""
from __future__ import annotations

from typing import Dict, List, Optional


class Watchdog:
    """Per-step wall-time straggler detector (design point 3 above).

    ``observe(step, dt)`` compares ``dt`` against ``factor`` times the
    median of the trailing ``window`` step times seen BEFORE this step
    (the current step must not dilute its own baseline), once at least
    ``min_history`` steps have accumulated.  Returns an event dict
    (``dt_s`` / ``median_s`` / ``factor``) on a trip, None otherwise —
    TrainLoop forwards trips to its metrics sink as ``"watchdog"``
    events.  Trips are recorded in ``events`` for post-hoc inspection."""

    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_history: int = 8):
        if factor <= 0:
            raise ValueError("watchdog factor must be > 0")
        self.factor = float(factor)
        self.window = int(window)
        self.min_history = int(min_history)
        self.times: List[float] = []
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> Optional[Dict[str, float]]:
        event = None
        if len(self.times) >= self.min_history:
            trail = sorted(self.times[-self.window:])
            med = trail[len(trail) // 2]
            if dt > self.factor * med:
                event = {"step": step, "dt_s": float(dt),
                         "median_s": float(med), "factor": self.factor}
                self.events.append(event)
        self.times.append(float(dt))
        return event
