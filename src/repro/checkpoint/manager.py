"""Fault-tolerant checkpoint manager.

Design (scales to multi-host; exercised single-host here):

  * atomic: write to ``step_<N>.tmp/`` then ``os.rename`` — a crash mid-write
    never corrupts the latest checkpoint; restore scans for the newest
    COMPLETE step (rename is the commit point).
  * sharded: each leaf is its own ``.npy``; on a pod each process writes its
    addressable shards (process-id suffix slot is in the filename schema).
  * logical arrays: leaves are saved unsharded (``jax.device_get``
    assembles fully-addressable sharded arrays on the host), so a
    checkpoint restores onto ANY mesh shape — this is the elastic-rescale
    path: a carry saved from an 8-device mesh-native train step restores
    bit-exact on a single device (and vice versa; tests/test_mesh_train.py
    round-trips exactly that).  Restored leaves are host numpy; the next
    jitted step lays them out per its own sharding specs.  This contract
    also covers FSDP (ISSUE 9): param/optimizer leaves sharded over the
    fsdp axis arrive here as fully-addressable GSPMD arrays, so
    ``device_get`` gathers the full leaf on save and nothing in the file
    format records the topology — a ZeRO-3 run saved on 8 devices
    restores bit-exact on 1 or 4 and resumes under the new mesh's specs
    (tests/test_mesh_train.py::test_fsdp8_save_restores_on_other_topologies).
  * S2FP8 compression (beyond-paper, core/s2fp8.py): optional 1-byte payload
    + (alpha, beta) per tensor for non-master state, ~4x smaller checkpoints.
  * retention: keep the latest ``keep`` checkpoints; GC is also atomic.
  * async-flush: ``save(..., blocking=False)`` hands the host copy to a
    writer thread so the train loop is not stalled on disk.

Hardening (the resilience layer; tests/test_resilience.py):

  * integrity: every committed step dir carries a ``MANIFEST.json`` with
    per-file CRC32 + size.  ``restore`` validates before reading — a
    truncated leaf, a flipped bit, or a missing manifest all fail closed.
  * quarantine: a dir that fails validation is renamed to
    ``step_<N>.quarantined`` (kept for post-mortem, invisible to
    ``latest_step``/GC) and an ``event_fn`` record
    ``checkpoint_quarantined`` is emitted; ``restore(step=None)`` then
    falls back to the next-newest VALID checkpoint instead of crashing —
    the behavior ``--resume auto`` and the escalation ladder's rung 4
    rely on.
  * transient-I/O retry: every write/read attempt retries up to
    ``retries`` times with exponential backoff + jitter (decorrelates a
    thundering herd of restarting hosts hitting shared storage).
"""
from __future__ import annotations

import json
import os
import random
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import s2fp8

MANIFEST = "MANIFEST.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _step_of(name: str) -> Optional[int]:
    """step_0000000012 -> 12; anything else (tmp, quarantined, stray
    files) -> None.  The single parser every directory scan goes through,
    so a quarantine rename can never crash GC or latest_step."""
    if not name.startswith("step_"):
        return None
    digits = name[len("step_"):]
    return int(digits) if digits.isdigit() else None


def _file_crc(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                return crc
            crc = zlib.crc32(buf, crc)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, compress: bool = False,
                 retries: int = 3, backoff_s: float = 0.05,
                 event_fn: Optional[Callable[[Dict[str, Any]], None]] = None):
        self.dir = directory
        self.keep = keep
        self.compress = compress
        self.retries = max(int(retries), 1)
        self.backoff_s = backoff_s
        # structured-event hook (TrainLoop wires its sink's emit here);
        # quarantines and retry exhaustion surface through it
        self.event_fn = event_fn
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        # wall-clock of the most recently COMPLETED disk write (async
        # writes included) — TrainLoop's checkpoint span reads this into
        # its "checkpoint_saved" telemetry events
        self.last_write_seconds: float = 0.0

    def _emit(self, record: Dict[str, Any]):
        if self.event_fn is not None:
            self.event_fn(record)

    def _with_retry(self, fn, what: str):
        """Run ``fn`` with exponential backoff + jitter on OSError — the
        transient-I/O class (NFS hiccups, contended shared storage).  The
        last failure re-raises; corruption is NOT retried (it goes through
        validation/quarantine instead)."""
        for attempt in range(self.retries):
            try:
                return fn()
            except OSError:
                if attempt == self.retries - 1:
                    raise
                delay = self.backoff_s * (2 ** attempt)
                time.sleep(delay * (1.0 + random.random()))

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, tree: Any, blocking: bool = True):
        # Snapshot to host memory first: device_get assembles sharded
        # leaves (fully-addressable single-host meshes) into one logical
        # array each, so what hits disk is mesh-shape-agnostic.
        leaves, treedef = _flatten(tree)
        # one batched device_get: D2H transfers for all leaves overlap
        # instead of serializing leaf-by-leaf
        host_leaves = [np.asarray(x) for x in jax.device_get(leaves)]
        if self._writer is not None:
            self._writer.join()          # backpressure: one in-flight write
            self._writer = None

        def write_once():
            tmp = self._step_dir(step) + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            meta = {"step": step, "n_leaves": len(host_leaves),
                    "compress": self.compress}
            files = []
            for i, leaf in enumerate(host_leaves):
                # compression is for big >=2-D weight/activation leaves;
                # scalars and 1-D leaves (StatsBank entries, norm scales,
                # biases) are kept raw so save->restore is bit-exact for
                # them even under compress=True
                if (self.compress and leaf.dtype in (np.float32,)
                        and leaf.size >= 4096 and leaf.ndim >= 2):
                    t = s2fp8.quantize(leaf)
                    files.append(f"leaf_{i:05d}.payload.npy")
                    np.save(os.path.join(tmp, files[-1]),
                            np.asarray(t.payload).view(np.uint8))
                    files.append(f"leaf_{i:05d}.stats.npy")
                    np.save(os.path.join(tmp, files[-1]),
                            np.asarray([float(t.alpha), float(t.beta)],
                                       np.float32))
                    meta[f"leaf_{i}"] = {"kind": "s2fp8",
                                         "shape": list(leaf.shape)}
                else:
                    files.append(f"leaf_{i:05d}.npy")
                    np.save(os.path.join(tmp, files[-1]), leaf)
                    meta[f"leaf_{i}"] = {"kind": "raw"}
            manifest = {"files": {
                name: {"crc32": _file_crc(os.path.join(tmp, name)),
                       "size": os.path.getsize(os.path.join(tmp, name))}
                for name in files}}
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump(meta, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # commit point

        def write():
            t0 = time.perf_counter()
            self._with_retry(write_once, f"save step {step}")
            self._gc()
            self.last_write_seconds = time.perf_counter() - t0

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # ------------------------------------------------------------------
    # integrity
    # ------------------------------------------------------------------
    def validate(self, step: int) -> Tuple[bool, str]:
        """Check a committed step dir against its manifest: META present,
        MANIFEST present, every listed file present with matching size and
        CRC32.  Pre-manifest dirs (or any tampering that removes the
        manifest) fail closed."""
        d = self._step_dir(step)
        if not os.path.exists(os.path.join(d, "META.json")):
            return False, "missing META.json"
        mpath = os.path.join(d, MANIFEST)
        if not os.path.exists(mpath):
            return False, "missing manifest"
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return False, "unreadable manifest"
        for name, info in manifest.get("files", {}).items():
            path = os.path.join(d, name)
            if not os.path.exists(path):
                return False, f"missing file {name}"
            if os.path.getsize(path) != info["size"]:
                return False, f"size mismatch {name}"
            if _file_crc(path) != info["crc32"]:
                return False, f"checksum mismatch {name}"
        return True, "ok"

    def quarantine(self, step: int, reason: str):
        """Rename a corrupt step dir out of the scan namespace (kept on
        disk for post-mortem) and emit ``checkpoint_quarantined``."""
        src = self._step_dir(step)
        dst = src + ".quarantined"
        if os.path.exists(dst):
            shutil.rmtree(dst, ignore_errors=True)
        os.rename(src, dst)
        self._emit({"kind": "event", "event": "checkpoint_quarantined",
                    "step": step, "reason": reason, "path": dst})

    # ------------------------------------------------------------------
    def _committed_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            s = _step_of(name)
            if s is not None and os.path.exists(
                    os.path.join(self.dir, name, "META.json")):
                steps.append(s)
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the structure of ``template``.

        ``step=None`` walks committed checkpoints newest -> oldest,
        validating each against its manifest; corrupt dirs are
        quarantined (with a ``checkpoint_quarantined`` event) and the
        walk continues — the caller gets the newest VALID state or
        FileNotFoundError when none survives.  An explicit ``step`` is
        validated the same way but raises instead of falling back (the
        caller asked for THAT step)."""
        if step is not None:
            ok, reason = self.validate(step)
            if not ok:
                raise ValueError(
                    f"checkpoint step {step} failed validation: {reason}")
            return self._read(template, step), step
        candidates = self._committed_steps()
        for s in reversed(candidates):
            ok, reason = self.validate(s)
            if not ok:
                self.quarantine(s, reason)
                continue
            try:
                return self._read(template, s), s
            except (OSError, ValueError) as e:
                # readable-manifest-but-unreadable-data (or a template
                # mismatch from a stale run) — same fallback path
                self.quarantine(s, f"read failed: {e}")
        raise FileNotFoundError(f"no valid checkpoint in {self.dir}")

    def _read(self, template: Any, step: int) -> Any:
        d = self._step_dir(step)
        with open(os.path.join(d, "META.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(template)
        if meta["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, template {len(leaves)}")
        out = []
        for i, tmpl in enumerate(leaves):
            info = meta[f"leaf_{i}"]
            if info["kind"] == "s2fp8":
                payload = self._with_retry(
                    lambda p=os.path.join(d, f"leaf_{i:05d}.payload.npy"):
                    np.load(p), "read payload")
                stats = self._with_retry(
                    lambda p=os.path.join(d, f"leaf_{i:05d}.stats.npy"):
                    np.load(p), "read stats")
                import jax.numpy as jnp
                t = s2fp8.S2FP8Tensor(
                    payload.view(jnp.float8_e5m2).reshape(info["shape"]),
                    jnp.float32(stats[0]), jnp.float32(stats[1]))
                arr = np.asarray(s2fp8.dequantize(t)).astype(np.asarray(tmpl).dtype)
            else:
                arr = self._with_retry(
                    lambda p=os.path.join(d, f"leaf_{i:05d}.npy"):
                    np.load(p), "read leaf")
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _gc(self):
        for s in self._committed_steps()[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
