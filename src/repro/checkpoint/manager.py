"""Fault-tolerant checkpoint manager.

Design (scales to multi-host; exercised single-host here):

  * atomic: write to ``step_<N>.tmp/`` then ``os.rename`` — a crash mid-write
    never corrupts the latest checkpoint; restore scans for the newest
    COMPLETE step (rename is the commit point).
  * sharded: each leaf is its own ``.npy``; on a pod each process writes its
    addressable shards (process-id suffix slot is in the filename schema).
  * logical arrays: leaves are saved unsharded (``jax.device_get``
    assembles fully-addressable sharded arrays on the host), so a
    checkpoint restores onto ANY mesh shape — this is the elastic-rescale
    path: a carry saved from an 8-device mesh-native train step restores
    bit-exact on a single device (and vice versa; tests/test_mesh_train.py
    round-trips exactly that).  Restored leaves are host numpy; the next
    jitted step lays them out per its own sharding specs.
  * S2FP8 compression (beyond-paper, core/s2fp8.py): optional 1-byte payload
    + (alpha, beta) per tensor for non-master state, ~4x smaller checkpoints.
  * retention: keep the latest ``keep`` checkpoints; GC is also atomic.
  * async-flush: ``save(..., blocking=False)`` hands the host copy to a
    writer thread so the train loop is not stalled on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core import s2fp8


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, compress: bool = False):
        self.dir = directory
        self.keep = keep
        self.compress = compress
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        # wall-clock of the most recently COMPLETED disk write (async
        # writes included) — TrainLoop's checkpoint span reads this into
        # its "checkpoint_saved" telemetry events
        self.last_write_seconds: float = 0.0

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, tree: Any, blocking: bool = True):
        # Snapshot to host memory first: device_get assembles sharded
        # leaves (fully-addressable single-host meshes) into one logical
        # array each, so what hits disk is mesh-shape-agnostic.
        leaves, treedef = _flatten(tree)
        # one batched device_get: D2H transfers for all leaves overlap
        # instead of serializing leaf-by-leaf
        host_leaves = [np.asarray(x) for x in jax.device_get(leaves)]
        if self._writer is not None:
            self._writer.join()          # backpressure: one in-flight write
            self._writer = None

        def write():
            t0 = time.perf_counter()
            tmp = self._step_dir(step) + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            meta = {"step": step, "n_leaves": len(host_leaves),
                    "compress": self.compress}
            for i, leaf in enumerate(host_leaves):
                # compression is for big >=2-D weight/activation leaves;
                # scalars and 1-D leaves (StatsBank entries, norm scales,
                # biases) are kept raw so save->restore is bit-exact for
                # them even under compress=True
                if (self.compress and leaf.dtype in (np.float32,)
                        and leaf.size >= 4096 and leaf.ndim >= 2):
                    t = s2fp8.quantize(leaf)
                    np.save(os.path.join(tmp, f"leaf_{i:05d}.payload.npy"),
                            np.asarray(t.payload).view(np.uint8))
                    np.save(os.path.join(tmp, f"leaf_{i:05d}.stats.npy"),
                            np.asarray([float(t.alpha), float(t.beta)], np.float32))
                    meta[f"leaf_{i}"] = {"kind": "s2fp8",
                                         "shape": list(leaf.shape)}
                else:
                    np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
                    meta[f"leaf_{i}"] = {"kind": "raw"}
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump(meta, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # commit point
            self._gc()
            self.last_write_seconds = time.perf_counter() - t0

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name, "META.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (newest step if None)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "META.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(template)
        if meta["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, template {len(leaves)}")
        out = []
        for i, tmpl in enumerate(leaves):
            info = meta[f"leaf_{i}"]
            if info["kind"] == "s2fp8":
                payload = np.load(os.path.join(d, f"leaf_{i:05d}.payload.npy"))
                stats = np.load(os.path.join(d, f"leaf_{i:05d}.stats.npy"))
                import jax.numpy as jnp
                t = s2fp8.S2FP8Tensor(
                    payload.view(jnp.float8_e5m2).reshape(info["shape"]),
                    jnp.float32(stats[0]), jnp.float32(stats[1]))
                arr = np.asarray(s2fp8.dequantize(t)).astype(np.asarray(tmpl).dtype)
            else:
                arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
