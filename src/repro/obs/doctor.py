"""s2fp8-doctor library: probe a bank with one replayed batch and rank
sites by FP8 health.

The doctor answers "which sites are hurting and what format should they
run in" from a checkpoint: :func:`probe_bank` replays ONE batch through
the banked loss with every refresh forced (``refresh_every=1``), so each
site recomputes its health metrics against the bank's CARRIED stats —
exactly what the next real training step would have truncated with.  A
warm bank fed a drifted batch reports saturation/underflow; a cold
(freshly-initialized) bank bootstraps with fresh stats and reports
clean.  :func:`site_report` flattens the probed bank into ranked rows
and :func:`recommend_fmt` applies the e4m3/e5m2 range-vs-resolution rule
(the static half of the ROADMAP's format-autotuning item).

This module imports ``core/statsbank.py`` (which imports
``repro.obs.metrics``) — import it directly, never through the
``repro.obs`` package root.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import statsbank
from repro.obs import metrics as obs_metrics

# Underflow-to-zero fraction above which a site is flagged and pushed
# toward the wider-range format.  Flushing a few percent of near-zero
# values is intrinsic S2FP8 behavior even with fresh stats (the squeeze
# trades the low tail for range — ~3-8% on small Gaussian tensors);
# well past that means the carried shift is discarding real signal.
UFLOW_THRESH = 0.15
# A site whose last refresh is more than this many refresh periods old is
# flagged stale (its carried stats describe a long-gone tensor).
STALE_FACTOR = 4.0


def probe_bank(loss_fn, params, batch, policy, bank: Dict[str, Any],
               cfg: statsbank.StatsConfig, step: int = 0
               ) -> Tuple[Dict[str, Any], float]:
    """One forced-refresh banked forward+backward over ``batch``.

    Every site refreshes (``refresh_every=1``) with telemetry on, so the
    returned bank carries health metrics measured against the input
    bank's carried stats.  Returns ``(probed_bank, loss)``; the input
    bank is not mutated (functional update via the bank cotangent)."""
    probe_cfg = dataclasses.replace(cfg, refresh_every=1, telemetry=True)
    bank_t = obs_metrics.ensure_telemetry(bank)

    def banked_loss(p, bk):
        with statsbank.bind(bk, jnp.int32(step), probe_cfg):
            loss, _ = loss_fn(p, batch, policy)
        return loss

    loss, (_, updates) = jax.value_and_grad(
        banked_loss, argnums=(0, 1))(params, bank_t)
    return statsbank.merge_updates(bank_t, updates), float(loss)


def _flags(row: Dict[str, Any], refresh_every: int) -> List[str]:
    fl = []
    if row["last"] < 0:
        fl.append("COLD")
    if row["sat_frac"] > 0:
        fl.append("SAT")
    if row["uflow_frac"] > UFLOW_THRESH:
        fl.append("UFLOW")
    if row["staleness"] > STALE_FACTOR * refresh_every:
        fl.append("STALE")
    return fl


def recommend_fmt(row: Dict[str, Any]) -> Tuple[str, str]:
    """The e4m3/e5m2 range-vs-resolution rule on one site row: any range
    distress (saturation at the format max, or meaningful underflow-to-
    zero) wants e5m2's wider exponent; a site comfortably in range can
    take e4m3's extra mantissa bit."""
    if row["sat_frac"] > 0:
        return "e5m2", "saturating at format max -> needs range"
    if row["uflow_frac"] > UFLOW_THRESH:
        return "e5m2", "underflow-to-zero above threshold -> needs range"
    return "e4m3", "in range -> can take the mantissa bit"


def is_clean(row: Dict[str, Any]) -> bool:
    """Healthy = no range distress and not stale (COLD just means no
    data has reached the site yet)."""
    return not (set(row["flags"]) & {"SAT", "UFLOW", "STALE"})


def site_report(bank: Dict[str, Any], *, step: int = 0,
                refresh_every: int = 16) -> List[Dict[str, Any]]:
    """Flatten a (probed) bank into per-site-direction rows, ranked most
    distressed first: saturation fraction, then underflow, then
    staleness.  Scanned segments ([L]-shaped leaves) yield one row per
    layer.  Sites without telemetry leaves are skipped."""
    rows: List[Dict[str, Any]] = []
    for site in sorted(bank):
        for d in sorted(bank[site]):
            st = bank[site][d]
            if not obs_metrics.has_telemetry(st):
                continue
            leaves = {k: np.asarray(v) for k, v in st.items()}
            scalar = leaves["last"].ndim == 0
            n = 1 if scalar else leaves["last"].shape[0]
            for i in range(n):
                def get(k):
                    return float(leaves[k]) if scalar else float(leaves[k][i])
                row = {"site": site, "dir": d,
                       "layer": None if scalar else i,
                       **{k: get(k) for k in obs_metrics.TELE_FIELDS},
                       "alpha": get("alpha"), "beta": get("beta"),
                       "last": get("last")}
                row["staleness"] = (step - row["last"]
                                    if row["last"] >= 0 else -1.0)
                row["flags"] = _flags(row, refresh_every)
                row["recommend"], row["why"] = recommend_fmt(row)
                rows.append(row)
    rows.sort(key=lambda r: (r["sat_frac"], r["uflow_frac"],
                             r["staleness"]), reverse=True)
    return rows


def format_report(rows: List[Dict[str, Any]], *, backend: str = "?",
                  loss: Optional[float] = None, top: int = 10) -> str:
    """Human-readable ranked health report for one backend's probe."""
    lines = []
    n_clean = sum(is_clean(r) for r in rows)
    head = (f"[s2fp8-doctor] backend={backend} sites={len(rows)} "
            f"clean={n_clean} flagged={len(rows) - n_clean}")
    if loss is not None:
        head += f" probe_loss={loss:.4f}"
    lines.append(head)
    if not rows:
        lines.append("  (no telemetry-bearing sites)")
        return "\n".join(lines)
    lines.append(f"  {'site':<40s} {'dir':<8s} {'sat':>7s} {'uflow':>7s} "
                 f"{'snr_dB':>7s} {'drift_m':>8s} {'stale':>6s} "
                 f"{'rec':>5s}  flags")
    for r in rows[:top]:
        name = r["site"] + (f"[{r['layer']}]" if r["layer"] is not None
                            else "")
        lines.append(
            f"  {name:<40.40s} {r['dir']:<8s} {r['sat_frac']:>7.3f} "
            f"{r['uflow_frac']:>7.3f} {r['qsnr_db']:>7.1f} "
            f"{r['drift_m']:>8.3f} {r['staleness']:>6.0f} "
            f"{r['recommend']:>5s}  {','.join(r['flags']) or '-'}")
    worst = rows[0]
    if is_clean(worst):
        lines.append("  verdict: all sites healthy")
    else:
        wname = worst["site"] + (f"[{worst['layer']}]"
                                 if worst["layer"] is not None else "")
        lines.append(f"  verdict: worst site {wname}.{worst['dir']} "
                     f"({','.join(worst['flags'])}) — {worst['why']}")
    stale = [r for r in rows if "STALE" in r["flags"] or "COLD" in r["flags"]]
    if stale:
        names = ", ".join(
            f"{r['site']}.{r['dir']}" for r in stale[:5])
        lines.append(f"  stalest/cold: {names}"
                     + (" …" if len(stale) > 5 else ""))
    return "\n".join(lines)
