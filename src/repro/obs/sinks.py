"""Pluggable metrics sinks.

A sink consumes flat dict records.  Every record carries a ``kind``:

* ``"train_step"``  — TrainLoop per-step line: loss/lr plus span timings
  (``data_ms``/``step_ms``/``ckpt_ms``/``refresh_ms``).
* ``"site_health"`` — one StatsBank site-direction's telemetry snapshot
  (keys per :data:`repro.obs.metrics.TELE_FIELDS` plus ``site``, ``dir``,
  ``staleness``, optional ``layer`` for scanned segments).
* ``"event"``       — irregular happenings: watchdog trips, checkpoint
  saves.

The protocol is three methods — ``emit(record)``, ``flush()``,
``close()`` — so file formats, consoles and test doubles interchange.
:func:`make_sink` parses the CLI spec syntax (``jsonl:<path>``,
``csv:<path>``, ``console``, ``null``).
"""
from __future__ import annotations

import csv
import json
from typing import Any, Dict, List, Optional

import numpy as np


class MetricsSink:
    """Base protocol; subclasses override :meth:`emit`."""

    def emit(self, record: Dict[str, Any]) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


def _to_py(v):
    """Host-side scalars for serialization (np/jax scalars -> float/int)."""
    if isinstance(v, (np.generic,)):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.item() if v.ndim == 0 else v.tolist()
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    return v


def _clean(record: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _to_py(v) for k, v in record.items()}


class NullSink(MetricsSink):
    def emit(self, record: Dict[str, Any]) -> None:
        pass


class MemorySink(MetricsSink):
    """Buffers records in a list — test double and programmatic consumer."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(_clean(record))

    def by_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("kind") == kind]


class JsonlSink(MetricsSink):
    """One JSON object per line, append mode — the default file sink."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def emit(self, record: Dict[str, Any]) -> None:
        self._f.write(json.dumps(_clean(record)) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class CsvSink(MetricsSink):
    """Single CSV whose header is the union of keys across all records
    (records buffer until :meth:`flush`/:meth:`close`, which rewrites the
    file — the column set is not knowable up front)."""

    def __init__(self, path: str):
        self.path = path
        self._records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        self._records.append(_clean(record))

    def flush(self) -> None:
        if not self._records:
            return
        cols: List[str] = []
        for r in self._records:
            for k in r:
                if k not in cols:
                    cols.append(k)
        with open(self.path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols, restval="")
            w.writeheader()
            w.writerows(self._records)


class ConsoleSink(MetricsSink):
    """Human-oriented formatting through a ``print``-like callable.

    Reproduces TrainLoop's historical log lines (``step ... loss ...``)
    and watchdog warnings, so a loop with no explicit sink behaves as it
    always did."""

    def __init__(self, print_fn=print):
        self.print_fn = print_fn

    def emit(self, record: Dict[str, Any]) -> None:
        r = _clean(record)
        kind = r.get("kind")
        if kind == "train_step":
            self.print_fn(
                f"step {r['step']:5d} loss {r['loss']:.4f} "
                f"lr {r['lr']:.2e} t {r.get('step_ms', 0.0):.0f}ms")
        elif kind == "event" and r.get("event") == "watchdog":
            self.print_fn(
                f"[watchdog] step {r['step']} took {r['dt_s']:.3f}s "
                f"(median {r['median_s']:.3f}s) — straggler suspected")
        elif kind == "event" and r.get("event") == "checkpoint_saved":
            self.print_fn(
                f"[ckpt] step {r['step']} saved "
                f"(write {r.get('write_s', 0.0):.2f}s)")
        elif kind == "site_health":
            layer = f"[{r['layer']}]" if r.get("layer") is not None else ""
            self.print_fn(
                f"[obs] step {r['step']} {r['site']}{layer}.{r['dir']} "
                f"sat {r['sat_frac']:.3f} uflow {r['uflow_frac']:.3f} "
                f"snr {r['qsnr_db']:.1f}dB stale {r['staleness']:.0f}")
        else:
            body = " ".join(f"{k}={v}" for k, v in r.items() if k != "kind")
            self.print_fn(f"[{kind or 'metric'}] {body}")


class TeeSink(MetricsSink):
    """Fan one stream out to several sinks."""

    def __init__(self, *sinks: MetricsSink):
        self.sinks = list(sinks)

    def emit(self, record: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.emit(record)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def make_sink(spec: Optional[str], print_fn=print) -> MetricsSink:
    """Parse a CLI sink spec: ``jsonl:<path>`` | ``csv:<path>`` |
    ``console`` | ``null`` (None -> NullSink)."""
    if spec is None or spec == "" or spec == "null":
        return NullSink()
    if spec == "console":
        return ConsoleSink(print_fn)
    if spec == "memory":
        return MemorySink()
    head, sep, rest = spec.partition(":")
    if head == "jsonl" and sep:
        return JsonlSink(rest)
    if head == "csv" and sep:
        return CsvSink(rest)
    raise ValueError(
        f"unknown metrics sink spec {spec!r} — expected jsonl:<path>, "
        f"csv:<path>, console, or null")
