"""TelemetryState: the jit-carried view of a telemetry-enabled StatsBank,
and the host-side drain that turns it into sink records.

The health metrics (:mod:`repro.obs.metrics`) live as extra leaves of the
bank's site states, updated inside the refresh ``lax.cond``.
:func:`telemetry_state` is a PURE elementwise extraction of those leaves
(plus derived staleness) — no reductions, so attaching telemetry to a
train step cannot disturb the jaxpr-asserted zero-steady-state-reduction
invariant.  The trainer ships the state off-device with
``jax.experimental.io_callback`` into :class:`Telemetry`, which flattens
it into per-site ``"site_health"`` records for a
:class:`~repro.obs.sinks.MetricsSink`.  Under a mesh the drain runs on
the replicated post-``shard_map`` bank, so each step emits exactly once.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.sinks import MetricsSink


def telemetry_state(bank: Dict[str, Any], step) -> Dict[str, Any]:
    """Extract ``{site: {dir: {metric: leaf}}}`` from a bank.  Purely
    elementwise (zero reductions).  Sites without telemetry leaves are
    skipped; the result is ``{}`` for a telemetry-off bank.  ``staleness``
    is steps since the direction's last refresh (-1 = never refreshed)."""
    step_f = jnp.asarray(step, jnp.float32)
    out: Dict[str, Any] = {}
    for site, entry in bank.items():
        dirs = {}
        for d, st in entry.items():
            if not obs_metrics.has_telemetry(st):
                continue
            rec = {f: st[f] for f in obs_metrics.TELE_FIELDS}
            rec["staleness"] = jnp.where(
                st["last"] >= 0, step_f - st["last"], -1.0)
            rec["alpha"] = st["alpha"]
            rec["beta"] = st["beta"]
            dirs[d] = rec
        if dirs:
            out[site] = dirs
    return out


def state_records(state: Dict[str, Any], step: int
                  ) -> Iterator[Dict[str, Any]]:
    """Flatten a (host-side) TelemetryState into ``"site_health"`` sink
    records — one per site-direction, or one per layer row for scanned
    segments ([L]-shaped leaves)."""
    for site in sorted(state):
        for d in sorted(state[site]):
            rec = state[site][d]
            leaf = np.asarray(rec["staleness"])
            if leaf.ndim == 0:
                yield {"kind": "site_health", "step": step, "site": site,
                       "dir": d, "layer": None,
                       **{k: float(np.asarray(v)) for k, v in rec.items()}}
            else:
                for i in range(leaf.shape[0]):
                    yield {"kind": "site_health", "step": step, "site": site,
                           "dir": d, "layer": i,
                           **{k: float(np.asarray(v)[i])
                              for k, v in rec.items()}}


class Telemetry:
    """Host endpoint of the telemetry drain.

    ``drain(state, step)`` is the ``io_callback`` target: it receives the
    TelemetryState as host arrays every step and forwards flattened
    records to the sink every ``every`` steps (telemetry values only
    change on refresh steps, so ``every`` is typically the bank's
    ``refresh_every``)."""

    def __init__(self, sink: MetricsSink, every: int = 1):
        if every < 1:
            raise ValueError("Telemetry every must be >= 1")
        self.sink = sink
        self.every = int(every)

    def drain(self, state: Dict[str, Any], step) -> None:
        step_i = int(np.asarray(step))
        if step_i % self.every != 0:
            return
        for rec in state_records(state, step_i):
            self.sink.emit(rec)

    def flush(self) -> None:
        self.sink.flush()
