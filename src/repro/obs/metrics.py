"""Per-site FP8 health metrics — the numbers behind the telemetry layer.

Each metric answers one question the loss curve cannot:

* ``sat_frac``   — fraction of nonzero elements whose shifted/squeezed
  log-magnitude lands at or past the payload format's max finite value
  (``log2|Y| >= log2(fmax)``): the carried (alpha, beta) no longer keep
  the tensor inside the representable range (paper Eq. 5 clamps these).
* ``uflow_frac`` — fraction of nonzero elements the truncation flushes to
  exactly zero: the shift has pushed them below the format's smallest
  magnitude (the resolution side of the range-vs-resolution tradeoff).
* ``qmse``       — mean squared truncation error vs the pre-truncation
  tensor, ``mean((truncate(x) - x)^2)``.
* ``qsnr_db``    — quantization signal-to-noise ratio,
  ``10*log10(sum(x^2) / sum((truncate(x) - x)^2))``; 0 when either sum is
  exactly zero (no signal / exact truncation).
* ``drift_mu`` / ``drift_m`` — ``|EMA - live|`` distance between the
  bank's carried (mu, m) moments and the live tensor's raw Eq. 3–4
  moments at refresh time: how stale the delayed stats had become.

All of them are computed INSIDE the StatsBank refresh ``lax.cond``
(:func:`repro.core.statsbank.refresh_state` calls :func:`health_update`),
measured against the **pre-refresh carried stats** — fresh stats never
saturate by construction, so measuring post-refresh would always read
clean.  On the bootstrap refresh (``last < 0``) there are no carried
stats and the fresh ones are used: a cold site reports clean.  Steady
(non-refresh) steps run none of this — the zero-steady-state-reduction
invariant the jaxpr tests assert is untouched.

This module must not import ``repro.core.statsbank`` (statsbank imports
it); it only depends on the backend registry and the s2fp8 math.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import s2fp8

# Extra per-direction site-state leaves carried by a telemetry-enabled
# bank (StatsConfig(telemetry=True)).  They ride the same pytree as the
# (alpha, beta, ema_mu, ema_m, last) stats — through scan xs, custom_vjp
# cotangents, merge_updates and checkpoints — with zero new plumbing.
TELE_FIELDS = ("sat_frac", "uflow_frac", "qmse", "qsnr_db",
               "drift_mu", "drift_m")

# Reverse lookup: refresh callers pass target_max; the metric needs the
# payload format's max finite value.  Falls back to e5m2 (the paper's
# format) for non-standard target_max values.
_FMT_FROM_TARGET = {float(v): k for k, v in s2fp8.FMT_TARGET_MAX.items()}


def resolve_fmt(fmt: Optional[str], target_max: float) -> str:
    if fmt is not None:
        return fmt
    return _FMT_FROM_TARGET.get(float(target_max), "e5m2")


def init_tele_state(shape: Tuple[int, ...] = ()) -> Dict[str, jnp.ndarray]:
    """Zeroed telemetry leaves (a cold site reports clean)."""
    return {f: jnp.zeros(shape, jnp.float32) for f in TELE_FIELDS}


def has_telemetry(state: Dict[str, jnp.ndarray]) -> bool:
    return TELE_FIELDS[0] in state


def health_update(x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                  new_stats: Dict[str, jnp.ndarray],
                  mu_t: jnp.ndarray, m_t: jnp.ndarray,
                  has: jnp.ndarray, first: jnp.ndarray,
                  count: jnp.ndarray, *, fmt: str,
                  backend: Optional[str] = None,
                  axis_name: Optional[Union[str, Tuple[str, ...]]] = None
                  ) -> Dict[str, jnp.ndarray]:
    """One refresh's health metrics (see module docstring for definitions).

    ``new_stats`` holds the freshly derived (alpha, beta); ``mu_t``/``m_t``
    are the live raw moments and ``count`` the (already-global) nonzero
    count from the refresh reduction.  Under ``axis_name`` the metric
    partials are psum'd exactly like the stats partials, so sharded
    metrics are metrics of the GLOBAL tensor.
    """
    # Measure with the stats that actually truncated recent steps: the
    # carried pair, except on bootstrap where only the fresh pair exists.
    a_used = jnp.where(first, new_stats["alpha"], state["alpha"])
    b_used = jnp.where(first, new_stats["beta"], state["beta"])
    xf = x.astype(jnp.float32)
    be = nbackend.get_backend(backend)
    t = be.truncate(xf, stats=(a_used, b_used), fmt=fmt).astype(jnp.float32)

    absx = jnp.abs(xf)
    nonzero = absx > 0.0
    ylog = a_used * jnp.log2(jnp.where(nonzero, absx, 1.0)) + b_used
    log_fmax = jnp.log2(jnp.float32(s2fp8.FMT_MAX_FINITE[fmt]))

    sat = jnp.sum(jnp.logical_and(nonzero, ylog >= log_fmax)
                  .astype(jnp.float32))
    uflow = jnp.sum(jnp.logical_and(nonzero, t == 0.0).astype(jnp.float32))
    err2 = jnp.sum(jnp.square(t - xf))
    sig2 = jnp.sum(jnp.square(xf))
    size = jnp.float32(xf.size)
    if axis_name is not None:
        sat, uflow, err2, sig2, size = jax.lax.psum(
            (sat, uflow, err2, sig2, size), axis_name)

    denom = jnp.maximum(count, 1.0)
    qmse = err2 / jnp.maximum(size, 1.0)
    # dB via a log-ratio with floored operands; exactly-zero error or
    # signal reports 0 rather than +/-inf.
    ok = jnp.logical_and(err2 > 0.0, sig2 > 0.0)
    qsnr_db = jnp.where(
        ok, 10.0 * (jnp.log10(jnp.maximum(sig2, 1e-38))
                    - jnp.log10(jnp.maximum(err2, 1e-38))), 0.0)
    live = jnp.logical_and(has, jnp.logical_not(first))
    drift_mu = jnp.where(live, jnp.abs(state["ema_mu"] - mu_t), 0.0)
    drift_m = jnp.where(live, jnp.abs(state["ema_m"] - m_t), 0.0)
    return {"sat_frac": (sat / denom).astype(jnp.float32),
            "uflow_frac": (uflow / denom).astype(jnp.float32),
            "qmse": qmse.astype(jnp.float32),
            "qsnr_db": qsnr_db.astype(jnp.float32),
            "drift_mu": drift_mu.astype(jnp.float32),
            "drift_m": drift_m.astype(jnp.float32)}


def ensure_telemetry(bank: Dict[str, Dict[str, Dict[str, jnp.ndarray]]]
                     ) -> Dict[str, Dict[str, Dict[str, jnp.ndarray]]]:
    """Widen a bank's site states with zeroed telemetry leaves (no-op for
    states that already carry them) — how the doctor probes a checkpoint
    that was trained with telemetry off."""
    out = {}
    for site, entry in bank.items():
        out[site] = {}
        for d, st in entry.items():
            if has_telemetry(st):
                out[site][d] = dict(st)
            else:
                widened = dict(st)
                widened.update(init_tele_state(st["alpha"].shape))
                out[site][d] = widened
    return out


def strip_telemetry(bank: Dict[str, Dict[str, Dict[str, jnp.ndarray]]]
                    ) -> Dict[str, Dict[str, Dict[str, jnp.ndarray]]]:
    """Drop telemetry leaves — restores the plain five-leaf site layout
    (e.g. to restore a telemetry-on checkpoint into a telemetry-off run)."""
    return {site: {d: {k: v for k, v in st.items() if k not in TELE_FIELDS}
                   for d, st in entry.items()}
            for site, entry in bank.items()}
