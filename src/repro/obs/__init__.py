"""Numerics observability: per-site FP8 health metrics riding the
StatsBank refresh, pluggable metrics sinks, and the telemetry drain.

Import layering (``core/statsbank.py`` imports ``repro.obs.metrics``, so
nothing here may import statsbank):

* :mod:`repro.obs.metrics`   — metric math + telemetry site-state leaves
* :mod:`repro.obs.sinks`     — MetricsSink protocol + jsonl/csv/console
* :mod:`repro.obs.telemetry` — TelemetryState extraction + io_callback drain
* :mod:`repro.obs.doctor`    — checkpoint health reports (imports
  statsbank; import it directly, not through this package root)
"""
from repro.obs.metrics import (TELE_FIELDS, ensure_telemetry, has_telemetry,
                               init_tele_state, strip_telemetry)
from repro.obs.sinks import (ConsoleSink, CsvSink, JsonlSink, MemorySink,
                             MetricsSink, NullSink, TeeSink, make_sink)
from repro.obs.telemetry import Telemetry, state_records, telemetry_state

__all__ = [
    "TELE_FIELDS", "ensure_telemetry", "has_telemetry", "init_tele_state",
    "strip_telemetry", "ConsoleSink", "CsvSink", "JsonlSink", "MemorySink",
    "MetricsSink", "NullSink", "TeeSink", "make_sink", "Telemetry",
    "state_records", "telemetry_state",
]
