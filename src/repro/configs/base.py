"""Architecture / run configuration schema + registry.

Every assigned architecture is a module in this package defining ``CONFIG``
(exact published numbers) built on :class:`ArchConfig`.  ``reduced()`` gives
the CPU-smoke variant of the same family.  ``--arch <id>`` in the launchers
resolves through :func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block types that can appear in a layer pattern:
#   "dense"  : GQA attention + dense MLP
#   "local"  : sliding-window GQA attention + dense MLP
#   "moe"    : GQA attention + MoE MLP (shared + routed experts)
#   "mamba1" : Mamba-1 selective-SSM block
#   "mamba2" : Mamba-2 (SSD, multi-head scalar-decay) block
#   "attn"   : attention-only block (Zamba2 shared attention)
# ---------------------------------------------------------------------------
BLOCK_TYPES = ("dense", "local", "moe", "mamba1", "mamba2", "attn")

ARCH_IDS = (
    "minicpm_2b", "stablelm_12b", "gemma3_1b", "nemotron_4_340b",
    "zamba2_1p2b", "deepseek_moe_16b", "kimi_k2_1t_a32b", "chameleon_34b",
    "falcon_mamba_7b", "whisper_medium",
    # paper-reproduction models
    "transformer_tiny", "resnet20_cifar", "ncf_ml1m",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_SPECS = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0
    dense_d_ff: int = 0              # d_ff of the first dense layer(s)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balancing loss weight
    # "global"  — route over all tokens (baseline; the token gather crosses
    #             data shards -> all-gather of activations)
    # "grouped" — route within each batch row; gathers stay data-local and
    #             only the (much smaller) dispatched xe crosses the expert
    #             axis (hillclimb for the collective-bound MoE cells)
    routing: str = "global"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    dt_rank: int = 0                 # mamba1; 0 -> d_model // 16
    head_dim: int = 64               # mamba2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio | mlp | conv
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    activation: str = "silu_glu"     # silu_glu | gelu_glu | gelu | sq_relu
    norm: str = "rms"                # rms | ln
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    pattern: Tuple[str, ...] = ()    # () -> ("dense",) * n_layers
    window: int = 0                  # sliding window for "local" blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): n_layers counts DECODER layers.
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"           # none | audio_stub | vq_stub
    # numerics / memory
    activation_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # Numerics backend for the S2FP8 truncations (core/backend.py registry):
    # "auto" -> fused Pallas kernels on TPU, pure-jnp ref elsewhere.  Both
    # are bitwise-identical; launchers may override with --backend.
    numerics_backend: str = "auto"
    remat: bool = True
    # attention autodiff schedule for long sequences:
    #   "naive" — chunked scan, linearized residuals (paper-era baseline)
    #   "flash" — custom-VJP recompute backward (hillclimb #1, models/flash.py)
    attn_impl: str = "naive"
    # SSM scan schedule:
    #   "step"    — one lax.scan iteration per timestep (baseline; HBM-bound:
    #               the state round-trips HBM every step)
    #   "unroll8" — 8 timesteps per scan body; state stays in registers/VMEM
    #               within a body (mamba1 hillclimb)
    #   "ssd"     — chunked SSD block decomposition (mamba2 hillclimb:
    #               intra-chunk work becomes MXU matmuls, state traffic /T)
    ssm_impl: str = "step"
    # schedule hint (minicpm uses WSD)
    schedule: str = "cosine"
    # which assigned shapes run; others map to a skip reason string
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def resolved_pattern(self) -> Tuple[str, ...]:
        return self.pattern or ("dense",) * self.n_layers

    @property
    def sub_quadratic(self) -> bool:
        p = set(self.resolved_pattern)
        return bool(p & {"mamba1", "mamba2"}) or (p <= {"local", "dense"} and "local" in p)

    def skip_reason(self, shape: str) -> Optional[str]:
        for s, reason in self.skip_shapes:
            if s == shape:
                return reason
        return None

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        q = self.n_heads * hd
        kvd = self.kv_heads * hd
        glu = self.activation.endswith("_glu")

        def attn_p():
            return d * q + 2 * d * kvd + q * d

        def mlp_p(f):
            return d * f * (3 if glu else 2)

        total = 0
        for blk in self.resolved_pattern:
            if blk in ("dense", "local"):
                total += attn_p() + mlp_p(ff)
            elif blk == "attn":
                total += attn_p() + mlp_p(ff)
            elif blk == "moe":
                m = self.moe
                routed = m.n_experts * mlp_p(m.expert_d_ff)
                shared = m.n_shared * mlp_p(m.expert_d_ff)
                total += attn_p() + routed + shared + d * m.n_experts
            elif blk == "mamba1":
                s = self.ssm
                di = s.expand * d
                dtr = s.dt_rank or d // 16
                total += d * 2 * di + di * s.conv_kernel + di * (dtr + 2 * s.state) \
                    + dtr * di + di * s.state + di * d
            elif blk == "mamba2":
                s = self.ssm
                di = s.expand * d
                nh = di // s.head_dim
                total += d * (2 * di + 2 * s.state * 1 + nh) + di * s.conv_kernel + di * d
        if self.moe and self.moe.first_dense_layers:
            # pattern already encodes dense first layers with dense_d_ff
            pass
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.enc_dec:
            enc = self.n_enc_layers * (attn_p() + mlp_p(ff))
            dec_cross = self.n_layers * attn_p()   # cross-attention stacks
            total += enc + dec_cross
        return total

    def n_active_params(self) -> int:
        """Active (per-token) params — differs from n_params for MoE."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        glu = self.activation.endswith("_glu")
        m = self.moe
        per_expert = d * m.expert_d_ff * (3 if glu else 2)
        inactive = (m.n_experts - m.top_k) * per_expert * \
            sum(1 for b in self.resolved_pattern if b == "moe")
        return self.n_params() - inactive

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.reduced()
