"""Nemotron-4-340B [arXiv:2402.16819] — dense, GQA kv=8, squared-ReLU MLP."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, kv_heads=8, d_ff=73728,
    vocab=256000, head_dim=192, activation="sq_relu", norm="ln",
    skip_shapes=(("long_500k", "skip(full-attn)"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=128, n_heads=8, kv_heads=2,
                          head_dim=16, d_ff=512, vocab=512)
