"""StableLM-2-12B [hf:stabilityai] — dense, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, kv_heads=8, d_ff=13824,
    vocab=100352, head_dim=160, activation="silu_glu",
    skip_shapes=(("long_500k", "skip(full-attn)"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=128, n_heads=8, kv_heads=2,
                          head_dim=16, d_ff=256, vocab=512)
