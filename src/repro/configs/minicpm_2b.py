"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, MHA, WSD schedule."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64, activation="silu_glu", tie_embeddings=True,
    schedule="wsd",
    skip_shapes=(("long_500k", "skip(full-attn): pure full attention, 500k KV "
                  "decode needs sub-quadratic attention per assignment"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=128, n_heads=4, kv_heads=4,
                          head_dim=32, d_ff=256, vocab=512)
