"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1, attention-free."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, kv_heads=0, d_ff=0,
    vocab=65024, activation="silu_glu",
    pattern=("mamba1",) * 64,
    ssm=SSMConfig(state=16, expand=2, conv_kernel=4, dt_rank=256),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=128, vocab=512,
        pattern=("mamba1",) * 4,
        ssm=SSMConfig(state=8, expand=2, conv_kernel=4, dt_rank=8))
