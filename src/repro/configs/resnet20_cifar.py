"""ResNet-20 on CIFAR-10 (paper §4.2).  Conv family — handled by
models/resnet.py, not the LM stack; ArchConfig fields are nominal."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="resnet20-cifar", family="conv",
    n_layers=20, d_model=64, n_heads=0, kv_heads=0, d_ff=0, vocab=10,
    remat=False,
)

DEPTH = 20
N_CLASSES = 10


def reduced() -> ArchConfig:
    return CONFIG
