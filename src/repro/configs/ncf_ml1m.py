"""NCF on MovieLens-1M (paper §4.4).  MLP family — models/ncf.py."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="ncf-ml1m", family="mlp",
    n_layers=4, d_model=64, n_heads=0, kv_heads=0, d_ff=0, vocab=0,
    remat=False,
)

N_USERS = 6040
N_ITEMS = 3706
FACTORS = 8


def reduced() -> ArchConfig:
    return CONFIG
