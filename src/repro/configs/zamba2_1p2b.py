"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention.

Adaptation note (DESIGN.md §6): Zamba2's single *weight-shared* attention
block applied at multiple depths is represented as regular attention blocks
at every 6th position; weight sharing is a parameter-count detail orthogonal
to the S2FP8 numerics and to the compute/communication shape of the model.
"""
from repro.configs.base import ArchConfig, SSMConfig

_PATTERN = tuple(
    ("attn" if (i % 6) == 5 else "mamba2") for i in range(38)
)

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64, activation="gelu_glu",
    pattern=_PATTERN,
    ssm=SSMConfig(state=64, expand=2, conv_kernel=4, head_dim=64),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=128, n_heads=4, kv_heads=4, head_dim=32,
        d_ff=256, vocab=512,
        pattern=("mamba2", "mamba2", "attn", "mamba2"),
        ssm=SSMConfig(state=8, expand=2, conv_kernel=4, head_dim=32))
