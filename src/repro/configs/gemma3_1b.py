"""Gemma-3-1B [hf:google/gemma-3-1b-pt] — 5:1 local:global, 128k context.

Pattern: 5 sliding-window (512) layers per 1 global layer.  The local layers
keep the long_500k cell sub-quadratic (ring KV cache of window size); the
global layers use an SP-sharded KV cache for that cell (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    ("local" if (i % 6) != 5 else "dense") for i in range(26)
)

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256, activation="gelu_glu", tie_embeddings=True,
    pattern=_PATTERN, window=512, rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=128, n_heads=4, kv_heads=1, head_dim=32,
        d_ff=256, vocab=512, window=64,
        pattern=("local", "local", "dense", "local"))
