"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE, 2 shared + 64
routed top-6 experts; first layer dense (d_ff 10944)."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128, activation="silu_glu",
    pattern=("dense_first",) + ("moe",) * 27,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
                  first_dense_layers=1, dense_d_ff=10944),
    skip_shapes=(("long_500k", "skip(full-attn)"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=3, d_model=128, n_heads=4, kv_heads=4, head_dim=32,
        d_ff=64, vocab=512,
        pattern=("dense_first", "moe", "moe"),
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, expert_d_ff=64,
                      first_dense_layers=1, dense_d_ff=256))
