from repro.configs.base import (ARCH_IDS, SHAPES, SHAPE_SPECS, ArchConfig,
                                MoEConfig, SSMConfig, get_config,
                                get_reduced_config)
