"""Transformer tiny (paper §4.3): 2 layers, d=128, filter 512, enc-dec."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="transformer-tiny", family="audio",   # enc-dec path
    n_layers=2, d_model=128, n_heads=4, kv_heads=4, d_ff=512,
    vocab=8192, head_dim=32, activation="gelu", norm="ln",
    enc_dec=True, n_enc_layers=2, remat=False,
)


def reduced() -> ArchConfig:
    return CONFIG
