"""Whisper-medium [arXiv:2212.04356] — enc-dec audio; conv frontend stubbed.

``input_specs`` feeds precomputed frame embeddings [B, S_frames, d_model]
(the conv frontend's output) per the assignment.  Decoder length is the
model-native 448; the assigned seq_len applies to the ENCODER frame axis.
long_500k is skipped: both stacks are full attention (and the decoder is
448 tokens by design).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, activation="gelu", norm="ln",
    enc_dec=True, n_enc_layers=24, frontend="audio_stub",
    skip_shapes=(("long_500k", "skip(full-attn enc-dec; 448-token decoder)"),),
)

DEC_LEN = 448  # whisper's decoder context


def reduced() -> ArchConfig:
    return CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
                          kv_heads=4, head_dim=32, d_ff=256, vocab=512)
