"""Kimi K2 1T-A32B [arXiv:2501.kimi2; paper-table] — trillion-param MoE,
384 routed experts top-8 + 1 shared; first layer dense.

Note: the assignment specifies GQA kv=8 (not MLA); head_dim is set to 128
for MXU alignment (64 heads x 128 = 8192 projection width vs d_model 7168 —
q/k/v projections are rectangular, as in the real model family).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128, activation="silu_glu",
    pattern=("dense_first",) + ("moe",) * 60,
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, expert_d_ff=2048,
                  first_dense_layers=1, dense_d_ff=18432),
    skip_shapes=(("long_500k", "skip(full-attn)"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=3, d_model=128, n_heads=4, kv_heads=2, head_dim=32,
        d_ff=64, vocab=512,
        pattern=("dense_first", "moe", "moe"),
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, expert_d_ff=64,
                      first_dense_layers=1, dense_d_ff=256))
