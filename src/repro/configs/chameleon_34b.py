"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM, VQ image tokens.

The modality frontend is a STUB per the assignment: images arrive as VQ
codebook token ids inside the shared 65536 vocab, so the backbone is a dense
decoder LM over mixed text+image token streams (``input_specs`` emits the
mixed ids directly).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128, activation="silu_glu", frontend="vq_stub",
    skip_shapes=(("long_500k", "skip(full-attn)"),),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=128, n_heads=8, kv_heads=2,
                          head_dim=16, d_ff=256, vocab=512)
