"""Optimizers with FP32 master weights (paper Fig. 4).

The model may run its GEMMs in S2FP8/FP8/bf16, but the optimizer state —
master params, momenta — is FP32, and updates consume the (already
S2FP8-truncated, for those modes) gradients.  Implemented directly (no
optax dependency in this container): SGD-momentum (paper's ResNet runs),
AdamW (Transformer/NCF + modern archs), plus global-norm clipping.

State layout is a pytree mirroring params, so the FSDP sharding rules for
params apply verbatim to optimizer state (ZeRO-style).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

_fsdp_scope = threading.local()


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any            # momentum / first moment (pytree or None)
    v: Any            # second moment (pytree or None)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


@contextlib.contextmanager
def fsdp_grads(axis_name, sharded):
    """Declare that some leaves of the gradient/param trees flowing into
    :func:`global_norm` / :func:`clip_by_global_norm` are FSDP-SHARDED
    over ``axis_name`` (``sharded``: a bool pytree matching the trees,
    True = that leaf is a dim-0 shard of the logical leaf).

    The mesh-native train step (training/trainer.py,
    ``param_sharding="fsdp"/"fsdp_q"``) wraps ``optimizer.update`` and its
    grad-norm metric in this scope, so an optimizer built with
    ``clip_axis_name=None`` computes the MIXED global norm without any
    signature change: sharded-leaf sum-of-squares partials psum over the
    fsdp axis, replicated leaves count once.  Trace-time (threadlocal)
    state — the scope must be active while the update is being traced."""
    prev = getattr(_fsdp_scope, "v", None)
    _fsdp_scope.v = (axis_name, sharded,
                     jax.tree_util.tree_structure(sharded))
    try:
        yield
    finally:
        _fsdp_scope.v = prev


def global_norm(tree, axis_name=None) -> jnp.ndarray:
    """L2 norm over every leaf of ``tree``.

    ``axis_name`` makes it correct inside ``shard_map``/``pmap`` when the
    leaves are per-shard PARTIALS (e.g. gradients before the DP sync):
    the per-shard sum of squares is psum'd across the mapped axis (a name
    or tuple of names) before the sqrt, so every shard sees the GLOBAL
    norm.  Leave it None for replicated trees — post-sync gradients in
    the mesh-native train step are already global, and a psum there would
    double-count.

    Inside an active :func:`fsdp_grads` scope (and with ``axis_name``
    None), a tree whose structure matches the scope's bool tree gets the
    mixed treatment: sharded leaves psum their sum-of-squares over the
    scope's fsdp axis, replicated leaves stay local.
    """
    scope = getattr(_fsdp_scope, "v", None)
    if axis_name is None and scope is not None \
            and jax.tree_util.tree_structure(tree) == scope[2]:
        fsdp_axis, sharded, _ = scope
        flags = jax.tree_util.tree_leaves(sharded)
        leaves = jax.tree_util.tree_leaves(tree)
        sq_shard = [jnp.sum(jnp.square(x.astype(jnp.float32)))
                    for x, f in zip(leaves, flags) if f]
        sq_rep = [jnp.sum(jnp.square(x.astype(jnp.float32)))
                  for x, f in zip(leaves, flags) if not f]
        sq = jnp.zeros((), jnp.float32)
        if sq_shard:
            sq = sq + jax.lax.psum(jnp.sum(jnp.stack(sq_shard)), fsdp_axis)
        if sq_rep:
            sq = sq + jnp.sum(jnp.stack(sq_rep))
        return jnp.sqrt(sq)
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    sq = jnp.sum(jnp.stack(leaves))
    if axis_name is not None:
        sq = jax.lax.psum(sq, axis_name)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float, axis_name=None):
    """Scale ``grads`` so their global L2 norm is at most ``max_norm``.

    With ``axis_name``, the norm is the GLOBAL (cross-shard) norm — the
    psum-aware variant for clipping per-shard gradient partials inside a
    mapped context; 1-device and N-device clipping then agree (bitwise
    when the shard partials sum order-exactly; tests/test_mesh_train.py).
    """
    norm = global_norm(grads, axis_name=axis_name)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 clip_norm: Optional[float] = None,
                 clip_axis_name=None) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), None)

    def update(grads, state, params, lr):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm,
                                           axis_name=clip_axis_name)

        def new_m_fn(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            return momentum * m + g

        new_m = jax.tree_util.tree_map(new_m_fn, grads, state.m, params)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_m)
        return new_params, OptState(state.step + 1, new_m, None)

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, clip_norm: Optional[float] = 1.0,
          moment_dtype=jnp.float32, clip_axis_name=None) -> Optimizer:
    """AdamW with FP32 master params.  ``moment_dtype=bf16`` halves the
    optimizer-state footprint (the capacity lever for the 340B/1T configs —
    EXPERIMENTS.md §Capacity); moment *arithmetic* stays f32, only storage
    rounds.  ``clip_axis_name`` makes the clip norm psum-aware for
    per-shard gradient partials inside a mapped context (the mesh-native
    train step syncs grads BEFORE the optimizer, so it leaves this None)."""
    def _zeros_like(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, moment_dtype), params)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like(params), _zeros_like(params))

    def update(grads, state, params, lr):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm,
                                           axis_name=clip_axis_name)
        t = (state.step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        tmap = jax.tree_util.tree_map
        new_m = tmap(lambda g, m: (b1 * m.astype(jnp.float32)
                                   + (1 - b1) * g.astype(jnp.float32)),
                     grads, state.m)
        new_v = tmap(lambda g, v: (b2 * v.astype(jnp.float32)
                                   + (1 - b2) * jnp.square(g.astype(jnp.float32))),
                     grads, state.v)

        def upd(p, m, v):
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                step_ = step_ + lr * weight_decay * p32
            return (p32 - step_).astype(p.dtype)

        new_params = tmap(upd, params, new_m, new_v)
        store = lambda tree: tmap(lambda x: x.astype(moment_dtype), tree)
        return new_params, OptState(state.step + 1, store(new_m), store(new_v))

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgdm":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(name)
