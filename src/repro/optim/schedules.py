"""LR schedules: WSD (minicpm), cosine, and the paper's step decay."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(base_lr: float, warmup: int, stable: int, decay: int):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat, then 1/sqrt-ish decay."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = jnp.maximum(step - (warmup + stable), 0.0)
        factor = 0.5 ** (in_decay / jnp.maximum(decay, 1))
        return warm * factor
    return fn


def cosine(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return fn


def step_decay(base_lr: float, boundaries, factor: float = 0.1):
    """The paper's ResNet schedule: x0.1 at fixed epochs/steps."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        mult = jnp.asarray(1.0, jnp.float32)
        for b in boundaries:
            mult = jnp.where(step >= b, mult * factor, mult)
        return base_lr * mult
    return fn


def constant(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def make_schedule(name: str, base_lr: float, total_steps: int, warmup: int = 0):
    if name == "wsd":
        stable = int(total_steps * 0.8) - warmup
        return wsd(base_lr, warmup, max(stable, 1), max(total_steps - warmup - stable, 1))
    if name == "cosine":
        return cosine(base_lr, warmup, total_steps)
    if name == "constant":
        return constant(base_lr)
    raise ValueError(name)
