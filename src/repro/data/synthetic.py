"""Deterministic synthetic data generators (offline container — DESIGN.md §6).

All generators are *stateless functions of (seed, step)*: a restarted job
re-produces the exact batch stream, which is what makes checkpoint/restart
bit-exact (training/fault.py) and elastic re-sharding trivial (any host can
compute any batch slice).

Tasks are constructed so that learning is measurable within a few hundred
steps (the convergence benchmarks need a real signal to separate FP8's
divergence from S2FP8's convergence, reproducing the paper's mechanism):

  * lm_batch: order-k Markov token stream — a transformer must learn the
    transition table; cross-entropy has a known floor (the chain's entropy).
  * seq2seq_batch: reversal task (copy task family the tiny-Transformer
    literature uses).
  * ncf_batch: low-rank user x item preference matrix with logistic noise.
  * cifar_batch: class-conditional Gaussian blobs at CIFAR shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _key(seed: int, step: int, salt: int = 0):
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), salt)


def make_markov_table(seed: int, vocab: int, branching: int = 4) -> jnp.ndarray:
    """Each token has `branching` likely successors; returns [V, V] logits."""
    rng = np.random.default_rng(seed)
    table = np.full((vocab, vocab), -4.0, np.float32)
    for v in range(vocab):
        nxt = rng.choice(vocab, size=branching, replace=False)
        table[v, nxt] = rng.normal(2.0, 0.5, branching)
    return jnp.asarray(table)


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
             table: jnp.ndarray | None = None):
    """Markov stream: tokens[t+1] ~ softmax(table[tokens[t]])."""
    if table is None:
        table = make_markov_table(seed, vocab)
    k = _key(seed, step)
    k0, ks = jax.random.split(k)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def gen(tok, kt):
        nxt = jax.random.categorical(kt, table[tok], axis=-1)
        return nxt, nxt

    keys = jax.random.split(ks, seq)
    _, toks = jax.lax.scan(gen, first, keys)
    toks = jnp.moveaxis(toks, 0, 1)                    # [B, S]
    tokens = jnp.concatenate([first[:, None], toks[:, :-1]], axis=1)
    labels = toks
    return {"tokens": tokens.astype(jnp.int32), "labels": labels.astype(jnp.int32)}


def seq2seq_batch(seed: int, step: int, batch: int, src_len: int, tgt_len: int,
                  vocab: int):
    """Reversal: target = reversed source (shifted for teacher forcing)."""
    k = _key(seed, step)
    src = jax.random.randint(k, (batch, src_len), 2, vocab)
    rev = src[:, ::-1][:, :tgt_len]
    bos = jnp.ones((batch, 1), jnp.int32)
    dec_in = jnp.concatenate([bos, rev[:, :-1]], axis=1)
    return {"enc_tokens": src.astype(jnp.int32),
            "dec_tokens": dec_in.astype(jnp.int32),
            "dec_labels": rev.astype(jnp.int32)}


def ncf_batch(seed: int, step: int, batch: int, n_users: int, n_items: int,
              rank: int = 8):
    """Implicit feedback from a fixed low-rank preference matrix."""
    ku = jax.random.PRNGKey(seed)
    u_emb = jax.random.normal(jax.random.fold_in(ku, 1), (n_users, rank))
    i_emb = jax.random.normal(jax.random.fold_in(ku, 2), (n_items, rank))
    k = _key(seed, step)
    k1, k2, k3 = jax.random.split(k, 3)
    users = jax.random.randint(k1, (batch,), 0, n_users)
    items = jax.random.randint(k2, (batch,), 0, n_items)
    score = jnp.einsum("br,br->b", u_emb[users], i_emb[items]) / jnp.sqrt(rank)
    prob = jax.nn.sigmoid(2.0 * score)
    labels = (jax.random.uniform(k3, (batch,)) < prob).astype(jnp.int32)
    return {"users": users, "items": items, "labels": labels}


def cifar_batch(seed: int, step: int, batch: int, n_classes: int = 10):
    """Class-conditional Gaussian blobs at CIFAR-10 shapes."""
    kc = jax.random.PRNGKey(seed)
    centers = jax.random.normal(jax.random.fold_in(kc, 7),
                                (n_classes, 32, 32, 3)) * 0.8
    k = _key(seed, step)
    k1, k2 = jax.random.split(k)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    noise = jax.random.normal(k2, (batch, 32, 32, 3)) * 0.6
    return {"images": centers[labels] + noise, "labels": labels}


class HostPrefetcher:
    """Overlaps next-batch generation with the current step (thread pool).

    On real multi-host pods each process generates only its addressable
    slice (stateless (seed, step, host_id) indexing makes that exact).
    """

    def __init__(self, gen_fn, n_prefetch: int = 2):
        import concurrent.futures as cf
        self._gen = gen_fn
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending = {}
        self._n = n_prefetch

    def get(self, step: int):
        for s in range(step, step + self._n):
            if s not in self._pending:
                self._pending[s] = self._pool.submit(self._gen, s)
        fut = self._pending.pop(step)
        return fut.result()
