"""Memory-optimal chunked attention with a flash-style custom VJP.

WHY (hypothesis from the §Perf loop, EXPERIMENTS.md): the naive chunked
attention (models/blocks.py:chunked_attention) is numerically fine but its
*autodiff schedule* is catastrophic — under `jax.checkpoint` the re-forward
linearizes the inner kv-scan, which stacks every per-tile residual
(scores, probs, corrections) into [nq, nk, ...] f32 buffers.  The static
HLO analysis of minicpm/train_4k showed ~80% of all HBM traffic coming from
exactly those DUS/DS stacks (~90 TB/device/step).

FIX: flash attention's backward — save only (out, rowwise logsumexp) from
the forward and *recompute* score tiles in the backward pass.  Residual
memory drops from O(S^2) to O(S), traffic drops by the stack factor, at the
cost of one extra QK^T recompute (compute term was 100x under the memory
term, so trading FLOPs for bytes is the right direction on v5e's
197TFLOP/s / 819GB/s balance point).

This is also exactly the schedule of the Pallas TPU kernel
(kernels/flash_attention.py) — the pure-JAX version keeps the multi-pod
dry-run compilable on the CPU backend while the kernel is the on-TPU
hot-spot implementation.

Interface matches blocks.chunked_attention: q [B,KV,G,Sq,d], k/v [B,KV,Sk,d].
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

_MASK = -1e30


def _mask_for(iq, ik, q_chunk, kv_chunk, sq, sk, causal, window):
    qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None] + (sk - sq)
    kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=None,
                    q_chunk=1024, kv_chunk=1024):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk):
    b, kvh, g, sq, d = q.shape
    sk = k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)
    kc = k.reshape(b, kvh, nk, kv_chunk, d)
    vc = v.reshape(b, kvh, nk, kv_chunk, d)
    qc = q.reshape(b, kvh, g, nq, q_chunk, d)

    def q_step(iq):
        qi = jax.lax.dynamic_index_in_dim(qc, iq, 3, keepdims=False) \
            .astype(jnp.float32)

        def kv_step(carry, ik):
            m, l, acc = carry
            ki = jax.lax.dynamic_index_in_dim(kc, ik, 2, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(vc, ik, 2, keepdims=False)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi,
                           ki.astype(jnp.float32)) * scale
            mask = _mask_for(iq, ik, q_chunk, kv_chunk, sq, sk, causal, window)
            s = jnp.where(mask[None, None, None], s, _MASK)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum("bkgqs,bksd->bkgqd", p,
                                              vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk, 1), _MASK, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # logsumexp rows
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype), lse

    outs = jax.lax.map(q_step, jnp.arange(nq))
    out = jnp.moveaxis(outs[0], 0, 3).reshape(b, kvh, g, sq, d)
    lse = jnp.moveaxis(outs[1], 0, 3).reshape(b, kvh, g, sq, 1)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    b, kvh, g, sq, d = q.shape
    sk = k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)

    doutf = dout.astype(jnp.float32)
    outf = out.astype(jnp.float32)
    # D_i = sum_d dout_i * out_i  (flash-2 backward identity)
    delta = jnp.sum(doutf * outf, axis=-1, keepdims=True)   # [B,KV,G,Sq,1]

    kc = k.reshape(b, kvh, nk, kv_chunk, d)
    vc = v.reshape(b, kvh, nk, kv_chunk, d)
    qc = q.reshape(b, kvh, g, nq, q_chunk, d)
    dc = doutf.reshape(b, kvh, g, nq, q_chunk, d)
    lc = lse.reshape(b, kvh, g, nq, q_chunk, 1)
    dl = delta.reshape(b, kvh, g, nq, q_chunk, 1)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry
        qi = jax.lax.dynamic_index_in_dim(qc, iq, 3, keepdims=False) \
            .astype(jnp.float32)
        di = jax.lax.dynamic_index_in_dim(dc, iq, 3, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(lc, iq, 3, keepdims=False)
        deli = jax.lax.dynamic_index_in_dim(dl, iq, 3, keepdims=False)

        def kv_step(inner, ik):
            dq_acc, dk_a, dv_a = inner
            ki = jax.lax.dynamic_index_in_dim(kc, ik, 2, keepdims=False) \
                .astype(jnp.float32)
            vi = jax.lax.dynamic_index_in_dim(vc, ik, 2, keepdims=False) \
                .astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, ki) * scale
            mask = _mask_for(iq, ik, q_chunk, kv_chunk, sq, sk, causal, window)
            s = jnp.where(mask[None, None, None], s, _MASK)
            p = jnp.exp(s - li)                              # [B,KV,G,cq,ck]
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv_blk = jnp.einsum("bkgqs,bkgqd->bksd", p, di)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", di, vi)
            ds = p * (dp - deli) * scale
            dq_blk = jnp.einsum("bkgqs,bksd->bkgqd", ds, ki)
            dk_blk = jnp.einsum("bkgqs,bkgqd->bksd", ds, qi)
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, jax.lax.dynamic_index_in_dim(dk_a, ik, 2, keepdims=False)
                + dk_blk, ik, 2)
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, jax.lax.dynamic_index_in_dim(dv_a, ik, 2, keepdims=False)
                + dv_blk, ik, 2)
            return (dq_acc + dq_blk, dk_a, dv_a), None

        dq0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (dqi, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dqi

    dk0 = jnp.zeros((b, kvh, nk, kv_chunk, d), jnp.float32)
    dv0 = jnp.zeros((b, kvh, nk, kv_chunk, d), jnp.float32)
    (dkc, dvc), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, kvh, g, sq, d).astype(q.dtype)
    dk = dkc.reshape(b, kvh, sk, d).astype(k.dtype)
    dv = dvc.reshape(b, kvh, sk, d).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
