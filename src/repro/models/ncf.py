"""Neural Collaborative Filtering (He et al. 2017) — the paper's §4.4 model.

NeuMF topology: GMF branch (elementwise product of embeddings) + MLP branch
(concatenated embeddings through a tower), fused into one logit.  Embedding
lookups and all MLP matmuls run through the numeric policy, matching the
paper's "Matrix-Multiplications and look-ups from the embeddings in S2FP8".
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.policy import Policy


def init_ncf(key, n_users: int, n_items: int, factors: int = 8,
             mlp_layers=(64, 32, 16, 8)) -> Dict:
    ks = jax.random.split(key, 6 + len(mlp_layers))
    mlp_embed = mlp_layers[0] // 2
    p = {
        "gmf_user": jax.random.normal(ks[0], (n_users, factors)) * 0.01,
        "gmf_item": jax.random.normal(ks[1], (n_items, factors)) * 0.01,
        "mlp_user": jax.random.normal(ks[2], (n_users, mlp_embed)) * 0.01,
        "mlp_item": jax.random.normal(ks[3], (n_items, mlp_embed)) * 0.01,
        "mlp": [],
        "out": jax.random.normal(ks[4], (factors + mlp_layers[-1], 1)) * 0.1,
    }
    d_in = mlp_layers[0]
    for i, d_out in enumerate(mlp_layers[1:]):
        p["mlp"].append({
            "w": jax.random.normal(ks[5 + i], (d_in, d_out)) / math.sqrt(d_in),
            "b": jnp.zeros((d_out,)),
        })
        d_in = d_out
    return p


def ncf_logits(p, users, items, pol: Policy):
    def lookup(table, idx):
        if pol.mode in ("s2fp8", "s2fp8_e4m3", "fp8", "fp8_ls"):
            table = pol.truncate(table)
        return jnp.take(table, idx, axis=0)

    gmf = lookup(p["gmf_user"], users) * lookup(p["gmf_item"], items)
    h = jnp.concatenate([lookup(p["mlp_user"], users),
                         lookup(p["mlp_item"], items)], axis=-1)
    for layer in p["mlp"]:
        h = jax.nn.relu(pol.dot(h, layer["w"]) + layer["b"])
    fused = jnp.concatenate([gmf, h], axis=-1)
    return pol.dot(fused, p["out"])[..., 0]


def loss_fn(p, batch, pol: Policy):
    """Binary cross-entropy on implicit feedback (label in {0,1})."""
    logits = ncf_logits(p, batch["users"], batch["items"], pol)
    labels = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"nll": loss}


def hit_ratio(p, users, pos_items, neg_items, pol: Policy, k: int = 10):
    """HR@k: rank 1 positive among 99 negatives (paper's eval protocol)."""
    all_items = jnp.concatenate([pos_items[:, None], neg_items], axis=1)  # [B, 100]
    b, n = all_items.shape
    u = jnp.repeat(users[:, None], n, axis=1)
    scores = ncf_logits(p, u.reshape(-1), all_items.reshape(-1), pol).reshape(b, n)
    rank_of_pos = jnp.sum(scores > scores[:, :1], axis=1)
    return jnp.mean(rank_of_pos < k)
