"""Model building blocks (numerics-agnostic: all GEMMs go through a Policy).

Block types (configs/base.py BLOCK_TYPES):
  dense / local : pre-norm GQA attention (+ optional sliding window) + MLP
  moe           : GQA attention + (shared + routed top-k) expert MLP
  mamba1        : Mamba-1 selective SSM (falcon-mamba)
  mamba2        : Mamba-2 SSD, multi-head scalar decay (zamba2)
  attn          : attention-only block with MLP (zamba2 shared block)

Every block exposes:
  init_block(block_type, cfg, key)   -> params dict
  block_apply(block_type, params, x, cfg, policy, positions, cache,
              cache_index, mode)     -> (y, new_cache, aux)
  init_cache(block_type, cfg, batch, max_len, dtype) -> cache pytree

``mode``: "train" (full-sequence, no cache), "prefill" (full sequence,
cache returned), "decode" (S==1 against the cache).

Attention avoids materializing repeated KV heads by computing in grouped
layout [B, KV, G, S, hd]; long sequences use a doubly-chunked (q x kv)
flash-style lax.scan so HLO size and live memory stay O(chunk^2) — the
pure-JAX counterpart of kernels/flash_attention.py (which is the TPU target
for this hot-spot).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import statsbank
from repro.core.policy import Policy
from repro.parallel.sharding import shard

_MASK = -1e30


# =========================================================================
# Norms / activations / RoPE
# =========================================================================

def init_norm(cfg: ArchConfig, dim: int) -> Dict[str, jnp.ndarray]:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ArchConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    return y.astype(x.dtype)


def activate(h_gate, h_lin, activation: str):
    if activation == "silu_glu":
        return jax.nn.silu(h_gate) * h_lin
    if activation == "gelu_glu":
        return jax.nn.gelu(h_gate) * h_lin
    if activation == "gelu":
        return jax.nn.gelu(h_gate)
    if activation == "sq_relu":           # Nemotron-4 squared ReLU
        r = jax.nn.relu(h_gate)
        return r * r
    raise ValueError(activation)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, hd]; positions: [S] int32, or [B, S] for per-slot decode
    (serving slots sit at different depths, so each batch row rotates by its
    own position)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., :, None] * freqs        # [..., S, half]
    if positions.ndim == 2:
        # [B, S, half] -> [B, 1..., S, half]: broadcast over head axes of x
        ang = ang.reshape(ang.shape[:1] + (1,) * (x.ndim - 3) + ang.shape[1:])
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# =========================================================================
# Attention
# =========================================================================

def _grouped(q, kv_heads):
    """[B, H, S, d] -> [B, KV, G, S, d]."""
    b, h, s, d = q.shape
    return q.reshape(b, kv_heads, h // kv_heads, s, d)


def _attn_einsum(policy: Optional[Policy], spec: str, a, b):
    """Attention contraction through the numeric policy: both attention
    GEMMs (scores QKᵀ and the value product) are ``policy.einsum`` calls,
    so under s2fp8 they get the paper's full "before and after every
    matrix-matrix product" dataflow — and on the payload path they route
    through the batched payload-domain kernel (core/qdot.py) like every
    other bilinear op.  Softmax math stays f32 in the caller."""
    if policy is None:
        return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
    return policy.einsum(spec, a, b).astype(jnp.float32)


def full_attention(q, k, v, *, causal=True, window=None, policy: Policy = None):
    """q: [B,KV,G,Sq,d]; k,v: [B,KV,Sk,d]. Plain masked softmax attention.

    Payload-mode policies take a planner-recognized fast path: the
    score/value einsum PAIR is one fused payload flash node
    (policy.flash_attention) instead of two batched payload GEMMs with an
    HBM round-trip of the [S, S] score tensor between them — same masked
    softmax semantics, VMEM-only score tiles."""
    if policy is not None and policy.uses_payload_gemm:
        return policy.flash_attention(q, k, v, causal=causal,
                                      window=window).astype(q.dtype)
    d = q.shape[-1]
    sq, sk = q.shape[3], k.shape[2]
    logits = _attn_einsum(policy, "bkgqd,bksd->bkgqs", q, k) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, _MASK)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _attn_einsum(policy, "bkgqs,bksd->bkgqd", probs, v)
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None,
                      q_chunk=1024, kv_chunk=1024, policy: Policy = None):
    """Flash-style doubly-chunked attention (pure JAX; see module docstring).

    q: [B,KV,G,Sq,d]; k,v: [B,KV,Sk,d].  The S2FP8 policy truncates the
    q/k/v tensors once (per-tensor statistics, paper-faithful placement)
    and the output; in-softmax math stays f32.
    """
    b, kvh, g, sq, d = q.shape
    sk = k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    if policy is not None:
        q, k, v = policy.truncate(q), policy.truncate(k), policy.truncate(v)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)

    kc = k.reshape(b, kvh, nk, kv_chunk, d)
    vc = v.reshape(b, kvh, nk, kv_chunk, d)
    qc = q.reshape(b, kvh, g, nq, q_chunk, d)

    def q_step(iq):
        qi = jax.lax.dynamic_index_in_dim(qc, iq, axis=3, keepdims=False)
        qi = qi.astype(jnp.float32)                          # [B,KV,G,cq,d]

        def kv_step(carry, ik):
            m, l, acc = carry
            ki = jax.lax.dynamic_index_in_dim(kc, ik, axis=2, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(vc, ik, axis=2, keepdims=False)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, ki.astype(jnp.float32)) * scale
            qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None] + (sk - sq)
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None, None], s, _MASK)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum("bkgqs,bksd->bkgqd",
                                              p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk, 1), _MASK, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype)                     # [B,KV,G,cq,d]

    out = jax.lax.map(q_step, jnp.arange(nq))                # [nq,B,KV,G,cq,d]
    out = jnp.moveaxis(out, 0, 3).reshape(b, kvh, g, sq, d)
    if policy is not None:
        out = policy.truncate(out)
    return out


def decode_attention(q, k_cache, v_cache, valid, *, policy: Policy = None):
    """One-token attention vs. a cache.  q: [B,KV,G,1,d]; caches [B,KV,Smax,d].

    ``valid``: bool [Smax] mask of live cache slots (computed by the caller —
    linear fill for full caches, ring occupancy for sliding-window caches),
    or [B, Smax] when batch rows sit at different positions (serving).
    The KV-cache seq axis may be sharded ("kv_seq") — the contraction +
    softmax reductions then lower to partial-softmax collectives under GSPMD.
    """
    d = q.shape[-1]
    logits = _attn_einsum(policy, "bkgqd,bksd->bkgqs", q, k_cache) / math.sqrt(d)
    vmask = (valid[:, None, None, None, :] if valid.ndim == 2
             else valid[None, None, None, None, :])
    logits = jnp.where(vmask, logits, _MASK)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _attn_einsum(policy, "bkgqs,bksd->bkgqd", probs, v_cache)
    return out.astype(q.dtype)


# =========================================================================
# MLP / MoE
# =========================================================================

def init_mlp(cfg: ArchConfig, key, d_in: int, d_ff: int) -> Dict[str, jnp.ndarray]:
    k1, k2, k3 = jax.random.split(key, 3)
    glu = cfg.activation.endswith("_glu")
    std_in = 1.0 / math.sqrt(d_in)
    std_ff = 1.0 / math.sqrt(d_ff)
    p = {
        "w_gate": jax.random.normal(k1, (d_in, d_ff), jnp.float32) * std_in,
        "w_down": jax.random.normal(k2, (d_ff, d_in), jnp.float32) * std_ff,
    }
    if glu:
        p["w_up"] = jax.random.normal(k3, (d_in, d_ff), jnp.float32) * std_in
    return p


def mlp_fwd(p, x, cfg: ArchConfig, pol: Policy):
    glu = cfg.activation.endswith("_glu")
    # named StatsBank scope: every GEMM truncation site inside this MLP
    # gets a stable ".../mlp/tN" key in the per-layer stats bank
    with statsbank.scope("mlp"):
        hg = pol.dot(x, p["w_gate"].astype(x.dtype))
        hl = pol.dot(x, p["w_up"].astype(x.dtype)) if glu else None
        h = activate(hg, hl, cfg.activation)
        h = shard(h, *(("batch",) + (None,) * (h.ndim - 2) + ("mlp",)))
        return pol.dot(h, p["w_down"].astype(x.dtype))


def init_moe(cfg: ArchConfig, key) -> Dict[str, jnp.ndarray]:
    m = cfg.moe
    d, f = cfg.d_model, m.expert_d_ff
    glu = cfg.activation.endswith("_glu")
    keys = jax.random.split(key, 6)
    std_d, std_f = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(keys[0], (d, m.n_experts), jnp.float32) * std_d,
        "we_gate": jax.random.normal(keys[1], (m.n_experts, d, f), jnp.float32) * std_d,
        "we_down": jax.random.normal(keys[2], (m.n_experts, f, d), jnp.float32) * std_f,
    }
    if glu:
        p["we_up"] = jax.random.normal(keys[3], (m.n_experts, d, f), jnp.float32) * std_d
    if m.n_shared:
        # shared experts fused into one dense MLP of width n_shared * f
        p["shared"] = init_mlp(cfg, keys[4], d, m.n_shared * f)
    return p


def moe_fwd(p, x, cfg: ArchConfig, pol: Policy):
    """Gather-based capacity dispatch (see DESIGN.md §2/§4).

    Token-choice top-k routing; each expert then takes its top-C tokens by
    routing weight (C = T*k/E * capacity_factor, rounded up to 128).  Dropped
    tokens fall back to the shared-expert/residual path only.  FLOPs scale
    with routed compute (k/E), not n_experts — this keeps the roofline's
    MODEL_FLOPS/HLO_FLOPs ratio honest for the MoE cells.
    """
    m = cfg.moe
    if m.routing == "grouped":
        return _moe_fwd_grouped(p, x, cfg, pol)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    glu = cfg.activation.endswith("_glu")

    # Router stays f32 (policy decision, DESIGN.md §5).
    logits = jnp.dot(xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate, idx = jax.lax.top_k(probs, m.top_k)                  # [T, k]
    aff = jnp.zeros((t, m.n_experts), jnp.float32)
    aff = aff.at[jnp.arange(t)[:, None], idx].set(gate)        # [T, E]

    cap = int(math.ceil(t * m.top_k / m.n_experts * m.capacity_factor))
    cap = max(128, ((cap + 127) // 128) * 128)
    cap = min(cap, t)
    w_ec, tok_idx = jax.lax.top_k(aff.T, cap)                  # [E, C]

    xe = jnp.take(xt, tok_idx.reshape(-1), axis=0)
    xe = xe.reshape(m.n_experts, cap, d)
    xe = shard(xe, "expert", "batch", None)

    hg = pol.einsum("ecd,edf->ecf", xe, p["we_gate"].astype(xe.dtype))
    hl = pol.einsum("ecd,edf->ecf", xe, p["we_up"].astype(xe.dtype)) if glu else None
    h = activate(hg, hl, cfg.activation)
    h = shard(h, "expert", "batch", None)
    oe = pol.einsum("ecf,efd->ecd", h, p["we_down"].astype(xe.dtype))
    oe = oe * w_ec[..., None].astype(oe.dtype)

    out = jnp.zeros((t, d), x.dtype)
    out = out.at[tok_idx.reshape(-1)].add(oe.reshape(-1, d))

    if m.n_shared:
        out = out + mlp_fwd(p["shared"], xt, cfg, pol)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e.
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, m.n_experts), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f_e * p_e) * m.router_aux_weight
    return out.reshape(b, s, d), aux


def _moe_fwd_grouped(p, x, cfg: ArchConfig, pol: Policy):
    """Grouped (per-batch-row) routing: token gathers stay data-local.

    Each batch row routes its own tokens; capacity is per (row, expert).
    The only cross-shard movement is resharding xe's expert axis onto the
    model axis — orders of magnitude less traffic than all-gathering the
    full activation across data shards (see EXPERIMENTS.md §Perf / kimi).
    """
    m = cfg.moe
    b, s, d = x.shape
    glu = cfg.activation.endswith("_glu")

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # [B,S,E]
    gate, idx = jax.lax.top_k(probs, m.top_k)                 # [B,S,k]
    aff = jnp.zeros((b, s, m.n_experts), jnp.float32)
    bi = jnp.arange(b)[:, None, None]
    si = jnp.arange(s)[None, :, None]
    aff = aff.at[bi, si, idx].set(gate)                       # [B,S,E]

    cap = int(math.ceil(s * m.top_k / m.n_experts * m.capacity_factor))
    cap = max(16, ((cap + 15) // 16) * 16)
    cap = min(cap, s)
    w_ec, tok_idx = jax.lax.top_k(aff.transpose(0, 2, 1), cap)  # [B,E,C]

    xe = jnp.take_along_axis(x[:, None], tok_idx[..., None], axis=2)
    xe = shard(xe, "batch", "expert", None, None)             # [B,E,C,D]

    hg = pol.einsum("becd,edf->becf", xe, p["we_gate"].astype(xe.dtype))
    hl = pol.einsum("becd,edf->becf", xe, p["we_up"].astype(xe.dtype)) if glu else None
    h = activate(hg, hl, cfg.activation)
    h = shard(h, "batch", "expert", None, None)
    oe = pol.einsum("becf,efd->becd", h, p["we_down"].astype(xe.dtype))
    oe = oe * w_ec[..., None].astype(oe.dtype)

    out = jnp.zeros((b, s, d), x.dtype)
    out = jax.vmap(lambda ob, ib, vb: ob.at[ib.reshape(-1)].add(
        vb.reshape(-1, d)))(out, tok_idx, oe)

    if m.n_shared:
        out = out + mlp_fwd(p["shared"], x, cfg, pol)

    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, m.n_experts), axis=2),
                   axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(f_e * p_e) * m.router_aux_weight
    return shard(out, "batch", None, None), aux


# =========================================================================
# Attention-bearing blocks (dense / local / moe / attn)
# =========================================================================

def init_attn_block(cfg: ArchConfig, key, block_type: str) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    keys = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    std_o = 1.0 / math.sqrt(h * hd)
    p = {
        "ln1": init_norm(cfg, d),
        "wq": jax.random.normal(keys[0], (d, h * hd), jnp.float32) * std,
        "wk": jax.random.normal(keys[1], (d, kv * hd), jnp.float32) * std,
        "wv": jax.random.normal(keys[2], (d, kv * hd), jnp.float32) * std,
        "wo": jax.random.normal(keys[3], (h * hd, d), jnp.float32) * std_o,
        "ln2": init_norm(cfg, d),
    }
    if block_type == "moe":
        p["moe"] = init_moe(cfg, keys[4])
    else:
        d_ff = cfg.d_ff
        if block_type == "dense_first" and cfg.moe:      # MoE arch dense layers
            d_ff = cfg.moe.dense_d_ff or cfg.d_ff
        p["mlp"] = init_mlp(cfg, keys[4], d, d_ff)
    return p


def attn_block_apply(p, x, cfg: ArchConfig, pol: Policy, positions,
                     cache, cache_index, mode: str, block_type: str,
                     cache_fmt: Optional[str] = None):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.kv_heads
    window = cfg.window if block_type == "local" else None

    xn = apply_norm(p["ln1"], x, cfg)
    # named StatsBank scope for the attention projections ("attn/tN" keys);
    # the attention-internal q/k/v/out truncations sit in the block root
    with statsbank.scope("attn"):
        q = pol.dot(xn, p["wq"].astype(x.dtype)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        k = pol.dot(xn, p["wk"].astype(x.dtype)).reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
        v = pol.dot(xn, p["wv"].astype(x.dtype)).reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "kv", None, None)
    v = shard(v, "batch", "kv", None, None)
    qg = _grouped(q, kvh)

    new_cache = cache
    if mode == "decode" and cache is not None and "kp" in cache:
        # Paged payload cache (serving): per-slot block-table write of the
        # new K/V token plus gather-dequant attention over the slot's
        # blocks.  Stats are frozen (alpha, beta) leaves carried in the
        # cache itself, so this path runs zero stats reductions.
        from repro.serving import paged_cache as _paged
        assert s == 1
        attn, new_cache = _paged.update_and_attend(
            qg, k, v, cache, cache_index, policy=pol, cache_fmt=cache_fmt)
    elif mode == "decode":
        assert s == 1 and cache is not None
        smax = cache["k"].shape[2]
        kpos = jnp.arange(smax)
        ci = jnp.asarray(cache_index)
        k_store, v_store = k, v
        if statsbank.current_session() is not None:
            # KV-cache range site: the stored copy is truncated at the
            # per-layer kv_cache/t{0,1} sites so export probes learn the
            # cache's (alpha, beta); frozen serving then stores exactly the
            # values the payload cache would round-trip.
            with statsbank.scope("kv_cache"):
                k_store = pol.truncate(k)
                v_store = pol.truncate(v)
        if ci.ndim == 1:
            # per-slot positions (serving): each batch row writes and masks
            # at its own depth instead of one shared scalar index
            bi = jnp.arange(b)
            if window and smax <= window:
                slot = jax.lax.rem(ci, smax)
                valid = kpos[None, :] < jnp.minimum(ci + 1, smax)[:, None]
            else:
                slot = ci
                valid = kpos[None, :] <= ci[:, None]
                if window:
                    valid &= kpos[None, :] > ci[:, None] - window
            k_cache = cache["k"].at[bi, :, slot].set(
                k_store[:, :, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[bi, :, slot].set(
                v_store[:, :, 0].astype(cache["v"].dtype))
        else:
            if window and smax <= window:
                # ring buffer: overwrite the oldest slot; all live slots are
                # within the window by construction.
                slot = jax.lax.rem(ci, smax)
                valid = kpos < jnp.minimum(ci + 1, smax)
            else:
                slot = ci
                valid = kpos <= ci
                if window:
                    valid &= kpos > ci - window
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_store.astype(cache["k"].dtype), slot, axis=2)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_store.astype(cache["v"].dtype), slot, axis=2)
        k_cache = shard(k_cache, "batch", "kv", "kv_seq", None)
        v_cache = shard(v_cache, "batch", "kv", "kv_seq", None)
        attn = decode_attention(qg, k_cache, v_cache, valid, policy=pol)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        causal = not (cfg.enc_dec and block_type == "encoder")
        if s > 2048:
            if cfg.attn_impl == "flash":
                if pol is None:
                    from repro.models.flash import flash_attention as _fa
                    attn = _fa(qg, k, v, causal, window)
                else:
                    # session-aware routing: payload policies run the fused
                    # payload flash node, all others the pure-JAX flash VJP
                    # with bank-site truncations (not local stats)
                    attn = pol.flash_attention(
                        qg, k, v, causal=causal,
                        window=window).astype(qg.dtype)
            else:
                attn = chunked_attention(qg, k, v, causal=causal,
                                         window=window, policy=pol)
        else:
            attn = full_attention(qg, k, v, causal=causal, window=window, policy=pol)
        if mode == "prefill" and cache is not None:
            k_store, v_store = k, v
            if statsbank.current_session() is not None:
                # same kv_cache/t{0,1} sites as the decode write path: the
                # cache holds the truncated (grid-snapped) values, so a
                # payload re-encode of it is lossless (dequant∘quant ≡
                # truncate; see core/s2fp8.py)
                with statsbank.scope("kv_cache"):
                    k_store = pol.truncate(k)
                    v_store = pol.truncate(v)
            smax = cache["k"].shape[2]
            kc = jnp.zeros_like(cache["k"])
            vc = jnp.zeros_like(cache["v"])
            if window:
                # window cache: keep only the last `smax_local` positions
                keep = min(smax, s)
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k_store[:, :, s - keep:].astype(kc.dtype), 0, axis=2)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v_store[:, :, s - keep:].astype(vc.dtype), 0, axis=2)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k_store.astype(kc.dtype), 0, axis=2)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v_store.astype(vc.dtype), 0, axis=2)
            new_cache = {"k": shard(kc, "batch", "kv", "kv_seq", None),
                         "v": shard(vc, "batch", "kv", "kv_seq", None)}

    attn = attn.reshape(b, kvh * (h // kvh), s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    with statsbank.scope("attn"):
        x = x + pol.dot(attn, p["wo"].astype(x.dtype))
    x = shard(x, "batch", None, None)

    aux = jnp.zeros((), jnp.float32)
    xn2 = apply_norm(p["ln2"], x, cfg)
    if block_type == "moe":
        with statsbank.scope("moe"):
            y, aux = moe_fwd(p["moe"], xn2, cfg, pol)
    else:
        y = mlp_fwd(p["mlp"], xn2, cfg, pol)
    x = x + y
    return shard(x, "batch", None, None), new_cache, aux


# =========================================================================
# Mamba-1 (falcon-mamba)
# =========================================================================

def _causal_conv1d(x, kernel, bias):
    """x: [B,S,C]; kernel: [K,C] depthwise; causal."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), kernel[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + bias).astype(x.dtype)


def init_mamba1(cfg: ArchConfig, key) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or d // 16
    keys = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    return {
        "ln": init_norm(cfg, d),
        "w_in": jax.random.normal(keys[0], (d, 2 * di), jnp.float32) * std,
        "conv_w": jax.random.normal(keys[1], (s.conv_kernel, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_x": jax.random.normal(keys[2], (di, dtr + 2 * s.state), jnp.float32) / math.sqrt(di),
        "w_dt": jax.random.normal(keys[3], (dtr, di), jnp.float32) / math.sqrt(dtr),
        "b_dt": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, s.state + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(keys[4], (di, d), jnp.float32) / math.sqrt(di),
    }


def mamba1_apply(p, x, cfg: ArchConfig, pol: Policy, cache, mode: str):
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.expand * d
    dtr = s_cfg.dt_rank or d // 16
    n = s_cfg.state
    kk = s_cfg.conv_kernel

    xn = apply_norm(p["ln"], x, cfg)
    xz = pol.dot(xn, p["w_in"].astype(x.dtype))            # [B,S,2di]
    xpart, z = jnp.split(xz, 2, axis=-1)
    xpart = shard(xpart, "batch", None, "mlp")

    if mode == "decode":
        window = jnp.concatenate([cache["conv"], xpart], axis=1)   # [B,K,di]
        xc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                        p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc).astype(x.dtype)[:, None]              # [B,1,di]
        new_conv = window[:, 1:]
    else:
        xc = jax.nn.silu(_causal_conv1d(xpart, p["conv_w"], p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
        new_conv = None if cache is None else xpart[:, -(kk - 1):]

    xdb = pol.dot(xc, p["w_x"].astype(x.dtype)).astype(jnp.float32)
    dt_r, bmat, cmat = jnp.split(xdb, [dtr, dtr + n], axis=-1)     # [B,S,*]
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r, p["w_dt"]) + p["b_dt"])
    a = -jnp.exp(p["a_log"])                                        # [di, n]
    xcf = xc.astype(jnp.float32)

    if mode == "decode":
        h0 = cache["ssm"].astype(jnp.float32)                       # [B,di,n]
        da = jnp.exp(dt[:, 0, :, None] * a)                         # [B,di,n]
        hn = h0 * da + (dt[:, 0, :, None] * bmat[:, 0, None, :]) * xcf[:, 0, :, None]
        y = jnp.einsum("bdn,bn->bd", hn, cmat[:, 0])[:, None]       # [B,1,di]
        new_ssm = hn.astype(cache["ssm"].dtype)
    else:
        def step(h, inp):
            xt, dtt, bt, ct = inp
            da = jnp.exp(dtt[:, :, None] * a)                       # [B,di,n]
            h = h * da + (dtt[:, :, None] * bt[:, None, :]) * xt[:, :, None]
            yt = jnp.einsum("bdn,bn->bd", h, ct)
            return h, yt

        h0 = jnp.zeros((b, di, n), jnp.float32)
        if cfg.ssm_impl == "unroll8" and s % 8 == 0:
            # 8 timesteps per scan body: the state lives in registers/VMEM
            # across the unrolled steps, cutting its HBM round-trips 8x.
            u = 8

            def chunk_step(h, inp):
                xs_c, dt_c, b_c, c_c = inp                  # [u, B, ...]
                ys = []
                for t in range(u):
                    h, yt = step(h, (xs_c[t], dt_c[t], b_c[t], c_c[t]))
                    ys.append(yt)
                return h, jnp.stack(ys)

            resh = lambda v: jnp.moveaxis(v, 1, 0).reshape(
                (s // u, u) + (b,) + v.shape[2:])
            xs = (resh(xcf), resh(dt), resh(bmat), resh(cmat))
            hn, ys = jax.lax.scan(chunk_step, h0, xs)
            y = jnp.moveaxis(ys.reshape((s, b) + ys.shape[3:]), 0, 1)
        else:
            xs = (jnp.moveaxis(xcf, 1, 0), jnp.moveaxis(dt, 1, 0),
                  jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
            hn, ys = jax.lax.scan(step, h0, xs)
            y = jnp.moveaxis(ys, 0, 1)                              # [B,S,di]
        new_ssm = hn if cache is not None else None

    y = (y + p["d_skip"] * xcf).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = pol.dot(y, p["w_out"].astype(x.dtype))
    new_cache = cache
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
    return x + out, new_cache, jnp.zeros((), jnp.float32)


# =========================================================================
# Mamba-2 (zamba2): multi-head SSD with scalar per-head decay
# =========================================================================

def init_mamba2(cfg: ArchConfig, key) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    keys = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    return {
        "ln": init_norm(cfg, d),
        # order: [x(di) | z(di) | B(n) | C(n) | dt(nh)]
        "w_in": jax.random.normal(keys[0], (d, 2 * di + 2 * s.state + nh), jnp.float32) * std,
        "conv_w": jax.random.normal(keys[1], (s.conv_kernel, di + 2 * s.state), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di + 2 * s.state,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(keys[2], (di, d), jnp.float32) / math.sqrt(di),
    }


def mamba2_apply(p, x, cfg: ArchConfig, pol: Policy, cache, mode: str):
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.expand * d
    n = s_cfg.state
    hd = s_cfg.head_dim
    nh = di // hd
    kk = s_cfg.conv_kernel

    xn = apply_norm(p["ln"], x, cfg)
    proj = pol.dot(xn, p["w_in"].astype(x.dtype))
    # w_in output layout: [ x|B|C (conv'd, di+2n) | z (di) | dt (nh) ]
    xbc = proj[..., : di + 2 * n]
    z = proj[..., di + 2 * n: 2 * di + 2 * n]
    dt_in = proj[..., -nh:]

    if mode == "decode":
        window = jnp.concatenate([cache["conv"], xbc], axis=1)
        conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"]) + p["conv_b"]
        conv = jax.nn.silu(conv)[:, None]           # [B,1,di+2n]
        new_conv = window[:, 1:]
    else:
        conv = jax.nn.silu(_causal_conv1d(xbc, p["conv_w"], p["conv_b"]).astype(jnp.float32))
        new_conv = None if cache is None else xbc[:, -(kk - 1):]

    xpart = conv[..., :di].reshape(b, -1, nh, hd)   # [B,S,nh,hd]
    bmat = conv[..., di: di + n]                    # [B,S,n]
    cmat = conv[..., di + n:]                       # [B,S,n]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])   # [B,S,nh]
    a = -jnp.exp(p["a_log"])                        # [nh]

    if mode == "decode":
        h0 = cache["ssm"].astype(jnp.float32)       # [B,nh,hd,n]
        da = jnp.exp(dt[:, 0] * a)                  # [B,nh]
        upd = jnp.einsum("bhp,bn->bhpn", dt[:, 0, :, None] * xpart[:, 0], bmat[:, 0])
        hn = h0 * da[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", hn, cmat[:, 0])[:, None]      # [B,1,nh,hd]
        new_ssm = hn.astype(cache["ssm"].dtype)
    elif cfg.ssm_impl == "ssd" and s % 64 == 0:
        y, hn = _ssd_chunked(xpart.astype(jnp.float32), dt, bmat, cmat, a,
                             chunk=64)
        new_ssm = hn if cache is not None else None
    else:
        def step(h, inp):
            xt, dtt, bt, ct = inp                   # [B,nh,hd],[B,nh],[B,n],[B,n]
            da = jnp.exp(dtt * a)
            h = h * da[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", dtt[:, :, None] * xt, bt)
            yt = jnp.einsum("bhpn,bn->bhp", h, ct)
            return h, yt

        h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
        xs = (jnp.moveaxis(xpart.astype(jnp.float32), 1, 0), jnp.moveaxis(dt, 1, 0),
              jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
        hn, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1)                  # [B,S,nh,hd]
        new_ssm = hn if cache is not None else None

    y = y + p["d_skip"][:, None] * xpart.astype(jnp.float32)
    y = y.reshape(b, -1, di)
    # gated RMSNorm then output proj
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6) * p["norm_scale"]
    out = pol.dot(y.astype(x.dtype), p["w_out"].astype(x.dtype))
    new_cache = cache
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
    return x + out, new_cache, jnp.zeros((), jnp.float32)


def _ssd_chunked(x, dt, bmat, cmat, a, chunk=64):
    """Mamba-2 SSD block decomposition (hillclimb: ssm_impl='ssd').

    x: [B,S,nh,hd]; dt: [B,S,nh]; bmat/cmat: [B,S,n]; a: [nh] (<0).
    Scalar per-head decay makes the intra-chunk kernel 1-semiseparable:

        y_t = C_t . (exp(cum_t) h_in)                       (inter-chunk)
            + sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) dt_s x_s   (intra)

    cum is the within-chunk cumsum of log-decay (<= 0), so every exp
    argument is a difference <= 0 — numerically safe without re-centering.
    State h round-trips HBM once per CHUNK (not per step) and the intra-
    chunk term is MXU matmuls — the same trade the Mamba-2 paper makes.
    Returns (y [B,S,nh,hd], h_final [B,nh,hd,n]).
    """
    b_, s_, nh_, hd_ = x.shape
    n_ = bmat.shape[-1]
    nc = s_ // chunk
    xc = x.reshape(b_, nc, chunk, nh_, hd_)
    dtc = dt.reshape(b_, nc, chunk, nh_)
    bc = bmat.reshape(b_, nc, chunk, n_)
    cc = cmat.reshape(b_, nc, chunk, n_)

    loga = dtc * a                                     # [B,nc,T,nh] (<= 0)
    cum = jnp.cumsum(loga, axis=2)                     # within-chunk cumsum

    def chunk_step(h, inp):
        xi, dti, bi, ci, cumi = inp                    # [B,T,...]
        # intra-chunk: M[b,h,t,s] = exp(cum_t - cum_s) * (C_t . B_s), s<=t
        g = jnp.einsum("btn,bsn->bts", ci, bi)         # [B,T,T]
        dcay = jnp.exp(cumi[:, :, None, :] - cumi[:, None, :, :])  # [B,T,T,nh]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(tri[None, :, :, None], g[..., None] * dcay, 0.0)
        dtx = dti[..., None] * xi                      # [B,T,nh,hd]
        y_intra = jnp.einsum("btsh,bshp->bthp", m, dtx)
        # inter-chunk: y_t += exp(cum_t) * C_t . h_in   (per head)
        y_inter = jnp.einsum("btn,bhpn->bthp", ci, h) * jnp.exp(cumi)[..., None]
        # state update: h' = exp(cum_T) h + sum_s exp(cum_T - cum_s) dtx_s (x) B_s
        tail = jnp.exp(cumi[:, -1:, :] - cumi)         # [B,T,nh]
        upd = jnp.einsum("bshp,bsn,bsh->bhpn", dtx, bi, tail)
        h_new = h * jnp.exp(cumi[:, -1])[:, :, None, None] + upd
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b_, nh_, hd_, n_), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0),
          jnp.moveaxis(cum, 1, 0))
    hn, ys = jax.lax.scan(chunk_step, h0, xs)          # ys [nc,B,T,nh,hd]
    y = jnp.moveaxis(ys, 0, 1).reshape(b_, s_, nh_, hd_)
    return y, hn


# =========================================================================
# Uniform dispatch + caches
# =========================================================================

def init_block(block_type: str, cfg: ArchConfig, key):
    if block_type in ("dense", "local", "moe", "attn", "dense_first", "encoder"):
        return init_attn_block(cfg, key, block_type)
    if block_type == "mamba1":
        return init_mamba1(cfg, key)
    if block_type == "mamba2":
        return init_mamba2(cfg, key)
    raise ValueError(block_type)


def block_apply(block_type: str, params, x, cfg: ArchConfig, pol: Policy,
                positions, cache=None, cache_index=0, mode: str = "train",
                cache_fmt: Optional[str] = None):
    if block_type in ("dense", "local", "moe", "attn", "dense_first", "encoder"):
        return attn_block_apply(params, x, cfg, pol, positions, cache,
                                cache_index, mode, block_type, cache_fmt)
    if block_type == "mamba1":
        return mamba1_apply(params, x, cfg, pol, cache, mode)
    if block_type == "mamba2":
        return mamba2_apply(params, x, cfg, pol, cache, mode)
    raise ValueError(block_type)


def init_cache(block_type: str, cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    if block_type in ("dense", "moe", "attn", "dense_first"):
        shape = (batch, cfg.kv_heads, max_len, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if block_type == "local":
        wlen = min(max_len, cfg.window or max_len)
        shape = (batch, cfg.kv_heads, wlen, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if block_type == "mamba1":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        return {"conv": jnp.zeros((batch, s.conv_kernel - 1, di), dtype),
                "ssm": jnp.zeros((batch, di, s.state), jnp.float32)}
    if block_type == "mamba2":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        nh = di // s.head_dim
        return {"conv": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * s.state), dtype),
                "ssm": jnp.zeros((batch, nh, s.head_dim, s.state), jnp.float32)}
    raise ValueError(block_type)
