"""CIFAR ResNets (He et al. 2016, pre-activation) — the paper's §4.2 models.

ResNet-20/32/44/56 (6n+2 basic-block family) for the convergence benchmark.
All convs run through the numeric policy (conv IS a GEMM to the paper); batch
norm runs in f32 with running statistics carried in a separate state pytree.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import statsbank
from repro.core.policy import Policy


def _conv_init(key, k, cin, cout):
    fan = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout)) * math.sqrt(2.0 / fan)


def init_bn(c):
    return ({"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))},
            {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))})


def batch_norm(p, st, x, train: bool, momentum=0.9):
    xf = x.astype(jnp.float32)
    if train:
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new_st = {"mean": momentum * st["mean"] + (1 - momentum) * mean,
                  "var": momentum * st["var"] + (1 - momentum) * var}
    else:
        mean, var, new_st = st["mean"], st["var"], st
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y.astype(x.dtype), new_st


def init_resnet(key, depth: int = 20, n_classes: int = 10, width: int = 16):
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    n = (depth - 2) // 6
    ks = iter(jax.random.split(key, depth * 3 + 8))
    params: Dict = {"stem": _conv_init(next(ks), 3, 3, width), "blocks": [], "bns": []}
    state: Dict = {"bns": []}
    bn_p, bn_s = init_bn(width)
    params["stem_bn"], stem_bn_s = bn_p, bn_s
    state["stem_bn"] = stem_bn_s
    cin = width
    for stage, cout in enumerate([width, 2 * width, 4 * width]):
        for blk in range(n):
            stride = 2 if (stage > 0 and blk == 0) else 1
            bp1, bs1 = init_bn(cin)
            bp2, bs2 = init_bn(cout)
            block = {
                "bn1": bp1, "conv1": _conv_init(next(ks), 3, cin, cout),
                "bn2": bp2, "conv2": _conv_init(next(ks), 3, cout, cout),
            }
            if stride != 1 or cin != cout:
                block["proj"] = _conv_init(next(ks), 1, cin, cout)
            params["blocks"].append(block)
            state["bns"].append({"bn1": bs1, "bn2": bs2})
            cin = cout
    fp, fs = init_bn(cin)
    params["final_bn"], state["final_bn"] = fp, fs
    params["fc"] = jax.random.normal(next(ks), (cin, n_classes)) / math.sqrt(cin)
    return params, state


def resnet_apply(params, state, x, pol: Policy, train: bool):
    """x: [B, 32, 32, 3].  Returns (logits, new_state).

    Conv truncation sites are named via StatsBank scopes ("stem",
    "block{i}", "head") so banked runs — including the payload-domain
    conv lowering, where each conv is one GEMM bank node — get stable,
    readable per-layer keys."""
    new_state = {"bns": []}
    with statsbank.scope("stem"):
        h = pol.conv(x, params["stem"])
    h, new_state["stem_bn"] = batch_norm(params["stem_bn"], state["stem_bn"], h, train)
    h = jax.nn.relu(h)
    n = len(params["blocks"]) // 3
    for i, (block, bst) in enumerate(zip(params["blocks"], state["bns"])):
        # first block of stages 2 and 3 downsamples (strides are structural,
        # derived from position — params hold arrays only, keeping grad trees clean)
        stride = 2 if i in (n, 2 * n) else 1
        y, bs1 = batch_norm(block["bn1"], bst["bn1"], h, train)
        y = jax.nn.relu(y)
        shortcut = h
        with statsbank.scope(f"block{i}"):
            if "proj" in block:
                shortcut = pol.conv(y, block["proj"], stride=(stride, stride))
            y = pol.conv(y, block["conv1"], stride=(stride, stride))
            y, bs2 = batch_norm(block["bn2"], bst["bn2"], y, train)
            y = jax.nn.relu(y)
            y = pol.conv(y, block["conv2"])
        h = shortcut + y
        new_state["bns"].append({"bn1": bs1, "bn2": bs2})
    h, new_state["final_bn"] = batch_norm(params["final_bn"], state["final_bn"], h, train)
    h = jax.nn.relu(h)
    h = jnp.mean(h, axis=(1, 2))
    with statsbank.scope("head"):
        return pol.dot(h, params["fc"]), new_state


def loss_fn(params, state, batch, pol: Policy, train: bool = True):
    logits, new_state = resnet_apply(params, state, batch["images"], pol, train)
    logits = logits.astype(jnp.float32)
    onehot = jax.nn.one_hot(batch["labels"], logits.shape[-1])
    nll = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return nll, ({"nll": nll, "acc": acc}, new_state)
