"""Decoder-only LM over heterogeneous block patterns, scan-over-layers.

The layer pattern (configs) is grouped into maximal homogeneous *segments*;
each segment's params are stacked on a leading layer axis and executed with
``lax.scan`` (+ optional ``jax.checkpoint`` remat).  HLO size is O(#segments),
not O(#layers) — a 96-layer dense model compiles as one scanned block, which
is what keeps the 512-device dry-runs tractable and matches production remat.

Three entry points:
  forward_train(params, tokens, labels)      -> (loss, aux-dict)
  prefill(params, tokens, caches)            -> (last-token logits, caches)
  decode_step(params, token, caches, index)  -> (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import statsbank
from repro.core.policy import Policy
from repro.models import blocks
from repro.parallel.sharding import shard


def segments_of(cfg: ArchConfig) -> List[Tuple[str, int]]:
    """Group the layer pattern into maximal (block_type, run_length) runs."""
    runs: List[Tuple[str, int]] = []
    for t in cfg.resolved_pattern:
        if runs and runs[-1][0] == t:
            runs[-1] = (t, runs[-1][1] + 1)
        else:
            runs.append((t, 1))
    return runs


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(cfg: ArchConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.resolved_pattern) + 3)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": blocks.init_norm(cfg, cfg.d_model),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), jnp.float32) / (cfg.d_model ** 0.5)
    li = 0
    for btype, length in segments_of(cfg):
        layer_ps = [blocks.init_block(btype, cfg, keys[3 + li + i])
                    for i in range(length)]
        params["segments"].append(_stack(layer_ps))
        li += length
    return params


def embed_tokens(params, tokens, cfg: ArchConfig, pol: Policy):
    table = params["embed"]
    if pol.mode in ("s2fp8", "s2fp8_e4m3", "fp8", "fp8_ls"):
        with statsbank.scope("embed"):
            table = pol.truncate(table)
    x = jnp.take(table, tokens, axis=0)
    return shard(x.astype(cfg.activation_dtype), "batch", None, None)


def lm_head(params, x, cfg: ArchConfig, pol: Policy):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    with statsbank.scope("head"):
        logits = pol.dot(x, w.astype(x.dtype))
    return shard(logits, "batch", None, "vocab")


def _segment_scan(btype, seg_params, x, cfg, pol, positions, caches,
                  cache_index, mode, seg_name: str = "seg",
                  cache_fmt: Optional[str] = None):
    """Scan one homogeneous segment.  caches: stacked per-layer pytree or None.

    When a StatsBank session is active (jitted train step with delayed
    stats), the segment's per-layer site states ride through the scan
    ``xs`` alongside the stacked layer params, so every layer truncates
    with its own carried (alpha, beta); their refreshed values flow back
    out through the scan transpose as the bank argument's cotangent.
    """
    n_layers = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
    sites = statsbank.segment_sites(seg_name, n_layers)

    def body(carry, xs):
        x, aux_sum = carry
        if caches is None:
            layer_p, layer_sites = xs
            with statsbank.segment_ctx(seg_name, layer_sites):
                y, _, aux = blocks.block_apply(btype, layer_p, x, cfg, pol,
                                               positions, None, cache_index,
                                               mode, cache_fmt)
            return (y, aux_sum + aux), None
        layer_p, layer_sites, layer_c = xs
        with statsbank.segment_ctx(seg_name, layer_sites):
            y, c_new, aux = blocks.block_apply(btype, layer_p, x, cfg, pol,
                                               positions, layer_c,
                                               cache_index, mode, cache_fmt)
        return (y, aux_sum + aux), c_new

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (seg_params, sites) if caches is None else (seg_params, sites, caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


def forward(params, tokens, cfg: ArchConfig, pol: Policy, *,
            caches=None, cache_index=0, mode: str = "train",
            cache_fmt: Optional[str] = None):
    """Shared forward.  Returns (hidden, total_aux, new_caches).

    ``cache_index`` may be a traced scalar (single shared position) or a
    [B] vector of per-slot positions (serving); ``cache_fmt`` is the static
    paged-cache storage format (see serving/paged_cache.py), threaded down
    to the block cache read/write paths.
    """
    x = embed_tokens(params, tokens, cfg, pol)
    s = tokens.shape[1]
    if mode == "decode":
        ci = jnp.asarray(cache_index, jnp.int32)
        if ci.ndim == 1:
            positions = jnp.broadcast_to(ci[:, None], (ci.shape[0], s))
        else:
            positions = jnp.full((s,), ci, jnp.int32)
    else:
        positions = jnp.arange(s, dtype=jnp.int32)

    total_aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, (btype, _) in enumerate(segments_of(cfg)):
        seg_c = None if caches is None else caches[i]
        x, aux, seg_c_new = _segment_scan(
            btype, params["segments"][i], x, cfg, pol, positions,
            seg_c, cache_index, mode, seg_name=f"seg{i}:{btype}",
            cache_fmt=cache_fmt)
        total_aux = total_aux + aux
        new_caches.append(seg_c_new)
    x = blocks.apply_norm(params["final_norm"], x, cfg)
    return x, total_aux, (new_caches if caches is not None else None)


def loss_fn(params, tokens, labels, cfg: ArchConfig, pol: Policy):
    """Next-token cross entropy (labels = tokens shifted by the data layer)."""
    x, aux, _ = forward(params, tokens, cfg, pol, mode="train")
    logits = lm_head(params, x, cfg, pol).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    # z-loss stabilizer (production default; tiny, keeps logz bounded)
    zloss = 1e-4 * jnp.mean(logz ** 2)
    return nll + zloss + aux, {"nll": nll, "aux": aux}


def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    caches = []
    for btype, length in segments_of(cfg):
        one = blocks.init_cache(btype, cfg, batch, max_len, dtype)
        caches.append(jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((length,) + leaf.shape, leaf.dtype), one))
    return caches


def prefill(params, tokens, cfg: ArchConfig, pol: Policy, caches, *,
            last_index=None):
    """Process a full prompt, fill caches, return last-position logits.

    ``last_index``: optional [B] int32 of each row's true last-token index
    (right-padded batched admission); default reads position -1.
    """
    x, _, new_caches = forward(params, tokens, cfg, pol,
                               caches=caches, mode="prefill")
    if last_index is None:
        x_last = x[:, -1:]
    else:
        x_last = x[jnp.arange(x.shape[0]), last_index][:, None]
    logits = lm_head(params, x_last, cfg, pol)
    return logits, new_caches


def decode_step(params, token, cfg: ArchConfig, pol: Policy, caches,
                cache_index, *, cache_fmt: Optional[str] = None):
    """One decode step.  token: [B, 1] int32; cache_index: traced scalar or
    per-slot [B] position vector (serving)."""
    x, _, new_caches = forward(params, token, cfg, pol, caches=caches,
                               cache_index=cache_index, mode="decode",
                               cache_fmt=cache_fmt)
    logits = lm_head(params, x, cfg, pol)
    return logits, new_caches
