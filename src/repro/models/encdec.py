"""Encoder-decoder transformer (whisper-medium backbone + transformer_tiny).

The modality frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings [B, S_frames, d_model] (the conv frontend's
output shape) straight into the encoder.  transformer_tiny (the paper's
En-Vi model) uses token embeddings on both sides.

Decoder blocks = causal self-attention (cached) + cross-attention over the
encoder output (KV computed once at prefill, cached) + MLP.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import statsbank
from repro.core.policy import Policy
from repro.models import blocks
from repro.models.blocks import apply_norm, init_norm, mlp_fwd, init_mlp, rope, \
    _grouped, full_attention, chunked_attention, decode_attention
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Decoder block (self + cross + mlp)
# ---------------------------------------------------------------------------

def init_dec_block(cfg: ArchConfig, key) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    ks = jax.random.split(key, 10)
    std = 1.0 / math.sqrt(d)
    std_o = 1.0 / math.sqrt(h * hd)

    def qkvo(i):
        return {
            "wq": jax.random.normal(ks[i], (d, h * hd), jnp.float32) * std,
            "wk": jax.random.normal(ks[i + 1], (d, kv * hd), jnp.float32) * std,
            "wv": jax.random.normal(ks[i + 2], (d, kv * hd), jnp.float32) * std,
            "wo": jax.random.normal(ks[i + 3], (h * hd, d), jnp.float32) * std_o,
        }

    return {
        "ln1": init_norm(cfg, d), "self": qkvo(0),
        "ln_x": init_norm(cfg, d), "cross": qkvo(4),
        "ln2": init_norm(cfg, d), "mlp": init_mlp(cfg, ks[8], d, cfg.d_ff),
    }


def _proj_qkv(p, xq, xkv, cfg, pol, positions_q, positions_k, use_rope=True):
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    hd, h, kvh = cfg.resolved_head_dim, cfg.n_heads, cfg.kv_heads
    q = pol.dot(xq, p["wq"].astype(xq.dtype)).reshape(b, sq, h, hd).transpose(0, 2, 1, 3)
    k = pol.dot(xkv, p["wk"].astype(xq.dtype)).reshape(b, sk, kvh, hd).transpose(0, 2, 1, 3)
    v = pol.dot(xkv, p["wv"].astype(xq.dtype)).reshape(b, sk, kvh, hd).transpose(0, 2, 1, 3)
    if use_rope:
        q = rope(q, positions_q, cfg.rope_theta)
        k = rope(k, positions_k, cfg.rope_theta)
    return _grouped(q, kvh), k, v


def dec_block_apply(p, x, enc_kv, cfg: ArchConfig, pol: Policy, positions,
                    cache, cache_index, mode):
    """enc_kv: dict {k, v} [B,KV,S_enc,hd] — precomputed cross K/V."""
    b, s, _ = x.shape
    hd, h, kvh = cfg.resolved_head_dim, cfg.n_heads, cfg.kv_heads

    # --- causal self attention -----------------------------------------
    xn = apply_norm(p["ln1"], x, cfg)
    qg, k, v = _proj_qkv(p["self"], xn, xn, cfg, pol, positions, positions)
    if mode == "decode":
        smax = cache["k"].shape[2]
        k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=2)
        v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=2)
        valid = jnp.arange(smax) <= cache_index
        attn = decode_attention(qg, k_c, v_c, valid, policy=pol)
        new_cache = {"k": k_c, "v": v_c}
    else:
        attn = full_attention(qg, k, v, causal=True, policy=pol) if s <= 2048 \
            else chunked_attention(qg, k, v, causal=True, policy=pol)
        new_cache = cache
        if mode == "prefill" and cache is not None:
            kc = jax.lax.dynamic_update_slice_in_dim(
                jnp.zeros_like(cache["k"]), k.astype(cache["k"].dtype), 0, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(
                jnp.zeros_like(cache["v"]), v.astype(cache["v"].dtype), 0, axis=2)
            new_cache = {"k": kc, "v": vc}
    attn = attn.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    x = x + pol.dot(attn, p["self"]["wo"].astype(x.dtype))

    # --- cross attention -------------------------------------------------
    xn = apply_norm(p["ln_x"], x, cfg)
    q = pol.dot(xn, p["cross"]["wq"].astype(x.dtype)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    qg = _grouped(q, kvh)
    s_enc = enc_kv["k"].shape[2]
    if s_enc <= 2048:
        attn = full_attention(qg, enc_kv["k"].astype(x.dtype),
                              enc_kv["v"].astype(x.dtype), causal=False, policy=pol)
    else:
        attn = chunked_attention(qg, enc_kv["k"].astype(x.dtype),
                                 enc_kv["v"].astype(x.dtype), causal=False, policy=pol)
    attn = attn.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    x = x + pol.dot(attn, p["cross"]["wo"].astype(x.dtype))

    # --- mlp --------------------------------------------------------------
    x = x + mlp_fwd(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg, pol)
    return shard(x, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_encdec(cfg: ArchConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 4)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "head": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), jnp.float32) / math.sqrt(cfg.d_model),
        "enc_norm": init_norm(cfg, cfg.d_model),
        "dec_norm": init_norm(cfg, cfg.d_model),
    }
    enc_layers = [blocks.init_block("encoder", cfg, ks[2 + i])
                  for i in range(cfg.n_enc_layers)]
    dec_layers = [init_dec_block(cfg, ks[2 + cfg.n_enc_layers + i])
                  for i in range(cfg.n_layers)]
    st = lambda ls: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ls)
    params["encoder"] = st(enc_layers)
    params["decoder"] = st(dec_layers)
    # cross-attention K/V projections read the encoder output; frontend stub
    # (audio) has no params — input_specs feeds embeddings directly.
    return params


def encode(params, enc_inputs, cfg: ArchConfig, pol: Policy):
    """enc_inputs: [B, S_enc, d_model] frame embeddings (audio stub) or
    [B, S_enc] token ids (transformer_tiny)."""
    if enc_inputs.ndim == 2:
        x = jnp.take(params["embed"], enc_inputs, axis=0).astype(cfg.activation_dtype)
    else:
        x = enc_inputs.astype(cfg.activation_dtype)
    x = shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    n_enc = jax.tree_util.tree_leaves(params["encoder"])[0].shape[0]
    sites = statsbank.segment_sites("enc", n_enc)

    def body(carry, xs):
        layer_p, layer_sites = xs
        with statsbank.segment_ctx("enc", layer_sites):
            y, _, _ = blocks.block_apply("encoder", layer_p, carry, cfg, pol,
                                         positions, None, 0, "train")
        return y, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["encoder"], sites))
    return apply_norm(params["enc_norm"], x, cfg)


def cross_kv(params, enc_out, cfg: ArchConfig, pol: Policy):
    """Per-decoder-layer cross K/V, stacked [L, B, KV, S_enc, hd]."""
    b, s, _ = enc_out.shape
    hd, kvh = cfg.resolved_head_dim, cfg.kv_heads

    n_dec = jax.tree_util.tree_leaves(params["decoder"])[0].shape[0]
    sites = statsbank.segment_sites("xkv", n_dec)

    def one(xs):
        layer_p, layer_sites = xs
        with statsbank.segment_ctx("xkv", layer_sites):
            k = pol.dot(enc_out, layer_p["cross"]["wk"].astype(enc_out.dtype))
            v = pol.dot(enc_out, layer_p["cross"]["wv"].astype(enc_out.dtype))
        k = k.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
        return {"k": shard(k, "batch", "kv", "kv_seq", None),
                "v": shard(v, "batch", "kv", "kv_seq", None)}

    return jax.lax.map(one, (params["decoder"], sites))


def decode_stack(params, dec_tokens, enc_kv, cfg: ArchConfig, pol: Policy,
                 caches=None, cache_index=0, mode="train"):
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.activation_dtype)
    x = shard(x, "batch", None, None)
    s = dec_tokens.shape[1]
    positions = (jnp.full((s,), cache_index, jnp.int32) if mode == "decode"
                 else jnp.arange(s, dtype=jnp.int32))

    def body(carry, xs):
        layer_p, layer_kv, layer_c = xs
        y, c_new = dec_block_apply(layer_p, carry, layer_kv, cfg, pol,
                                   positions, layer_c, cache_index, mode)
        return y, c_new

    if caches is None:
        n_dec = jax.tree_util.tree_leaves(params["decoder"])[0].shape[0]
        sites = statsbank.segment_sites("dec", n_dec)

        def body_nc(carry, xs2):
            layer_p, layer_kv, layer_sites = xs2
            with statsbank.segment_ctx("dec", layer_sites):
                y, _ = dec_block_apply(layer_p, carry, layer_kv, cfg, pol,
                                       positions, None, cache_index, mode)
            return y, None
        body_fn = jax.checkpoint(body_nc, prevent_cse=False) if (cfg.remat and mode == "train") else body_nc
        x, _ = jax.lax.scan(body_fn, x, (params["decoder"], enc_kv, sites))
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["decoder"], enc_kv, caches))
    x = apply_norm(params["dec_norm"], x, cfg)
    with statsbank.scope("head"):
        logits = pol.dot(x, params["head"].astype(x.dtype))
    return logits, new_caches


def loss_fn(params, enc_inputs, dec_tokens, dec_labels, cfg, pol):
    enc_out = encode(params, enc_inputs, cfg, pol)
    ekv = cross_kv(params, enc_out, cfg, pol)
    logits, _ = decode_stack(params, dec_tokens, ekv, cfg, pol, mode="train")
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, dec_labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + 1e-4 * jnp.mean(logz ** 2), {"nll": nll}


def init_dec_caches(cfg: ArchConfig, batch: int, max_dec_len: int, dtype=jnp.bfloat16):
    hd, kvh, L = cfg.resolved_head_dim, cfg.kv_heads, cfg.n_layers
    shape = (L, batch, kvh, max_dec_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def serve_prefill(params, enc_inputs, dec_bos, cfg, pol, max_dec_len=448):
    """Encode + build cross KV + prefill decoder with BOS. Returns
    (first logits, state dict)."""
    enc_out = encode(params, enc_inputs, cfg, pol)
    ekv = cross_kv(params, enc_out, cfg, pol)
    caches = init_dec_caches(cfg, enc_inputs.shape[0], max_dec_len)
    # run the BOS token through decode-mode at index 0
    logits, caches = decode_stack(params, dec_bos, ekv, cfg, pol,
                                  caches=caches, cache_index=0, mode="decode")
    return logits, {"ekv": ekv, "caches": caches}


def serve_decode(params, token, state, cache_index, cfg, pol):
    logits, caches = decode_stack(params, token, state["ekv"], cfg, pol,
                                  caches=state["caches"],
                                  cache_index=cache_index, mode="decode")
    return logits, {"ekv": state["ekv"], "caches": caches}
