"""Static cost analyzer over optimized (SPMD-partitioned) HLO text.

Why: ``compiled.cost_analysis()`` counts each ``while`` body ONCE — a
96-layer scanned transformer under-reports FLOPs/bytes/collectives by ~96x.
This analyzer parses the HLO module into computations, costs each op
locally, and propagates through the call graph multiplying ``while`` bodies
by their ``known_trip_count`` (emitted by XLA in backend_config).

Cost model per op (per device — the module is already partitioned):
  flops:
    dot:          2 * prod(result_shape) * prod(contracted dims of lhs)
    convolution:  2 * prod(result_shape) * prod(kernel spatial) * Cin/groups
                  (groups inferred from feature_group_count)
    (elementwise VPU flops are ignored: MXU dots dominate every cell here;
    this matches the convention of MFU accounting.)
  bytes (HBM traffic):
    for every materialized op (fusion, dot, conv, copy, slice ops,
    collectives, sort, gather/scatter, reduce, ...): result bytes (1 write)
    + operand bytes (1 read each).  Zero-cost ops: bitcast, tuple,
    get-tuple-element, parameter, constant, while/call/conditional shells
    (their bodies are costed recursively instead).
  collective bytes (ICI traffic):
    all-reduce 2x result, all-gather 1x result, reduce-scatter 1x operand,
    all-to-all / collective-permute 1x result — multiplied by trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e5m2|f8e4m3fn|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\]")

_ZERO_COST = {
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

_CONTROL = {"while", "call", "conditional", "fusion", "async-start",
            "async-done", "custom-call"}

_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_info(text: str) -> Tuple[int, List[int], int]:
    """(total bytes, dims-of-first-shape, elems-of-first-shape) in `text`."""
    total = 0
    first_dims: List[int] = []
    first_elems = 0
    for i, m in enumerate(_SHAPE_RE.finditer(text)):
        dtype, dims_s = m.group(1), m.group(2)
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if i == 0:
            first_dims, first_elems = dims, n
    return total, first_dims, first_elems


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_text: str
    line: str
    called: List[Tuple[str, float]]     # (computation name, multiplier)


_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")
_KERNEL_SHAPE_RE = re.compile(r"\),\s*(?:.*?)?$")


def parse_module(hlo: str) -> Tuple[Dict[str, List[_Op]], Optional[str], Dict[str, str]]:
    """Split module text into computations -> op lists. Returns
    (computations, entry_name, op_result_types)."""
    comps: Dict[str, List[_Op]] = {}
    types: Dict[str, str] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", s)
        # op lines have " = " before their first "(" — headers never do
        if header and not s.startswith("ROOT") and " = " not in s.split("(")[0]:
            cur = header.group(2)
            comps[cur] = []
            if header.group(1):
                entry = cur
            continue
        if s == "}" or s.startswith("}"):
            # stay permissive: end of computation
            if cur is not None and s.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _LINE_RE.match(s)
        if not m:
            continue
        name, result_text, opcode = m.group(1), m.group(2), m.group(3)
        types[name] = result_text
        called: List[Tuple[str, float]] = []
        if opcode == "while":
            trip = _TRIP_RE.search(s)
            n = float(trip.group(1)) if trip else 1.0
            body = _CALLED_RE.search(s)
            cond = _COND_RE.search(s)
            if body:
                called.append((body.group(1), n))
            if cond:
                called.append((cond.group(1), n))
        elif opcode in ("fusion", "call", "custom-call", "reduce", "sort",
                        "map", "scatter", "reduce-window", "select-and-scatter",
                        "all-reduce", "reduce-scatter"):
            c = _CALLED_RE.search(s)
            if c:
                called.append((c.group(1), 1.0))
        elif opcode == "conditional":
            b = _BRANCHES_RE.search(s)
            if b:
                for cname in b.group(1).split(","):
                    cname = cname.strip().lstrip("%")
                    if cname:
                        called.append((cname, 1.0))   # upper bound: all branches
        comps[cur].append(_Op(name, opcode, result_text, s, called))
    return comps, entry, types


def _operand_list(line: str) -> List[str]:
    """Operand names inside the op's parens (top-level commas)."""
    inner = line[line.find("(") + 1:]
    depth = 1
    buf, out = [], []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf))
    names = []
    for arg in out:
        m = re.search(r"%([\w.\-]+)\s*$", arg.strip())
        names.append(m.group(1) if m else "")
    return names


def _name_bytes(name: str, types: Dict[str, str]) -> int:
    t = types.get(name)
    if not t:
        return 0
    b, _, _ = _shape_info(t)
    return b


def _operand_bytes(line: str, types: Dict[str, str]) -> int:
    """Bytes of operands referenced inside the op's parens. Works with or
    without inline types."""
    inner = line[line.find("(") + 1:]
    depth = 1
    out = []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(ch)
    args = "".join(out)
    total, _, _ = _shape_info(args)
    if total:
        return total
    # no inline types: resolve names
    b = 0
    for m in re.finditer(r"%([\w.\-]+)", args):
        t = types.get(m.group(1))
        if t:
            tb, _, _ = _shape_info(t)
            b += tb
    return b


def _fusion_update_bytes(op: "_Op", comps, types) -> int:
    """Bytes of the update operand of the DUS inside a slice-write fusion."""
    for cname, _ in op.called:
        for inner in comps.get(cname, ()):
            if inner.opcode == "dynamic-update-slice":
                args = _operand_list(inner.line)
                if len(args) > 1:
                    # inline types are present inside fused computations
                    inner_args = inner.line[inner.line.find("(") + 1:]
                    shapes = _SHAPE_RE.findall(inner_args)
                    if len(shapes) > 1:
                        dims = shapes[1][1]
                        n = 1
                        for d in (dims.split(",") if dims else []):
                            n *= int(d)
                        return n * _DTYPE_BYTES[shapes[1][0]]
                    b = _name_bytes(args[1], types)
                    if b:
                        return b
    # fallback: result / leading dim (one slice of the stacked buffer)
    rb, rdims, _ = _shape_info(op.result_text)
    return rb // max(rdims[0] if rdims else 1, 1)


def _dot_flops(op: _Op, types: Dict[str, str]) -> float:
    _, rdims, relems = _shape_info(op.result_text)
    # lhs operand type: first shape inside the parens (inline) or via table
    inner = op.line[op.line.find("(") + 1:]
    m = _SHAPE_RE.search(inner)
    if m:
        lhs_dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    else:
        nm = re.search(r"%([\w.\-]+)", inner)
        lhs_dims = []
        if nm and nm.group(1) in types:
            _, lhs_dims, _ = _shape_info(types[nm.group(1)])
    cm = _LHS_CONTRACT_RE.search(op.line)
    k = 1
    if cm and lhs_dims:
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * relems * k


def _conv_flops(op: _Op, types: Dict[str, str]) -> float:
    _, _, relems = _shape_info(op.result_text)
    inner = op.line[op.line.find("(") + 1:]
    shapes = _SHAPE_RE.findall(inner)
    kernel_elems = 1
    cout = 1
    if len(shapes) >= 2:
        kd = [int(d) for d in shapes[1][1].split(",")] if shapes[1][1] else []
        for d in kd:
            kernel_elems *= d
        cout = kd[-1] if kd else 1
    fgc = _FGC_RE.search(op.line)
    groups = int(fgc.group(1)) if fgc else 1
    # per output element: kernel_elems / cout multiplies (already /groups via
    # kernel Cin dim), times 2 for MAC
    per_out = kernel_elems / max(cout, 1)
    return 2.0 * relems * per_out


def cost_of(hlo: str) -> Cost:
    comps, entry, types = parse_module(hlo)
    if entry is None:
        # fall back: the computation with most ops
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    memo: Dict[Tuple[str, bool], Cost] = {}
    # ops whose called computation is an intra-op lambda/fusion body: its
    # internal ops never touch HBM — count only flops (MXU dots in fusions).
    _FUSED_CALLERS = {"fusion", "reduce", "sort", "map", "scatter",
                      "reduce-window", "select-and-scatter", "all-reduce",
                      "reduce-scatter", "custom-call"}

    def comp_cost(name: str, fused: bool, stack=()) -> Cost:
        key = (name, fused)
        if key in memo:
            return memo[key]
        if name not in comps or name in stack:
            return Cost()
        total = Cost()
        for op in comps[name]:
            oc = op.opcode
            if oc in _ZERO_COST:
                pass
            elif oc == "dot":
                total.flops += _dot_flops(op, types)
                if not fused:
                    rb, _, _ = _shape_info(op.result_text)
                    total.bytes += rb + _operand_bytes(op.line, types)
            elif oc == "convolution":
                total.flops += _conv_flops(op, types)
                if not fused:
                    rb, _, _ = _shape_info(op.result_text)
                    total.bytes += rb + _operand_bytes(op.line, types)
            elif oc in _COLL_MULT and not fused:
                rb, _, _ = _shape_info(op.result_text)
                ob = _operand_bytes(op.line, types)
                traffic = (ob if oc == "reduce-scatter" else rb) * _COLL_MULT[oc]
                total.coll_bytes += traffic
                total.coll[oc] = total.coll.get(oc, 0.0) + traffic
                total.bytes += rb + ob
            elif oc in ("while", "call", "conditional"):
                pass                                    # bodies costed below
            elif oc == "fusion" and not fused and "dynamic-update-slice" in op.name:
                # in-place slice-write fusion (scan carry / cache update):
                # traffic = update slice in + out, not the aliased buffer.
                ub = _fusion_update_bytes(op, comps, types)
                total.bytes += 2 * ub
            elif oc == "fusion" and not fused and "dynamic-slice" in op.name:
                rb, _, _ = _shape_info(op.result_text)
                total.bytes += 2 * rb                   # slice read + write
            elif oc == "dynamic-update-slice" and not fused:
                # in-place on TPU: traffic = the update slice (read + write),
                # NOT the full destination buffer.
                args = _operand_list(op.line)
                ub = _name_bytes(args[1], types) if len(args) > 1 else 0
                total.bytes += 2 * ub
            elif oc in ("dynamic-slice", "slice", "copy", "broadcast",
                        "transpose") and not fused:
                rb, _, _ = _shape_info(op.result_text)
                total.bytes += 2 * rb                   # read slice + write
            elif oc == "gather" and not fused:
                rb, _, _ = _shape_info(op.result_text)
                total.bytes += 2 * rb                   # gathered reads + write
            elif not fused:
                rb, _, _ = _shape_info(op.result_text)
                total.bytes += rb + _operand_bytes(op.line, types)
            for cname, mult in op.called:
                child_fused = fused or oc in _FUSED_CALLERS
                total.add(comp_cost(cname, child_fused, stack + (name,)), mult)
        memo[key] = total
        return total

    return comp_cost(entry, False) if entry else Cost()
