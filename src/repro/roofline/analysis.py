"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute_term    = HLO_FLOPs / (chips x peak)        [s]
  memory_term     = HLO_bytes / (chips x HBM_bw)      [s]
  collective_term = collective_bytes / (chips x link) [s]

``cost_analysis`` FLOPs/bytes come from the SPMD-partitioned module and are
*per-device* numbers on current JAX; we detect which convention the backend
used by magnitude and normalize (see ``normalize_costs``).  Collective bytes
are not in cost_analysis at all — we parse the partitioned HLO text and sum
result-shape bytes per collective op with per-op traffic multipliers:

  all-reduce      2x  (reduce-scatter + all-gather equivalent traffic)
  all-gather      1x  (result bytes ~ bytes moved, x(n-1)/n ~ 1)
  reduce-scatter  1x  (operand bytes)
  all-to-all      1x
  collective-permute 1x
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (v5e: ~2 usable axes typical)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# matches e.g. "f32[256,1024]{1,0}" or "(f32[8], bf16[4,4])"
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e5m2|f8e4m3fn|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device traffic bytes by collective kind, from partitioned HLO."""
    out = {k: 0.0 for k in _COLL_MULT}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result-type = lhs of " = <shape> <op>(" ; op name appears right
        # after the result shape. Filter *-start/*-done pairs (count starts).
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\(", line)
        if not m:
            continue
        shape_txt, op, started = m.group(1), m.group(2), m.group(3)
        out[op] += _shape_bytes(shape_txt) * _COLL_MULT[op]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # per device
    hlo_gbytes: float            # per device
    coll_gbytes: float           # per device
    coll_breakdown: Dict[str, float]
    model_gflops_total: float    # analytic 6*N*D (or active)
    bytes_per_device: float      # from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.hlo_gflops * 1e9 / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_gbytes * 1e9 / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_gbytes * 1e9 / ICI_BW

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs across chips."""
        total = self.hlo_gflops * self.chips
        return self.model_gflops_total / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_s * PEAK_FLOPS * self.chips
        return (self.model_gflops_total * 1e9 / denom) if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops_per_dev": self.hlo_gflops,
            "hlo_gbytes_per_dev": self.hlo_gbytes,
            "coll_gbytes_per_dev": self.coll_gbytes,
            "coll_breakdown": self.coll_breakdown,
            "model_gflops_total": self.model_gflops_total,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s, "useful_flops_frac": self.useful_flops_frac,
            "mfu": self.mfu,
        }


def model_flops(cfg, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N*D inference (per paper-of-
    record conventions), N = active params, D = tokens processed."""
    from repro.configs.base import SHAPE_SPECS
    seq, gbs, kind = SHAPE_SPECS[shape_name]
    n = cfg.n_active_params()
    if kind == "train":
        tokens = seq * gbs if not cfg.enc_dec else (seq + 448) * gbs
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq * gbs
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * gbs


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, mem_bytes: float,
            model_gflops_total: float) -> Roofline:
    """Roofline terms from the trip-count-aware static HLO analyzer
    (roofline/hlo_cost.py).  ``cost`` (XLA cost_analysis) is kept by the
    caller for reference but NOT used — it undercounts while-loop bodies."""
    from repro.roofline import hlo_cost
    c = hlo_cost.cost_of(hlo_text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=c.flops / 1e9, hlo_gbytes=c.bytes / 1e9,
        coll_gbytes=c.coll_bytes / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in c.coll.items()},
        model_gflops_total=model_gflops_total,
        bytes_per_device=mem_bytes)
