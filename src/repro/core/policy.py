"""Numeric policy: how every GEMM/conv in the framework executes.

The paper's Figure 4 dataflow is realized by wrapping each bilinear op's
operands and result in ``truncate_bidir`` (see core/s2fp8.py).  The policy
object selects between:

  fp32    — baseline, nothing inserted
  bf16    — operands cast to bf16, f32 accumulation (paper Table A2 column)
  fp8     — raw e5m2 truncation around GEMMs (the diverging baseline)
  fp8_ls  — raw e5m2 + loss scaling lambda (applied in the trainer; the GEMM
            wrapping here is identical to ``fp8``)
  s2fp8   — the paper's format (shifted & squeezed truncation)

Models never reference numerics directly — they call ``policy.dot`` /
``policy.einsum`` / ``policy.conv`` and get the right dataflow, so every
architecture in configs/ is numerics-agnostic.

The s2fp8 truncations are routed through the numerics-backend registry
(core/backend.py): ``backend="ref"`` is the pure-jnp path, ``"pallas"``
the fused-kernel path (bitwise-identical by construction), and the
default ``"auto"`` picks pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import qdot as qdot_mod
from repro.core import s2fp8
from repro.core import statsbank

MODES = ("fp32", "bf16", "fp8", "fp8_ls", "s2fp8", "s2fp8_e4m3")
# How s2fp8-mode GEMMs execute (see core/qdot.py):
#   "fig4"    — the composed truncation chain around an f32 GEMM (three
#               f32-in/f32-out passes; semantic ground truth);
#   "payload" — qdot_train: operands quantized once to FP8 payloads, the
#               fused dequant-GEMM with an Eq. 5 output epilogue, NT/TN
#               payload backward (1 byte/element operand streaming);
#   "auto"    — payload where the fused kernels are the engine (pallas
#               backends), fig4 on the ref engine.
GEMM_MODES = ("auto", "payload", "fig4")


def _identity(x):
    return x


@functools.lru_cache(maxsize=None)
def _s2fp8_wrap(backend: Optional[str], fmt: str) -> Callable:
    """Session-aware truncation wrapper for the s2fp8 modes.

    When a StatsBank session is active (core/statsbank.py — the trainer
    binds one inside the jitted train step), each call resolves to a named
    bank site: the truncation reuses the site's carried (alpha, beta) and
    the stats reduction only runs on refresh steps.  Outside a session it
    is the classic exact-stats ``bidir_truncate``.  Cached per
    (backend, fmt) so the callable is a stable object under jit tracing.
    """
    exact = nbackend.bidir_truncate(backend, fmt)

    def wrap(x):
        sess = statsbank.current_session()
        if sess is not None:
            return sess.truncate(x, fmt=fmt, backend=backend)
        return exact(x)

    return wrap


def _bf16_cast(x):
    # bf16 operand storage, f32 accumulation (preferred_element_type below).
    return x.astype(jnp.bfloat16)


@functools.lru_cache(maxsize=None)
def _einsum_is_matmul(spec: str) -> bool:
    """True for two-operand specs of the dense-layer family
    ``"...k,kn->...n"`` — explicit ("bsd,df->bsf") or ellipsis
    ("...d,df->...f") batch dims — the shapes ``qdot_train`` executes
    payload-domain.  Batched/multi-contraction specs return False and
    keep the composed Fig. 4 chain."""
    if "->" not in spec:
        return False
    lhs, out = spec.replace(" ", "").split("->")
    parts = lhs.split(",")
    if len(parts) != 2:
        return False
    la, lb = parts
    if len(lb) != 2 or "." in lb:
        return False
    k, n = lb
    if la.startswith("..."):
        la = la[3:]
        if not (out.startswith("...") and la):
            return False
        out = out[3:]
    if "." in la or "." in out or len(set(la)) != len(la):
        return False
    return (k != n and la[-1] == k and n not in la
            and out == la[:-1] + n)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numeric execution policy for all bilinear ops in a model."""

    mode: str = "fp32"
    # Truncate the GEMM output as well as the operands (paper: "before and
    # after every convolution and matrix-matrix product").
    truncate_output: bool = True
    # Loss scale for fp8_ls (consumed by the trainer; kept here so configs
    # carry one self-contained numerics description).
    loss_scale: float = 1.0
    # GEMM output dtype. None -> f32 (paper-strict: every partial sum in
    # f32, including cross-shard).  "bfloat16" rounds the MXU's f32
    # accumulator to bf16 at the GEMM boundary — within-GEMM accumulation
    # stays f32 (the paper's actual requirement) but TP partial-sum
    # all-reduces then move half the bytes (hillclimb lever; EXPERIMENTS.md
    # §Perf documents the trade).
    output_dtype: Optional[str] = None
    # Numerics backend for the s2fp8 truncations (core/backend.py registry).
    # "auto" -> pallas on TPU, ref elsewhere; both produce bitwise-identical
    # truncations, so the choice is an execution detail, not a semantic one.
    backend: str = "auto"
    # GEMM execution for the s2fp8 modes (GEMM_MODES above).  With shared
    # (bank) stats the two paths are bitwise-identical on the forward value
    # (tests/test_qdot_train.py), so this too is an execution detail.
    gemm_mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown numeric mode {self.mode!r}; want one of {MODES}")
        if self.backend != "auto" and \
                self.backend not in nbackend.available_backends():
            raise ValueError(
                f"unknown numerics backend {self.backend!r}; registered: "
                f"{('auto',) + nbackend.available_backends()}")
        if self.gemm_mode not in GEMM_MODES:
            raise ValueError(f"unknown gemm_mode {self.gemm_mode!r}; "
                             f"want one of {GEMM_MODES}")
        if self.gemm_mode == "payload" and (
                not self.truncate_output or self.output_dtype is not None):
            # refuse rather than silently downgrade an explicit request:
            # the payload path fuses the output truncation (needs
            # truncate_output) and accumulates/emits f32 (the bf16
            # output_dtype lever belongs to the fig4 chain)
            raise ValueError(
                "gemm_mode='payload' requires truncate_output=True and "
                "output_dtype=None; use gemm_mode='auto' or 'fig4'")

    # -- operand / output transforms ------------------------------------
    @property
    def backend_obj(self) -> "nbackend.NumericsBackend":
        return nbackend.get_backend(self.backend)

    @property
    def _wrap(self) -> Callable:
        if self.mode == "s2fp8":
            return _s2fp8_wrap(self.backend, "e5m2")
        if self.mode == "s2fp8_e4m3":
            return _s2fp8_wrap(self.backend, "e4m3")
        if self.mode in ("fp8", "fp8_ls"):
            return s2fp8.fp8_truncate_bidir
        if self.mode == "bf16":
            return _bf16_cast
        return _identity

    @property
    def accum_dtype(self):
        if self.output_dtype == "bfloat16":
            return jnp.bfloat16
        return jnp.float32

    @property
    def _fmt(self) -> str:
        return "e4m3" if self.mode == "s2fp8_e4m3" else "e5m2"

    @property
    def uses_payload_gemm(self) -> bool:
        """Whether s2fp8 GEMMs route through ``qdot_train``
        (core/qdot.py).  Requires ``truncate_output`` (the payload path
        fuses the output truncation as a kernel epilogue — Fig. 4's full
        dataflow) and the default f32 GEMM-boundary dtype (the kernel
        accumulates and emits f32, paper-strict — the bf16
        ``output_dtype`` lever belongs to the fig4 chain); "auto"
        resolves to payload on the pallas engines and fig4 on ref."""
        if self.mode not in ("s2fp8", "s2fp8_e4m3") or not self.truncate_output \
                or self.output_dtype is not None:
            return False                 # "payload" here is unreachable:
        if self.gemm_mode != "auto":     # __post_init__ rejects the combo
            return self.gemm_mode == "payload"
        return isinstance(self.backend_obj, nbackend.PallasBackend)

    def _qdot_routable(self, a, b) -> bool:
        return (self.uses_payload_gemm and b.ndim == 2 and a.ndim >= 1
                and a.shape[-1] == b.shape[0])

    def truncate(self, x: jnp.ndarray) -> jnp.ndarray:
        """Tensor-level truncation at op boundaries (bidirectional: the
        cotangent is truncated too for fp8/s2fp8 modes)."""
        return self._wrap(x)

    def _wrap_out(self, y):
        if self.truncate_output and self.mode in ("s2fp8", "s2fp8_e4m3", "fp8", "fp8_ls"):
            return self._wrap(y)
        return y

    # -- bilinear ops -----------------------------------------------------
    def dot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        if self._qdot_routable(a, b):
            y = qdot_mod.qdot_train(a, b, backend=self.backend, fmt=self._fmt)
            return y.astype(a.dtype)
        w = self._wrap
        y = jnp.dot(w(a), w(b), preferred_element_type=self.accum_dtype)
        return self._wrap_out(y).astype(a.dtype)

    def dot_general(self, a, b, dimension_numbers) -> jnp.ndarray:
        # one support-check source: the backend planner.  Of the plannable
        # family, the "nn" orientation is the [..., K] x [K, N] shape
        # qdot_train's NT/TN backward is built for; other contractions
        # keep the composed Fig. 4 chain.
        plan = nbackend.plan_qdot_general(a.shape, b.shape, dimension_numbers)
        if (plan is not None and plan[0] == "nn"
                and self._qdot_routable(a, b)):
            y = qdot_mod.qdot_train(a, b, backend=self.backend, fmt=self._fmt)
            return y.astype(a.dtype)
        w = self._wrap
        y = jax.lax.dot_general(
            w(a), w(b), dimension_numbers, preferred_element_type=self.accum_dtype
        )
        return self._wrap_out(y).astype(a.dtype)

    def einsum(self, spec: str, *operands) -> jnp.ndarray:
        if (len(operands) == 2 and _einsum_is_matmul(spec)
                and self._qdot_routable(*operands)):
            a, b = operands
            y = qdot_mod.qdot_train(a, b, backend=self.backend, fmt=self._fmt)
            return y.astype(a.dtype)
        w = self._wrap
        y = jnp.einsum(
            spec, *[w(o) for o in operands], preferred_element_type=self.accum_dtype
        )
        return self._wrap_out(y).astype(operands[0].dtype)

    def conv(self, x, kernel, *, stride=(1, 1), padding="SAME") -> jnp.ndarray:
        """NHWC x HWIO conv — the ResNet path (conv is a GEMM to the paper)."""
        w = self._wrap
        y = jax.lax.conv_general_dilated(
            w(x), w(kernel),
            window_strides=stride, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=self.accum_dtype,
        )
        return self._wrap_out(y).astype(x.dtype)

    def qdot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Payload-domain GEMM: quantize both operands to S2FP8 storage and
        run the backend's fused dequant-matmul (the paper §5 "tensor
        processing engine" — operands stream at 1 byte/element).  Forward
        value only (no custom VJP): intended for inference/serving paths;
        training GEMMs go through ``dot``, which routes payload-domain via
        ``qdot_train`` when ``gemm_mode`` resolves to "payload".  Both
        s2fp8 storage formats are supported (e4m3 rides the same kernels
        via the ``fmt``/``qdtype`` plumbing)."""
        if self.mode not in ("s2fp8", "s2fp8_e4m3"):
            return self.dot(a, b)
        fmt = self._fmt
        be = self.backend_obj
        sess = statsbank.current_session()
        if sess is not None:
            # bank-carried operand stats: quantization is pure elementwise
            # (no per-call reduction); serving keeps the bank warm via
            # statsbank.HostStatsBank
            sa = sess.operand_stats(a, fmt=fmt)
            sb = sess.operand_stats(b, fmt=fmt)
            y = be.qmatmul(be.quantize(a, stats=sa, fmt=fmt),
                           be.quantize(b, stats=sb, fmt=fmt))
        else:
            y = be.qmatmul(be.quantize(a, fmt=fmt), be.quantize(b, fmt=fmt))
        return self._wrap_out(y).astype(a.dtype)


def make_policy(mode: str, loss_scale: Optional[float] = None,
                backend: Optional[str] = None,
                gemm_mode: Optional[str] = None) -> Policy:
    return Policy(mode=mode,
                  loss_scale=loss_scale if loss_scale is not None else 1.0,
                  backend=backend or "auto",
                  gemm_mode=gemm_mode or "auto")
