"""Numeric policy: how every GEMM/conv in the framework executes.

The paper's Figure 4 dataflow is realized by wrapping each bilinear op's
operands and result in ``truncate_bidir`` (see core/s2fp8.py).  The policy
object selects between:

  fp32    — baseline, nothing inserted
  bf16    — operands cast to bf16, f32 accumulation (paper Table A2 column)
  fp8     — raw e5m2 truncation around GEMMs (the diverging baseline)
  fp8_ls  — raw e5m2 + loss scaling lambda (applied in the trainer; the GEMM
            wrapping here is identical to ``fp8``)
  s2fp8   — the paper's format (shifted & squeezed truncation)

Models never reference numerics directly — they call ``policy.dot`` /
``policy.einsum`` / ``policy.conv`` and get the right dataflow, so every
architecture in configs/ is numerics-agnostic.

The s2fp8 truncations are routed through the numerics-backend registry
(core/backend.py): ``backend="ref"`` is the pure-jnp path, ``"pallas"``
the fused-kernel path (bitwise-identical by construction), and the
default ``"auto"`` picks pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import collectives as collectives_mod
from repro.core import qdot as qdot_mod
from repro.core import s2fp8
from repro.core import statsbank

MODES = ("fp32", "bf16", "fp8", "fp8_ls", "s2fp8", "s2fp8_e4m3")
# How s2fp8-mode GEMMs execute (see core/qdot.py):
#   "fig4"    — the composed truncation chain around an f32 GEMM (three
#               f32-in/f32-out passes; semantic ground truth);
#   "payload" — qdot_train: operands quantized once to FP8 payloads, the
#               fused dequant-GEMM with an Eq. 5 output epilogue, NT/TN
#               payload backward (1 byte/element operand streaming);
#   "auto"    — payload where the fused kernels are the engine (pallas
#               backends), fig4 on the ref engine.
GEMM_MODES = ("auto", "payload", "fig4")


def _identity(x):
    return x


@functools.lru_cache(maxsize=None)
def _s2fp8_wrap(backend: Optional[str], fmt: str) -> Callable:
    """Session-aware truncation wrapper for the s2fp8 modes.

    When a StatsBank session is active (core/statsbank.py — the trainer
    binds one inside the jitted train step), each call resolves to a named
    bank site: the truncation reuses the site's carried (alpha, beta) and
    the stats reduction only runs on refresh steps.  Outside a session it
    is the classic exact-stats ``bidir_truncate``.  Cached per
    (backend, fmt) so the callable is a stable object under jit tracing.
    """
    exact = nbackend.bidir_truncate(backend, fmt)

    def wrap(x):
        sess = statsbank.current_session()
        if sess is not None:
            return sess.truncate(x, fmt=fmt, backend=backend)
        return exact(x)

    return wrap


def _bf16_cast(x):
    # bf16 operand storage, f32 accumulation (preferred_element_type below).
    return x.astype(jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numeric execution policy for all bilinear ops in a model."""

    mode: str = "fp32"
    # Truncate the GEMM output as well as the operands (paper: "before and
    # after every convolution and matrix-matrix product").
    truncate_output: bool = True
    # Loss scale for fp8_ls (consumed by the trainer; kept here so configs
    # carry one self-contained numerics description).
    loss_scale: float = 1.0
    # GEMM output dtype. None -> f32 (paper-strict: every partial sum in
    # f32, including cross-shard).  "bfloat16" rounds the MXU's f32
    # accumulator to bf16 at the GEMM boundary — within-GEMM accumulation
    # stays f32 (the paper's actual requirement) but TP partial-sum
    # all-reduces then move half the bytes (hillclimb lever; EXPERIMENTS.md
    # §Perf documents the trade).
    output_dtype: Optional[str] = None
    # Numerics backend for the s2fp8 truncations (core/backend.py registry).
    # "auto" -> pallas on TPU, ref elsewhere; both produce bitwise-identical
    # truncations, so the choice is an execution detail, not a semantic one.
    backend: str = "auto"
    # GEMM execution for the s2fp8 modes (GEMM_MODES above).  With shared
    # (bank) stats the two paths are bitwise-identical on the forward value
    # (tests/test_qdot_train.py), so this too is an execution detail.
    gemm_mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown numeric mode {self.mode!r}; want one of {MODES}")
        if self.backend != "auto" and \
                self.backend not in nbackend.available_backends():
            raise ValueError(
                f"unknown numerics backend {self.backend!r}; registered: "
                f"{('auto',) + nbackend.available_backends()}")
        if self.gemm_mode not in GEMM_MODES:
            raise ValueError(f"unknown gemm_mode {self.gemm_mode!r}; "
                             f"want one of {GEMM_MODES}")
        if self.gemm_mode == "payload" and not self.truncate_output:
            # refuse rather than silently downgrade an explicit request:
            # the payload path fuses the output truncation into the GEMM
            # epilogue, so it cannot represent truncate_output=False.
            # (output_dtype="bfloat16" IS honored: the kernel accumulates
            # and emits f32, and the payload return rounds to bf16 at the
            # GEMM boundary exactly where the fig4 chain does.)
            raise ValueError(
                "gemm_mode='payload' requires truncate_output=True; "
                "use gemm_mode='auto' or 'fig4'")

    # -- operand / output transforms ------------------------------------
    @property
    def backend_obj(self) -> "nbackend.NumericsBackend":
        return nbackend.get_backend(self.backend)

    @property
    def _wrap(self) -> Callable:
        if self.mode == "s2fp8":
            return _s2fp8_wrap(self.backend, "e5m2")
        if self.mode == "s2fp8_e4m3":
            return _s2fp8_wrap(self.backend, "e4m3")
        if self.mode in ("fp8", "fp8_ls"):
            return s2fp8.fp8_truncate_bidir
        if self.mode == "bf16":
            return _bf16_cast
        return _identity

    @property
    def accum_dtype(self):
        if self.output_dtype == "bfloat16":
            return jnp.bfloat16
        return jnp.float32

    @property
    def _fmt(self) -> str:
        return "e4m3" if self.mode == "s2fp8_e4m3" else "e5m2"

    @property
    def uses_payload_gemm(self) -> bool:
        """Whether s2fp8 GEMMs route through ``qdot_train``
        (core/qdot.py).  Requires ``truncate_output`` (the payload path
        fuses the output truncation as a kernel epilogue — Fig. 4's full
        dataflow); the bf16 ``output_dtype`` lever is honored by rounding
        the kernel's f32 output at the GEMM boundary (within-GEMM
        accumulation stays f32 either way).  "auto" resolves to payload
        on the pallas engines and fig4 on ref."""
        if self.mode not in ("s2fp8", "s2fp8_e4m3") or not self.truncate_output:
            return False                 # "payload" here is unreachable:
        if self.gemm_mode != "auto":     # __post_init__ rejects the combo
            return self.gemm_mode == "payload"
        return isinstance(self.backend_obj, nbackend.PallasBackend)

    def _qdot_routable(self, a, b) -> bool:
        return (self.uses_payload_gemm and b.ndim == 2 and a.ndim >= 1
                and a.shape[-1] == b.shape[0])

    def truncate(self, x: jnp.ndarray) -> jnp.ndarray:
        """Tensor-level truncation at op boundaries (bidirectional: the
        cotangent is truncated too for fp8/s2fp8 modes)."""
        if isinstance(x, collectives_mod.FSDPPayloadParam):
            # a truncation SITE is not a GEMM B slot: gather f32 first so
            # the site's custom_vjp sees the full leaf (its cotangent then
            # reduce-scatters through the gather's backward, keeping the
            # bwd shape contract on the shard)
            x = jnp.asarray(x)
        return self._wrap(x)

    def _wrap_out(self, y):
        if self.truncate_output and self.mode in ("s2fp8", "s2fp8_e4m3", "fp8", "fp8_ls"):
            return self._wrap(y)
        return y

    def _qdot_out(self, y, dtype):
        """Cast a payload-path f32 result to the caller's dtype, honoring
        the bf16 GEMM-boundary lever on the way: rounding through
        ``accum_dtype`` is exactly where the fig4 chain's
        ``preferred_element_type`` rounds, so the two gemm_modes agree on
        output dtype (and boundary rounding) for every policy config."""
        return y.astype(self.accum_dtype).astype(dtype)

    # -- bilinear ops -----------------------------------------------------
    # All GEMM returns cast to jnp.result_type(a, b) — mixed-dtype
    # operands (f32 weights x bf16 activations) follow the contraction's
    # own promotion on every API (dot == dot_general == einsum) instead
    # of silently downcasting to the first operand.
    #
    # FSDP payload handoff (core/collectives.FSDPPayloadParam): the
    # quantized-FSDP trainer passes payload-eligible param shards wrapped
    # in a pytree marker exposing the FULL logical shape.  ``dot`` streams
    # them through ``qdot_train`` as 1-byte gathered payloads; every other
    # consumption (planned einsum/dot_general, norms, lookups) coerces via
    # the wrapper's ``__jax_array__`` f32 gather — correct gradients
    # either way, the payload wire is the dot-family fast path.
    def dot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        if isinstance(b, collectives_mod.FSDPPayloadParam):
            if self._qdot_routable(a, b):
                y = qdot_mod.qdot_train(a, b, backend=self.backend,
                                        fmt=self._fmt)
                return self._qdot_out(y, jnp.result_type(a.dtype, b.dtype))
            b = jnp.asarray(b)          # f32 gather fallback
        if self._qdot_routable(a, b):
            y = qdot_mod.qdot_train(a, b, backend=self.backend, fmt=self._fmt)
            return self._qdot_out(y, jnp.result_type(a, b))
        w = self._wrap
        y = jnp.dot(w(a), w(b), preferred_element_type=self.accum_dtype)
        return self._wrap_out(y).astype(jnp.result_type(a, b))

    def dot_general(self, a, b, dimension_numbers) -> jnp.ndarray:
        if isinstance(b, collectives_mod.FSDPPayloadParam):
            b = jnp.asarray(b)          # f32 gather fallback
        # one support-check source: the backend planner.  Everything it
        # maps — dense, batched, NT/TN orientations — runs payload-domain;
        # contractions outside the planned family keep the composed
        # Fig. 4 chain.
        plan = (nbackend.plan_qdot_general(a.shape, b.shape,
                                           dimension_numbers)
                if self.uses_payload_gemm else None)
        if plan is not None:
            y = qdot_mod.qdot_train(a, b, plan=plan, backend=self.backend,
                                    fmt=self._fmt)
            return self._qdot_out(y, jnp.result_type(a, b))
        w = self._wrap
        y = jax.lax.dot_general(
            w(a), w(b), dimension_numbers, preferred_element_type=self.accum_dtype
        )
        return self._wrap_out(y).astype(jnp.result_type(a, b))

    def einsum(self, spec: str, *operands) -> jnp.ndarray:
        # planner-driven routing (replaces the PR-3 "...k,kn->...n"
        # whitelist): any two-operand contraction the batched payload
        # kernels execute — dense, batched (MoE ecd,edf), broadcast-on-B
        # (becd,edf), attention score/value — goes payload-domain.
        operands = tuple(
            jnp.asarray(o) if isinstance(o, collectives_mod.FSDPPayloadParam)
            else o for o in operands)   # f32 gather fallback
        if len(operands) == 2 and self.uses_payload_gemm:
            plan = nbackend.plan_einsum(spec, operands[0].shape,
                                        operands[1].shape)
            if plan is not None:
                y = qdot_mod.qdot_train(*operands, plan=plan,
                                        backend=self.backend, fmt=self._fmt)
                return self._qdot_out(y, jnp.result_type(*operands))
        w = self._wrap
        y = jnp.einsum(
            spec, *[w(o) for o in operands], preferred_element_type=self.accum_dtype
        )
        # jnp.result_type, not operands[0].dtype: mixed-dtype operands
        # (f32 weights x bf16 activations) must follow einsum's own
        # promotion instead of silently downcasting to the first operand
        return self._wrap_out(y).astype(jnp.result_type(*operands))

    def conv(self, x, kernel, *, stride=(1, 1), padding="SAME") -> jnp.ndarray:
        """NHWC x HWIO conv — the ResNet path (conv is a GEMM to the paper).

        On the payload path the conv lowers to the payload GEMM via an
        im2col patch-extraction prologue (:meth:`_conv_im2col`): patches
        stream into the quantizer once and the contraction runs on 1-byte
        operands with the fused Eq. 5 epilogue, exactly like ``dot``."""
        if self.uses_payload_gemm:
            return self._conv_im2col(x, kernel, stride, padding)
        w = self._wrap
        wx, wk = w(x), w(kernel)
        # lax.conv rejects a preferred_element_type NARROWER than the
        # operands (the bf16 boundary lever on f32 inputs): accumulate at
        # the wider of (accum_dtype, operand dtype) and round at the GEMM
        # boundary instead — the same place the dot/einsum paths round.
        # The boundary astype is a no-op when accum_dtype was legal.
        op_dtype = jnp.result_type(wx, wk)
        pety = (op_dtype if jnp.dtype(self.accum_dtype).itemsize
                < jnp.dtype(op_dtype).itemsize else self.accum_dtype)
        y = jax.lax.conv_general_dilated(
            wx, wk, window_strides=stride, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=pety,
        ).astype(self.accum_dtype)
        return self._wrap_out(y).astype(x.dtype)

    def flash_attention(self, q, k, v, *, causal: bool = True,
                        window=None) -> jnp.ndarray:
        """Fused attention through the policy.

        Layout: q ``[B, KV, G, Sq, d]``; k, v ``[B, KV, Sk, d]`` (the
        models/flash.py grouped-query convention).  Payload-mode s2fp8
        policies run the payload-domain flash node
        (core/qdot.qflash_attention): 1-byte Q/K/V streaming, VMEM-only
        score tiles, fused Eq. 5 output epilogue, payload residuals — one
        StatsBank FLASH_DIRS node for the q/k/v/out directions.  Every
        other mode runs the pure-JAX flash custom-VJP (models/flash.py)
        with the policy's tensor-level truncations around it, so under a
        session flash attention consumes the SAME bank sites as the
        chunked path (q/k/v/out truncation sites in the same order) —
        flash vs einsum attention see bank numerics, not locally
        recomputed stats."""
        if self.uses_payload_gemm:
            y = qdot_mod.qflash_attention(q, k, v, causal=causal,
                                          window=window,
                                          backend=self.backend,
                                          fmt=self._fmt)
            return self._qdot_out(y, jnp.result_type(q, k, v))
        from repro.models.flash import flash_attention as _fa
        q, k, v = self.truncate(q), self.truncate(k), self.truncate(v)
        window = None if window is None else int(window)
        return self.truncate(_fa(q, k, v, causal, window))

    def _conv_im2col(self, x, kernel, stride, padding):
        """Payload-domain conv: im2col gather -> dense payload GEMM.

        The patch tensor ``[B, OH, OW, KH*KW*C]`` is built from KH*KW
        strided slices of the zero-padded input (stride/padding handled
        in the gather; zero-padding is exact for S2FP8 — padding zeros
        are excluded from stats and quantize to zero payloads), reshaped
        against ``kernel`` flattened to ``[KH*KW*C, F]`` — the dense
        ``[..., K] x [K, N]`` family ``qdot_train`` executes with payload
        residuals and the NT/TN payload backward (the conv VJP is the
        GEMM VJP scattered back through the slices' transpose).  Output
        dims are validated against ``lax.conv_general_dilated``."""
        kh, kw, cin, cout = kernel.shape
        sh, sw = stride
        if isinstance(padding, str):
            pads = jax.lax.padtype_to_pads(x.shape[1:3], (kh, kw),
                                           (sh, sw), padding)
        else:
            pads = list(padding)
        xp = jnp.pad(x, ((0, 0),) + tuple(pads) + ((0, 0),))
        b, hp, wp, _ = xp.shape
        oh = (hp - kh) // sh + 1
        ow = (wp - kw) // sw + 1
        cols = [jax.lax.slice(xp, (0, i, j, 0),
                              (b, i + (oh - 1) * sh + 1,
                               j + (ow - 1) * sw + 1, cin),
                              (1, sh, sw, 1))
                for i in range(kh) for j in range(kw)]
        patches = jnp.concatenate(cols, axis=-1)     # [B, OH, OW, KH*KW*C]
        y = qdot_mod.qdot_train(patches, kernel.reshape(kh * kw * cin, cout),
                                backend=self.backend, fmt=self._fmt)
        expected = jax.eval_shape(
            lambda x_, k_: jax.lax.conv_general_dilated(
                x_, k_, window_strides=stride, padding=padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC")), x, kernel).shape
        if y.shape != expected:
            raise ValueError(
                f"im2col conv lowering produced {y.shape}, but "
                f"lax.conv_general_dilated would produce {expected} "
                f"(stride={stride}, padding={padding!r})")
        return self._qdot_out(y, x.dtype)

    def qdot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Payload-domain GEMM: quantize both operands to S2FP8 storage and
        run the backend's fused dequant-matmul (the paper §5 "tensor
        processing engine" — operands stream at 1 byte/element).  Forward
        value only (no custom VJP): intended for inference/serving paths;
        training GEMMs go through ``dot``, which routes payload-domain via
        ``qdot_train`` when ``gemm_mode`` resolves to "payload".  Both
        s2fp8 storage formats are supported (e4m3 rides the same kernels
        via the ``fmt``/``qdtype`` plumbing)."""
        if self.mode not in ("s2fp8", "s2fp8_e4m3"):
            return self.dot(a, b)
        fmt = self._fmt
        be = self.backend_obj
        sess = statsbank.current_session()
        if sess is not None:
            # bank-carried operand stats: quantization is pure elementwise
            # (no per-call reduction); serving keeps the bank warm via
            # statsbank.HostStatsBank
            sa = sess.operand_stats(a, fmt=fmt)
            sb = sess.operand_stats(b, fmt=fmt)
            y = be.qmatmul(be.quantize(a, stats=sa, fmt=fmt),
                           be.quantize(b, stats=sb, fmt=fmt))
        else:
            y = be.qmatmul(be.quantize(a, fmt=fmt), be.quantize(b, fmt=fmt))
        return self._wrap_out(y).astype(a.dtype)


def make_policy(mode: str, loss_scale: Optional[float] = None,
                backend: Optional[str] = None,
                gemm_mode: Optional[str] = None) -> Policy:
    return Policy(mode=mode,
                  loss_scale=loss_scale if loss_scale is not None else 1.0,
                  backend=backend or "auto",
                  gemm_mode=gemm_mode or "auto")
