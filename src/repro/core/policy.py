"""Numeric policy: how every GEMM/conv in the framework executes.

The paper's Figure 4 dataflow is realized by wrapping each bilinear op's
operands and result in ``truncate_bidir`` (see core/s2fp8.py).  The policy
object selects between:

  fp32    — baseline, nothing inserted
  bf16    — operands cast to bf16, f32 accumulation (paper Table A2 column)
  fp8     — raw e5m2 truncation around GEMMs (the diverging baseline)
  fp8_ls  — raw e5m2 + loss scaling lambda (applied in the trainer; the GEMM
            wrapping here is identical to ``fp8``)
  s2fp8   — the paper's format (shifted & squeezed truncation)

Models never reference numerics directly — they call ``policy.dot`` /
``policy.einsum`` / ``policy.conv`` and get the right dataflow, so every
architecture in configs/ is numerics-agnostic.

The s2fp8 truncations are routed through the numerics-backend registry
(core/backend.py): ``backend="ref"`` is the pure-jnp path, ``"pallas"``
the fused-kernel path (bitwise-identical by construction), and the
default ``"auto"`` picks pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import s2fp8
from repro.core import statsbank

MODES = ("fp32", "bf16", "fp8", "fp8_ls", "s2fp8", "s2fp8_e4m3")


def _identity(x):
    return x


@functools.lru_cache(maxsize=None)
def _s2fp8_wrap(backend: Optional[str], fmt: str) -> Callable:
    """Session-aware truncation wrapper for the s2fp8 modes.

    When a StatsBank session is active (core/statsbank.py — the trainer
    binds one inside the jitted train step), each call resolves to a named
    bank site: the truncation reuses the site's carried (alpha, beta) and
    the stats reduction only runs on refresh steps.  Outside a session it
    is the classic exact-stats ``bidir_truncate``.  Cached per
    (backend, fmt) so the callable is a stable object under jit tracing.
    """
    exact = nbackend.bidir_truncate(backend, fmt)

    def wrap(x):
        sess = statsbank.current_session()
        if sess is not None:
            return sess.truncate(x, fmt=fmt, backend=backend)
        return exact(x)

    return wrap


def _bf16_cast(x):
    # bf16 operand storage, f32 accumulation (preferred_element_type below).
    return x.astype(jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numeric execution policy for all bilinear ops in a model."""

    mode: str = "fp32"
    # Truncate the GEMM output as well as the operands (paper: "before and
    # after every convolution and matrix-matrix product").
    truncate_output: bool = True
    # Loss scale for fp8_ls (consumed by the trainer; kept here so configs
    # carry one self-contained numerics description).
    loss_scale: float = 1.0
    # GEMM output dtype. None -> f32 (paper-strict: every partial sum in
    # f32, including cross-shard).  "bfloat16" rounds the MXU's f32
    # accumulator to bf16 at the GEMM boundary — within-GEMM accumulation
    # stays f32 (the paper's actual requirement) but TP partial-sum
    # all-reduces then move half the bytes (hillclimb lever; EXPERIMENTS.md
    # §Perf documents the trade).
    output_dtype: Optional[str] = None
    # Numerics backend for the s2fp8 truncations (core/backend.py registry).
    # "auto" -> pallas on TPU, ref elsewhere; both produce bitwise-identical
    # truncations, so the choice is an execution detail, not a semantic one.
    backend: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown numeric mode {self.mode!r}; want one of {MODES}")
        if self.backend != "auto" and \
                self.backend not in nbackend.available_backends():
            raise ValueError(
                f"unknown numerics backend {self.backend!r}; registered: "
                f"{('auto',) + nbackend.available_backends()}")

    # -- operand / output transforms ------------------------------------
    @property
    def backend_obj(self) -> "nbackend.NumericsBackend":
        return nbackend.get_backend(self.backend)

    @property
    def _wrap(self) -> Callable:
        if self.mode == "s2fp8":
            return _s2fp8_wrap(self.backend, "e5m2")
        if self.mode == "s2fp8_e4m3":
            return _s2fp8_wrap(self.backend, "e4m3")
        if self.mode in ("fp8", "fp8_ls"):
            return s2fp8.fp8_truncate_bidir
        if self.mode == "bf16":
            return _bf16_cast
        return _identity

    @property
    def accum_dtype(self):
        if self.output_dtype == "bfloat16":
            return jnp.bfloat16
        return jnp.float32

    def truncate(self, x: jnp.ndarray) -> jnp.ndarray:
        """Tensor-level truncation at op boundaries (bidirectional: the
        cotangent is truncated too for fp8/s2fp8 modes)."""
        return self._wrap(x)

    def _wrap_out(self, y):
        if self.truncate_output and self.mode in ("s2fp8", "s2fp8_e4m3", "fp8", "fp8_ls"):
            return self._wrap(y)
        return y

    # -- bilinear ops -----------------------------------------------------
    def dot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        w = self._wrap
        y = jnp.dot(w(a), w(b), preferred_element_type=self.accum_dtype)
        return self._wrap_out(y).astype(a.dtype)

    def dot_general(self, a, b, dimension_numbers) -> jnp.ndarray:
        w = self._wrap
        y = jax.lax.dot_general(
            w(a), w(b), dimension_numbers, preferred_element_type=self.accum_dtype
        )
        return self._wrap_out(y).astype(a.dtype)

    def einsum(self, spec: str, *operands) -> jnp.ndarray:
        w = self._wrap
        y = jnp.einsum(
            spec, *[w(o) for o in operands], preferred_element_type=self.accum_dtype
        )
        return self._wrap_out(y).astype(operands[0].dtype)

    def conv(self, x, kernel, *, stride=(1, 1), padding="SAME") -> jnp.ndarray:
        """NHWC x HWIO conv — the ResNet path (conv is a GEMM to the paper)."""
        w = self._wrap
        y = jax.lax.conv_general_dilated(
            w(x), w(kernel),
            window_strides=stride, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=self.accum_dtype,
        )
        return self._wrap_out(y).astype(x.dtype)

    def qdot(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Payload-domain GEMM: quantize both operands to S2FP8 storage and
        run the backend's fused dequant-matmul (the paper §5 "tensor
        processing engine" — operands stream at 1 byte/element).  Forward
        value only (no custom VJP): intended for inference/serving paths;
        training GEMMs go through ``dot``'s Fig. 4 wrapping."""
        if self.mode == "s2fp8_e4m3":
            # storage payloads are e5m2-only today (ROADMAP: e4m3 backend
            # parity) — refuse rather than silently compute in e5m2
            raise NotImplementedError(
                "qdot has no e4m3 storage path yet; use mode='s2fp8' or dot()")
        if self.mode != "s2fp8":
            return self.dot(a, b)
        be = self.backend_obj
        sess = statsbank.current_session()
        if sess is not None:
            # bank-carried operand stats: quantization is pure elementwise
            # (no per-call reduction); serving keeps the bank warm via
            # statsbank.HostStatsBank
            sa = sess.operand_stats(a, fmt="e5m2")
            sb = sess.operand_stats(b, fmt="e5m2")
            y = be.qmatmul(be.quantize(a, stats=sa), be.quantize(b, stats=sb))
        else:
            y = be.qmatmul(be.quantize(a), be.quantize(b))
        return self._wrap_out(y).astype(a.dtype)


def make_policy(mode: str, loss_scale: Optional[float] = None,
                backend: Optional[str] = None) -> Policy:
    return Policy(mode=mode,
                  loss_scale=loss_scale if loss_scale is not None else 1.0,
                  backend=backend or "auto")
