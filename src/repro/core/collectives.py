"""S2FP8-compressed data-parallel gradient synchronization (beyond-paper).

The paper never discusses distribution; this extends its format to the DP
gradient all-reduce, which at pod scale is ICI-bound.  Key numerics fact:
S2FP8 is a *nonlinear* code (log-domain affine), so summation does NOT
commute with encoding — you cannot all-reduce payloads directly.  We
therefore split the all-reduce into its two data-movement-asymmetric legs:

    all_reduce(g)  ==  all_gather(reduce_scatter(g))

  * reduce-scatter leg: arithmetic — runs in bf16 (additive-safe, 2 bytes/elt)
  * all-gather leg: pure data movement — each device S2FP8-encodes its
    *reduced* shard (1 byte/elt + 8 bytes stats) and gathers payloads.

ICI bytes per element: f32 all-reduce ~ 2*(n-1)/n * 4B; compressed version
~ (n-1)/n * (2B + 1B) — a ~2.7x traffic cut with the paper's own format
carrying the gather leg.

Two API levels:

  * **axis level** (``grad_sync_axis`` / ``compressed_allreduce_axis``) —
    plain functions over ``lax`` collectives that run INSIDE an existing
    ``shard_map`` body.  This is what the mesh-native train step
    (training/trainer.py ``make_train_step(mesh=...)``) composes: the
    gradient pytree is synced leaf-by-leaf with a per-leaf routing
    decision (:func:`leaf_sync_route`) — small, integer, 0-d or
    non-divisible leaves bypass compression through a plain ``psum``.
  * **mesh level** (``compressed_grad_sync`` / ``compressed_allreduce_1d``)
    — self-contained wrappers that build their own ``shard_map`` over a
    replicated input; kept for standalone callers and as the numerics
    test surface (tests/test_collectives.py).

The schedule is explicit ``lax`` collectives so it is inspectable in HLO;
the dry-run roofline counts the bytes (see also ``modeled_ici_bytes`` in
benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend as nbackend
from repro.core.s2fp8 import S2FP8Tensor

AxisName = Union[str, Tuple[str, ...]]


def _encode_local(x: jnp.ndarray, backend: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard S2FP8 encode through the numerics-backend registry —
    LOCAL stats by the backend-interface convention (``compute_stats``
    without ``axis_name`` reduces over the tensor the caller holds; inside
    a shard_map body that is the shard).  Still one (a, b) pair per
    tensor-shard, 8 bytes against megabytes of payload; on TPU pods the
    registry resolves to the fused Pallas kernels for the encode pass."""
    t = nbackend.get_backend(backend).quantize(x)
    return t.payload, t.alpha, t.beta


def _decode_local(payload, alpha, beta, backend: Optional[str] = None
                  ) -> jnp.ndarray:
    return nbackend.get_backend(backend).dequantize(
        S2FP8Tensor(payload=payload, alpha=alpha, beta=beta))


# ---------------------------------------------------------------------------
# per-leaf routing
# ---------------------------------------------------------------------------

def leaf_sync_route(shape: Sequence[int], dtype, axis_size: int,
                    min_size: int = 1 << 16) -> str:
    """Routing decision for one gradient leaf: ``"compressed"`` (S2FP8
    all-gather leg) or ``"plain"`` (f32 psum).  Pure function of the
    leaf's static shape/dtype, so the decision is trace-free and
    unit-testable (tests/test_mesh_train.py).

    A leaf bypasses compression when any of:

      * non-float dtype — integer/bool leaves (step counters, masks) have
        no log2 image; summation must stay exact;
      * 0-d scalar — nothing to scatter, and the 8-byte stats would
        outweigh the payload;
      * fewer than ``min_size`` elements — below ~64k the per-tensor
        stats reduction and kernel launches dominate the 3-byte/elt win;
      * length not divisible by ``axis_size`` — the tiled
        psum_scatter/all_gather legs need equal shards (padding a grad
        leaf would perturb its stats).
    """
    size = 1
    for d in shape:
        size *= d
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return "plain"
    if len(shape) == 0:
        return "plain"
    if size < min_size:
        return "plain"
    if size % axis_size != 0:
        return "plain"
    return "compressed"


# ---------------------------------------------------------------------------
# axis level: composable inside an existing shard_map body
# ---------------------------------------------------------------------------

def compressed_allreduce_axis(flat: jnp.ndarray, axis_name: str,
                              axis_size: int,
                              backend: Optional[str] = None) -> jnp.ndarray:
    """SUM-all-reduce a 1-D f32 leaf across a mapped axis with the
    S2FP8-compressed all-gather leg.  Must run inside a ``shard_map`` (or
    other mapped context) where ``axis_name`` is bound; ``flat`` is the
    local value (len % axis_size == 0).  ``backend`` selects the numerics
    engine for the encode/decode legs (None/"auto": platform default —
    fused Pallas kernels on TPU, ref jnp elsewhere)."""
    red = jax.lax.psum_scatter(flat.astype(jnp.bfloat16), axis_name,
                               scatter_dimension=0, tiled=True)
    payload, alpha, beta = _encode_local(red.astype(jnp.float32), backend)
    payloads = jax.lax.all_gather(payload, axis_name, tiled=True)
    alphas = jax.lax.all_gather(alpha[None], axis_name)
    betas = jax.lax.all_gather(beta[None], axis_name)
    chunks = payloads.reshape(axis_size, flat.shape[0] // axis_size)
    dec = jax.vmap(functools.partial(_decode_local, backend=backend))(
        chunks, alphas[:, 0], betas[:, 0])
    return dec.reshape(-1)


def grad_sync_axis(grads, axis_name: AxisName, axis_sizes: Dict[str, int],
                   *, mode: str = "s2fp8", min_size: int = 1 << 16,
                   backend: Optional[str] = None):
    """SUM-reduce a gradient pytree across mapped mesh axes, inside an
    existing ``shard_map`` body.

    This is the mesh-native train step's gradient synchronizer: the step
    scales its local loss by ``1 / global_batch_shards`` before
    differentiation, so the per-shard gradients are *contributions* to the
    global mean and the sync is a pure sum (no trailing division — the
    1-device and N-device backward pipelines then see identical
    per-element cotangent values).

    * ``mode="f32"``  — every leaf is a plain ``psum`` (float leaves
      promoted to f32 for the wire, cast back after).
    * ``mode="s2fp8"`` — leaves routed per :func:`leaf_sync_route`:
      compressible leaves take the bf16-reduce-scatter + S2FP8-all-gather
      legs, the rest fall back to plain psum.

    ``axis_name`` may be a tuple (e.g. ``("pod", "data")``): the
    compressed legs run over the LAST axis (the largest, innermost data
    axis by the mesh conventions in launch/mesh.py) and a plain f32 psum
    folds the leading axes first.
    """
    if mode not in ("f32", "s2fp8"):
        raise ValueError(f"grad_sync mode must be 'f32' or 's2fp8', "
                         f"got {mode!r}")
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    inner = axes[-1]

    def plain(g):
        if jnp.issubdtype(g.dtype, jnp.floating):
            return jax.lax.psum(g.astype(jnp.float32), axes).astype(g.dtype)
        return jax.lax.psum(g, axes)

    def sync(g):
        if mode == "f32" or leaf_sync_route(
                g.shape, g.dtype, axis_sizes[inner], min_size) == "plain":
            return plain(g)
        flat = g.reshape(-1).astype(jnp.float32)
        if len(axes) > 1:
            flat = jax.lax.psum(flat, axes[:-1])
        out = compressed_allreduce_axis(flat, inner, axis_sizes[inner],
                                        backend)
        return out.reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(sync, grads)


# ---------------------------------------------------------------------------
# mesh level: self-contained wrappers over replicated inputs
# ---------------------------------------------------------------------------

def compressed_allreduce_1d(g: jnp.ndarray, mesh: Mesh, axis: str = "data",
                            backend: Optional[str] = None):
    """All-reduce a replicated-per-shard gradient across ``axis`` with an
    S2FP8-compressed all-gather leg.  g must be 1-D with len % axis_size == 0
    (caller flattens/pads; see ``compressed_grad_sync``).  Builds its own
    ``shard_map``; the body is :func:`compressed_allreduce_axis`."""
    n = mesh.shape[axis]
    body = functools.partial(compressed_allreduce_axis, axis_name=axis,
                             axis_size=n, backend=backend)
    return shard_map(body, mesh=mesh,
                     in_specs=P(), out_specs=P(), check_rep=False)(g)


def compressed_grad_sync(grads, mesh: Mesh, axis: str = "data",
                         min_size: int = 1 << 16,
                         backend: Optional[str] = None):
    """Apply the compressed all-reduce to every leaf :func:`leaf_sync_route`
    deems compressible (small / integer / 0-d / non-divisible leaves go
    through a plain f32 psum — stats overhead dominates below ~64k
    elements, and non-float leaves must sum exactly).  Leaves are averaged
    over ``axis``."""
    n = mesh.shape[axis]

    def sync_leaf(g):
        if leaf_sync_route(g.shape, g.dtype, n, min_size) == "plain":
            if jnp.issubdtype(g.dtype, jnp.integer):
                # integer/bool leaves stay in their own dtype: psum the n
                # replicated copies and divide back exactly (the sum is a
                # multiple of n, so floor-division is the true mean) — an
                # f32 round-trip would truncate and drop bits past 2^24
                def plain_int(x):
                    return jax.lax.psum(x, axis) // n
                return shard_map(plain_int, mesh=mesh, in_specs=P(),
                                 out_specs=P(), check_rep=False)(g)

            def plain(x):
                return jax.lax.psum(x, axis) / n
            return shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_rep=False)(g.astype(jnp.float32)).astype(g.dtype)
        flat = g.reshape(-1).astype(jnp.float32)
        out = compressed_allreduce_1d(flat, mesh, axis, backend) / n
        return out.reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(sync_leaf, grads)
