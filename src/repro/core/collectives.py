"""S2FP8-compressed data-parallel gradient synchronization (beyond-paper).

The paper never discusses distribution; this extends its format to the DP
gradient all-reduce, which at pod scale is ICI-bound.  Key numerics fact:
S2FP8 is a *nonlinear* code (log-domain affine), so summation does NOT
commute with encoding — you cannot all-reduce payloads directly.  We
therefore split the all-reduce into its two data-movement-asymmetric legs:

    all_reduce(g)  ==  all_gather(reduce_scatter(g))

  * reduce-scatter leg: arithmetic — runs in bf16 (additive-safe, 2 bytes/elt)
  * all-gather leg: pure data movement — each device S2FP8-encodes its
    *reduced* shard (1 byte/elt + 8 bytes stats) and gathers payloads.

ICI bytes per element: f32 all-reduce ~ 2*(n-1)/n * 4B; compressed version
~ (n-1)/n * (2B + 1B) — a ~2.7x traffic cut with the paper's own format
carrying the gather leg.  Implemented with shard_map + lax collectives so
the schedule is explicit and inspectable in HLO (tests/test_collectives.py
verifies numerics; the dry-run roofline counts the bytes).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend as nbackend
from repro.core.s2fp8 import S2FP8Tensor


def _encode_local(x: jnp.ndarray, backend: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard S2FP8 encode through the numerics-backend registry —
    LOCAL stats by the backend-interface convention (``compute_stats``
    without ``axis_name`` reduces over the tensor the caller holds; inside
    a shard_map body that is the shard).  Still one (a, b) pair per
    tensor-shard, 8 bytes against megabytes of payload; on TPU pods the
    registry resolves to the fused Pallas kernels for the encode pass."""
    t = nbackend.get_backend(backend).quantize(x)
    return t.payload, t.alpha, t.beta


def _decode_local(payload, alpha, beta, backend: Optional[str] = None
                  ) -> jnp.ndarray:
    return nbackend.get_backend(backend).dequantize(
        S2FP8Tensor(payload=payload, alpha=alpha, beta=beta))


def compressed_allreduce_1d(g: jnp.ndarray, mesh: Mesh, axis: str = "data",
                            backend: Optional[str] = None):
    """All-reduce a replicated-per-shard gradient across ``axis`` with an
    S2FP8-compressed all-gather leg.  g must be 1-D with len % axis_size == 0
    (caller flattens/pads; see ``compressed_grad_sync``).  ``backend``
    selects the numerics engine for the encode/decode legs (None/"auto":
    platform default — fused Pallas kernels on TPU, ref jnp elsewhere)."""
    n = mesh.shape[axis]

    def body(gl):
        # gl: the local copy [L]. reduce_scatter in bf16.
        red = jax.lax.psum_scatter(gl.astype(jnp.bfloat16), axis,
                                   scatter_dimension=0, tiled=True)
        payload, alpha, beta = _encode_local(red.astype(jnp.float32), backend)
        payloads = jax.lax.all_gather(payload, axis, tiled=True)
        alphas = jax.lax.all_gather(alpha[None], axis)
        betas = jax.lax.all_gather(beta[None], axis)
        shard_len = gl.shape[0] // n
        chunks = payloads.reshape(n, shard_len)
        dec = jax.vmap(functools.partial(_decode_local, backend=backend))(
            chunks, alphas[:, 0], betas[:, 0])
        return dec.reshape(-1)

    return shard_map(body, mesh=mesh,
                     in_specs=P(), out_specs=P(), check_rep=False)(g)


def compressed_grad_sync(grads, mesh: Mesh, axis: str = "data",
                         min_size: int = 1 << 16,
                         backend: Optional[str] = None):
    """Apply the compressed all-reduce to every leaf >= min_size elements
    (small leaves go through a plain f32 psum — stats overhead dominates
    below ~64k elements). Leaves are averaged over ``axis``."""
    n = mesh.shape[axis]

    def sync_leaf(g):
        flat = g.reshape(-1).astype(jnp.float32) / n
        if flat.shape[0] < min_size or flat.shape[0] % n != 0:
            def plain(x):
                return jax.lax.psum(x, axis) / n
            return shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_rep=False)(g.astype(jnp.float32)).astype(g.dtype)
        out = compressed_allreduce_1d(flat * n, mesh, axis, backend) / n
        return out.reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(sync_leaf, grads)
