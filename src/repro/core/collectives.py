"""S2FP8-compressed data-parallel gradient synchronization (beyond-paper).

The paper never discusses distribution; this extends its format to the DP
gradient all-reduce, which at pod scale is ICI-bound.  Key numerics fact:
S2FP8 is a *nonlinear* code (log-domain affine), so summation does NOT
commute with encoding — you cannot all-reduce payloads directly.  We
therefore split the all-reduce into its two data-movement-asymmetric legs:

    all_reduce(g)  ==  all_gather(reduce_scatter(g))

  * reduce-scatter leg: arithmetic — runs in bf16 (additive-safe, 2 bytes/elt)
  * all-gather leg: pure data movement — each device S2FP8-encodes its
    *reduced* shard (1 byte/elt + 8 bytes stats) and gathers payloads.

ICI bytes per element: f32 all-reduce ~ 2*(n-1)/n * 4B; compressed version
~ (n-1)/n * (2B + 1B) — a ~2.7x traffic cut with the paper's own format
carrying the gather leg.

Two API levels:

  * **axis level** (``grad_sync_axis`` / ``compressed_allreduce_axis``) —
    plain functions over ``lax`` collectives that run INSIDE an existing
    ``shard_map`` body.  This is what the mesh-native train step
    (training/trainer.py ``make_train_step(mesh=...)``) composes: the
    gradient pytree is synced leaf-by-leaf with a per-leaf routing
    decision (:func:`leaf_sync_route`) — small, integer, 0-d or
    non-divisible leaves bypass compression through a plain ``psum``.
  * **mesh level** (``compressed_grad_sync`` / ``compressed_allreduce_1d``)
    — self-contained wrappers that build their own ``shard_map`` over a
    replicated input; kept for standalone callers and as the numerics
    test surface (tests/test_collectives.py).

The schedule is explicit ``lax`` collectives so it is inspectable in HLO;
the dry-run roofline counts the bytes (see also ``modeled_ici_bytes`` in
benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple, \
    Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend as nbackend
from repro.core.s2fp8 import S2FP8Tensor

AxisName = Union[str, Tuple[str, ...]]


def _encode_local(x: jnp.ndarray, backend: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard S2FP8 encode through the numerics-backend registry —
    LOCAL stats by the backend-interface convention (``compute_stats``
    without ``axis_name`` reduces over the tensor the caller holds; inside
    a shard_map body that is the shard).  Still one (a, b) pair per
    tensor-shard, 8 bytes against megabytes of payload; on TPU pods the
    registry resolves to the fused Pallas kernels for the encode pass."""
    t = nbackend.get_backend(backend).quantize(x)
    return t.payload, t.alpha, t.beta


def _decode_local(payload, alpha, beta, backend: Optional[str] = None
                  ) -> jnp.ndarray:
    return nbackend.get_backend(backend).dequantize(
        S2FP8Tensor(payload=payload, alpha=alpha, beta=beta))


# ---------------------------------------------------------------------------
# per-leaf routing
# ---------------------------------------------------------------------------

def leaf_sync_route(shape: Sequence[int], dtype, axis_size: int,
                    min_size: int = 1 << 16) -> str:
    """Routing decision for one gradient leaf: ``"compressed"`` (S2FP8
    all-gather leg) or ``"plain"`` (f32 psum).  Pure function of the
    leaf's static shape/dtype, so the decision is trace-free and
    unit-testable (tests/test_mesh_train.py).

    A leaf bypasses compression when any of:

      * non-float dtype — integer/bool leaves (step counters, masks) have
        no log2 image; summation must stay exact;
      * 0-d scalar — nothing to scatter, and the 8-byte stats would
        outweigh the payload;
      * fewer than ``min_size`` elements — below ~64k the per-tensor
        stats reduction and kernel launches dominate the 3-byte/elt win;
      * length not divisible by ``axis_size`` — the tiled
        psum_scatter/all_gather legs need equal shards (padding a grad
        leaf would perturb its stats).
    """
    size = 1
    for d in shape:
        size *= d
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return "plain"
    if len(shape) == 0:
        return "plain"
    if size < min_size:
        return "plain"
    if size % axis_size != 0:
        return "plain"
    return "compressed"


# ---------------------------------------------------------------------------
# axis level: composable inside an existing shard_map body
# ---------------------------------------------------------------------------

def compressed_allreduce_axis(flat: jnp.ndarray, axis_name: str,
                              axis_size: int,
                              backend: Optional[str] = None) -> jnp.ndarray:
    """SUM-all-reduce a 1-D f32 leaf across a mapped axis with the
    S2FP8-compressed all-gather leg.  Must run inside a ``shard_map`` (or
    other mapped context) where ``axis_name`` is bound; ``flat`` is the
    local value (len % axis_size == 0).  ``backend`` selects the numerics
    engine for the encode/decode legs (None/"auto": platform default —
    fused Pallas kernels on TPU, ref jnp elsewhere)."""
    red = jax.lax.psum_scatter(flat.astype(jnp.bfloat16), axis_name,
                               scatter_dimension=0, tiled=True)
    payload, alpha, beta = _encode_local(red.astype(jnp.float32), backend)
    payloads = jax.lax.all_gather(payload, axis_name, tiled=True)
    alphas = jax.lax.all_gather(alpha[None], axis_name)
    betas = jax.lax.all_gather(beta[None], axis_name)
    chunks = payloads.reshape(axis_size, flat.shape[0] // axis_size)
    dec = jax.vmap(functools.partial(_decode_local, backend=backend))(
        chunks, alphas[:, 0], betas[:, 0])
    return dec.reshape(-1)


def grad_sync_axis(grads, axis_name: AxisName, axis_sizes: Dict[str, int],
                   *, mode: str = "s2fp8", min_size: int = 1 << 16,
                   backend: Optional[str] = None, skip=None):
    """SUM-reduce a gradient pytree across mapped mesh axes, inside an
    existing ``shard_map`` body.

    This is the mesh-native train step's gradient synchronizer: the step
    scales its local loss by ``1 / global_batch_shards`` before
    differentiation, so the per-shard gradients are *contributions* to the
    global mean and the sync is a pure sum (no trailing division — the
    1-device and N-device backward pipelines then see identical
    per-element cotangent values).

    * ``mode="f32"``  — every leaf is a plain ``psum`` (float leaves
      promoted to f32 for the wire, cast back after).
    * ``mode="s2fp8"`` — leaves routed per :func:`leaf_sync_route`:
      compressible leaves take the bf16-reduce-scatter + S2FP8-all-gather
      legs, the rest fall back to plain psum.

    ``axis_name`` may be a tuple (e.g. ``("pod", "data")``): the
    compressed legs run over the LAST axis (the largest, innermost data
    axis by the mesh conventions in launch/mesh.py) and a plain f32 psum
    folds the leading axes first.

    ``skip``: optional bool pytree matching ``grads`` — True leaves are
    returned untouched.  The FSDP train step uses this for param leaves
    whose gradients exit ``jax.grad`` already reduce-scattered to the
    owner shard by the gather custom_vjp's backward.
    """
    if mode not in ("f32", "s2fp8"):
        raise ValueError(f"grad_sync mode must be 'f32' or 's2fp8', "
                         f"got {mode!r}")
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    inner = axes[-1]

    def plain(g):
        if jnp.issubdtype(g.dtype, jnp.floating):
            return jax.lax.psum(g.astype(jnp.float32), axes).astype(g.dtype)
        return jax.lax.psum(g, axes)

    def sync(g):
        if mode == "f32" or leaf_sync_route(
                g.shape, g.dtype, axis_sizes[inner], min_size) == "plain":
            return plain(g)
        flat = g.reshape(-1).astype(jnp.float32)
        if len(axes) > 1:
            flat = jax.lax.psum(flat, axes[:-1])
        out = compressed_allreduce_axis(flat, inner, axis_sizes[inner],
                                        backend)
        return out.reshape(g.shape).astype(g.dtype)

    if skip is not None:
        return jax.tree_util.tree_map(
            lambda g, s: g if s else sync(g), grads, skip)
    return jax.tree_util.tree_map(sync, grads)


# ---------------------------------------------------------------------------
# FSDP param axis: gather-on-use / scatter-on-grad
# ---------------------------------------------------------------------------
#
# The grad machinery above syncs REPLICATED leaves; this section is the
# param-axis counterpart for leaves *sharded* over the mesh's ``fsdp``
# axis (dim 0, ZeRO-3 style).  Two wire formats for the gather leg:
#
#   * f32   — ``param_gather_axis``: a tiled all-gather of the owner
#     shards (4 bytes/elt), wrapped in a custom_vjp whose backward is the
#     grad reduce-scatter, so grads leave ``jax.grad`` already summed AND
#     sharded back to the owner (the trainer skips its replicated sync
#     for these leaves).
#   * payload — ``payload_gather_axis``: each owner quantizes its shard
#     with the leaf-GLOBAL (alpha, beta) from the StatsBank (the
#     partials psum over the batch axes makes every shard agree on the
#     stats; refresh cadence == quantize-at-owner cadence) and gathers
#     1-byte payloads.  The result is a full-size ``S2FP8Tensor`` that
#     ``qdot_train`` feeds straight into the payload GEMM operand slot —
#     no f32/bf16 copy of the leaf ever crosses the wire or lands in HBM.
#
# ``FSDPPayloadParam`` is the handoff contract: the trainer wraps each
# payload-eligible shard in it, the wrapper flows through the user's
# loss_fn as a pytree leaf, and ``Policy.dot`` / ``qdot_train`` unwrap it
# at the GEMM.  Any OTHER consumption (embedding lookups, norms, ...)
# degrades safely: ``__jax_array__`` coerces through the f32 gather with
# the same reduce-scatter backward.

class FSDPInfo(NamedTuple):
    """Static (hashable) description of one FSDP-sharded leaf: how to
    gather it and how to return its gradient.  ``lead_axes`` are the
    OTHER mapped batch axes (e.g. ``("pod",)``) whose contributions must
    psum before the reduce-scatter over ``axis``.  ``gather_f32`` is the
    per-train-step custom_vjp f32 gather (shared so ``__jax_array__``
    fallbacks get the identical grad path)."""
    axis: str
    axis_size: int
    lead_axes: Tuple[str, ...]
    grad_mode: str
    grad_min_size: int
    grad_backend: Optional[str]
    gather_f32: Optional[Callable] = None


def param_scatter_axis(g: jnp.ndarray, info: FSDPInfo) -> jnp.ndarray:
    """Reduce a full-size grad leaf back to the owner's shard: psum over
    the lead batch axes, then reduce-scatter over the fsdp axis (dim 0).
    This is the sharded half of ``all_reduce == all_gather(reduce_scatter)``
    — FSDP grads only need to exist at the owner, so the compressed path
    keeps just the arithmetic (bf16 reduce-scatter) leg and drops the
    payload all-gather leg entirely."""
    if info.lead_axes:
        g = jax.lax.psum(g, info.lead_axes)
    if info.axis_size == 1:
        return g
    route = ("compressed" if info.grad_mode == "s2fp8" and leaf_sync_route(
        g.shape, g.dtype, info.axis_size, info.grad_min_size) == "compressed"
        else "plain")
    wire = jnp.bfloat16 if route == "compressed" else jnp.float32
    red = jax.lax.psum_scatter(g.astype(wire), info.axis,
                               scatter_dimension=0, tiled=True)
    return red.astype(g.dtype)


def make_param_gather(info: FSDPInfo) -> Callable:
    """custom_vjp f32 gather for one FSDP leaf config: forward is a tiled
    all-gather over dim 0 (shard -> full leaf), backward is
    :func:`param_scatter_axis` (full cotangent -> owner shard).  Build
    ONCE per train-step factory so the custom_vjp identity is stable
    across traces."""
    @jax.custom_vjp
    def gather(p_shard):
        return jax.lax.all_gather(p_shard, info.axis, tiled=True)

    def fwd(p_shard):
        return gather(p_shard), None

    def bwd(_, g):
        return (param_scatter_axis(g, info),)

    gather.defvjp(fwd, bwd)
    return gather


def param_gather_axis(p_shard: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Plain (non-differentiable-boundary) tiled f32 gather of an FSDP
    shard over dim 0 — 4 bytes/elt on the wire.  For the in-step gather
    use :func:`make_param_gather` (this is the forward leg only)."""
    return jax.lax.all_gather(p_shard, axis_name, tiled=True)


def payload_gather_axis(q_local: S2FP8Tensor, axis_name: str) -> S2FP8Tensor:
    """All-gather an S2FP8-quantized FSDP shard into the full-size
    payload tensor: 1 byte/elt on the wire, stats scalars ride along
    unchanged (every shard quantized with the same leaf-global (alpha,
    beta), so the gathered tensor is a single coherent S2FP8Tensor).
    FP8 payloads move as bitcast u8 — all_gather is pure data movement
    and some backends reject sub-byte-exponent float element types."""
    u8 = jax.lax.bitcast_convert_type(q_local.payload, jnp.uint8)
    full = jax.lax.all_gather(u8, axis_name, tiled=True)
    payload = jax.lax.bitcast_convert_type(full, q_local.payload.dtype)
    return S2FP8Tensor(payload=payload, alpha=q_local.alpha,
                       beta=q_local.beta, fmt=q_local.fmt)


class FSDPPayloadParam:
    """Pytree marker carrying one payload-eligible FSDP shard into the
    loss function.  Child: the local f32 shard (dim 0 = full / axis_size);
    static aux: the :class:`FSDPInfo`.  ``qdot_train`` consumes it
    directly (quantize-at-owner -> payload all-gather -> payload GEMM B
    slot -> grad reduce-scatter); every other consumption coerces via
    ``__jax_array__`` through the f32 gather custom_vjp, which keeps the
    gradient contract identical."""

    def __init__(self, shard, info: FSDPInfo):
        self.shard = shard
        self.info = info

    # --- array-like surface (full LOGICAL leaf, not the shard) ---
    @property
    def shape(self):
        return (self.shard.shape[0] * self.info.axis_size,) \
            + tuple(self.shard.shape[1:])

    @property
    def ndim(self):
        return self.shard.ndim

    @property
    def dtype(self):
        return self.shard.dtype

    def __jax_array__(self):
        if self.info.gather_f32 is None:
            return param_gather_axis(self.shard, self.info.axis)
        return self.info.gather_f32(self.shard)

    def astype(self, dtype):
        return self.__jax_array__().astype(dtype)

    def __getitem__(self, idx):
        return self.__jax_array__()[idx]

    @property
    def T(self):
        # e.g. tied-embedding lm heads (`params["embed"].T`): a transposed
        # B slot can't stream the row-sharded payload, so it takes the f32
        # gather like any other non-GEMM consumption
        return self.__jax_array__().T

    def __repr__(self):
        return (f"FSDPPayloadParam(shard={self.shard.shape}, "
                f"full={self.shape}, axis={self.info.axis!r}"
                f"x{self.info.axis_size})")


jax.tree_util.register_pytree_node(
    FSDPPayloadParam,
    lambda p: ((p.shard,), p.info),
    lambda info, children: FSDPPayloadParam(children[0], info))


# ---------------------------------------------------------------------------
# mesh level: self-contained wrappers over replicated inputs
# ---------------------------------------------------------------------------

def compressed_allreduce_1d(g: jnp.ndarray, mesh: Mesh, axis: str = "data",
                            backend: Optional[str] = None):
    """All-reduce a replicated-per-shard gradient across ``axis`` with an
    S2FP8-compressed all-gather leg.  g must be 1-D with len % axis_size == 0
    (caller flattens/pads; see ``compressed_grad_sync``).  Builds its own
    ``shard_map``; the body is :func:`compressed_allreduce_axis`."""
    n = mesh.shape[axis]
    body = functools.partial(compressed_allreduce_axis, axis_name=axis,
                             axis_size=n, backend=backend)
    return shard_map(body, mesh=mesh,
                     in_specs=P(), out_specs=P(), check_rep=False)(g)


def compressed_grad_sync(grads, mesh: Mesh, axis: str = "data",
                         min_size: int = 1 << 16,
                         backend: Optional[str] = None):
    """Apply the compressed all-reduce to every leaf :func:`leaf_sync_route`
    deems compressible (small / integer / 0-d / non-divisible leaves go
    through a plain f32 psum — stats overhead dominates below ~64k
    elements, and non-float leaves must sum exactly).  Leaves are averaged
    over ``axis``."""
    n = mesh.shape[axis]

    def sync_leaf(g):
        if leaf_sync_route(g.shape, g.dtype, n, min_size) == "plain":
            if jnp.issubdtype(g.dtype, jnp.integer):
                # integer/bool leaves stay in their own dtype: psum the n
                # replicated copies and divide back exactly (the sum is a
                # multiple of n, so floor-division is the true mean) — an
                # f32 round-trip would truncate and drop bits past 2^24
                def plain_int(x):
                    return jax.lax.psum(x, axis) // n
                return shard_map(plain_int, mesh=mesh, in_specs=P(),
                                 out_specs=P(), check_rep=False)(g)

            def plain(x):
                return jax.lax.psum(x, axis) / n
            return shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_rep=False)(g.astype(jnp.float32)).astype(g.dtype)
        flat = g.reshape(-1).astype(jnp.float32)
        out = compressed_allreduce_1d(flat, mesh, axis, backend) / n
        return out.reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(sync_leaf, grads)
