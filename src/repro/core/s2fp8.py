"""S2FP8 — Shifted & Squeezed FP8 (Cambier et al., ICLR 2020), Eq. 1–5.

A tensor ``X`` is represented by an e5m2 payload ``Y`` plus two FP32
statistics ``alpha`` (squeeze) and ``beta`` (shift) such that

    log2|Y_i| = alpha * log2|X_i| + beta,      sign(Y_i) = sign(X_i)

with (paper Eq. 2–4, over the nonzero elements)

    mu    = mean_i log2|X_i|
    m     = max_i  log2|X_i|
    alpha = 15 / (m - mu)
    beta  = -alpha * mu

so that log2|Y| has zero mean and max exactly 15 — centered in FP8's
[2^-16, 2^16] window.  The training-simulation truncation (paper Eq. 5) is

    T(X) = sign(X) * ( 2^{-beta} * truncate_FP8( 2^{beta} |X|^{alpha} ) )^{1/alpha}

All transforms are computed in the log2 domain (exact exponent arithmetic,
no overflow: the forward log-image is <= 15 by construction).

Three layers of API:

* ``compute_stats`` / ``quantize`` / ``dequantize`` — the storage format
  (``S2FP8Tensor`` pytree: 1 byte/elt payload + 2 scalars).  Used for
  checkpoint compression and compressed collectives.
* ``truncate`` — Eq. 5 value simulation with configurable gradient behaviour
  (straight-through, or truncating the cotangent as well).
* ``quantized_dot`` semantics are composed in ``core/policy.py`` by placing
  bidirectional truncations around GEMM operands and results, which yields
  exactly the paper's Figure 4 dataflow for *any* bilinear op (dot, conv,
  einsum) without bespoke custom_vjp per op.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fp8

# Max log2 magnitude the transformed tensor is pinned to (paper Eq. 2).
TARGET_MAX_LOG2 = 15.0
# e4m3 variant (paper §6 future work: "broader suite of low precision
# formats"): e4m3 max normal is 448 ~= 2^8.8 — pin the transformed max at
# 2^8 to stay clear of saturation, trading dynamic range for the extra
# mantissa bit (eps 2^-4 vs e5m2's 2^-3).
TARGET_MAX_LOG2_E4M3 = 8.0
# Guard for degenerate tensors where max(log2|X|) == mean(log2|X|)
# (constant-magnitude tensors): fall back to a pure shift (alpha = 1).
_DEGENERATE_EPS = 1e-6

# One table per payload format — the single source the backend registry
# (core/backend.py), the dispatch layer and the Pallas kernels all read,
# so adding a format is a one-place change.
FMT_TARGET_MAX = {"e5m2": TARGET_MAX_LOG2, "e4m3": TARGET_MAX_LOG2_E4M3}
FMT_QDTYPE = {"e5m2": jnp.float8_e5m2, "e4m3": jnp.float8_e4m3fn}
FMT_MAX_FINITE = {"e5m2": fp8.E5M2_MAX, "e4m3": fp8.E4M3_MAX}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class S2FP8Tensor:
    """Storage representation: FP8 payload + (alpha, beta) statistics.

    ``fmt`` tags which 8-bit payload format the bytes are in ("e5m2" — the
    paper's — or "e4m3", the extra-mantissa-bit ablation).  It is pytree
    aux data (static), so format mismatches surface as trace-time shape
    errors rather than silently dequantizing with the wrong exponent map.
    """

    payload: jnp.ndarray        # float8 (per ``fmt``), same shape as source
    alpha: jnp.ndarray          # f32 scalar (squeeze)
    beta: jnp.ndarray           # f32 scalar (shift)
    fmt: str = "e5m2"           # payload format tag (static)

    @property
    def shape(self):
        return self.payload.shape

    @property
    def nbytes_payload(self) -> int:
        """Wire size: 1 byte per element plus one (alpha, beta) f32 pair —
        8 bytes total for the two stats, counted once per tensor."""
        return int(np.prod(self.payload.shape, dtype=np.int64)) + 8

    def reshape(self, *shape) -> "S2FP8Tensor":
        """Payload reshape (1-byte move); stats are global, so they carry."""
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return S2FP8Tensor(payload=self.payload.reshape(shape),
                           alpha=self.alpha, beta=self.beta, fmt=self.fmt)

    def tree_flatten(self):
        return (self.payload, self.alpha, self.beta), self.fmt

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, fmt=aux)


def stats_from_reduction(log_sum, log_max, count,
                         target_max: float = TARGET_MAX_LOG2
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scalar epilogue mapping the raw reduction (sum log2|X|, max log2|X|,
    nonzero count) to (alpha, beta) per paper Eq. 3–4.  Shared by the jnp
    path, the Pallas stats kernel and the fused truncate kernel so every
    backend agrees on the degenerate-case conventions:

      * all-zero tensor      -> identity transform (alpha=1, beta=0)
      * constant |X| (m==mu) -> pure shift pinning the max at 2^target_max
    """
    mu = log_sum / jnp.maximum(count, 1.0)
    spread = log_max - mu
    degenerate = spread < _DEGENERATE_EPS
    alpha = jnp.where(degenerate, 1.0, target_max / jnp.where(degenerate, 1.0, spread))
    beta = jnp.where(degenerate, target_max - log_max, -alpha * mu)
    empty = count == 0
    alpha = jnp.where(empty, 1.0, alpha)
    beta = jnp.where(empty, 0.0, beta)
    return alpha.astype(jnp.float32), beta.astype(jnp.float32)


def compute_stats_partials(x: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Raw reduction triplet (sum log2|X|, max log2|X|, nonzero count as f32).

    This is the additive/max-decomposable half of Eq. 3–4: partials from
    disjoint shards combine with (+, max, +), which is what gives sharded
    stats their exact global semantics — all-reduce the triplet, then run
    the :func:`stats_from_reduction` epilogue once (core/backend.py
    ``compute_stats(..., axis_name=...)`` and the StatsBank refresh both
    do exactly that).  ``log_max`` is -inf for an all-zero tensor.
    """
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    nonzero = absx > 0.0
    logx = jnp.where(nonzero, jnp.log2(jnp.where(nonzero, absx, 1.0)), 0.0)
    count = jnp.sum(nonzero)
    log_sum = jnp.sum(logx)
    log_max = jnp.max(jnp.where(nonzero, logx, -jnp.inf))
    return log_sum, log_max, count.astype(jnp.float32)


def compute_stats(x: jnp.ndarray,
                  target_max: float = TARGET_MAX_LOG2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (alpha, beta) per paper Eq. 3–4, ignoring zero elements."""
    log_sum, log_max, count = compute_stats_partials(x)
    return stats_from_reduction(log_sum, log_max, count, target_max)


# One jitted program for the stats reduction, shared by every backend
# (core/backend.py): alpha/beta must come from the SAME compiled program on
# both sides of a ref-vs-pallas comparison, or XLA's per-program fusion/FMA
# choices shift them by 1 ulp and break bitwise parity downstream.
compute_stats_jit = jax.jit(compute_stats, static_argnames=("target_max",))

# Partials as one jitted program too — the sharded-stats building block
# (psum/pmax the triplet, then the epilogue) keeps the same compiled
# reduction on every shard.
compute_stats_partials_jit = jax.jit(compute_stats_partials)


def _forward_map(x: jnp.ndarray, alpha, beta) -> jnp.ndarray:
    """Y = sign(X) * 2^{alpha*log2|X| + beta}, zeros preserved (f32)."""
    absx = jnp.abs(x)
    nonzero = absx > 0.0
    ylog = alpha * jnp.log2(jnp.where(nonzero, absx, 1.0)) + beta
    y = jnp.sign(x) * jnp.exp2(ylog)
    return jnp.where(nonzero, y, 0.0).astype(jnp.float32)


def _inverse_map(y: jnp.ndarray, alpha, beta) -> jnp.ndarray:
    """X = sign(Y) * 2^{(log2|Y| - beta)/alpha}, zeros preserved (f32)."""
    y = y.astype(jnp.float32)
    absy = jnp.abs(y)
    nonzero = absy > 0.0
    xlog = (jnp.log2(jnp.where(nonzero, absy, 1.0)) - beta) / alpha
    x = jnp.sign(y) * jnp.exp2(xlog)
    return jnp.where(nonzero, x, 0.0)


def quantize(x: jnp.ndarray, stats: Optional[Tuple] = None,
             fmt: str = "e5m2") -> S2FP8Tensor:
    """FP32/bf16 tensor -> S2FP8 storage (payload + stats).

    ``stats=(alpha, beta)`` quantizes with the given scalars instead of
    reducing over ``x`` — the delayed-stats / StatsBank path.  ``fmt``
    selects the payload format; the forward image is pinned at the
    format's target max (Eq. 2) and clamped at its max finite, so stale
    stats saturate instead of overflowing.

    The elementwise identity ``dequantize(quantize(x, stats=s)) ==
    truncate_value(x, stats=s)`` is what makes payload-domain GEMMs
    (core/qdot.py) replay the paper's Fig. 4 chain exactly."""
    if stats is None:
        stats = compute_stats(x, target_max=FMT_TARGET_MAX[fmt])
    alpha, beta = stats
    y = _forward_map(x.astype(jnp.float32), alpha, beta)
    fmax = FMT_MAX_FINITE[fmt]
    y = jnp.clip(y, -fmax, fmax)
    return S2FP8Tensor(payload=y.astype(FMT_QDTYPE[fmt]), alpha=alpha,
                       beta=beta, fmt=fmt)


def dequantize(t: S2FP8Tensor, dtype=jnp.float32) -> jnp.ndarray:
    """S2FP8 storage -> dense tensor."""
    return _inverse_map(t.payload.astype(jnp.float32), t.alpha, t.beta).astype(dtype)


def truncate_value(x: jnp.ndarray, stats: Optional[Tuple] = None) -> jnp.ndarray:
    """Paper Eq. 5: the pure value semantics of the S2FP8 round-trip.

    ``stats=(alpha, beta)`` skips the reduction — the delayed-stats hook
    used by core/backend.py to amortize the stats pass across steps.  The
    forward image is clamped at the e5m2 max finite: a no-op for fresh
    stats (|Y| <= 2^15 by construction) but it turns stale-stats overflow
    (delayed mode, tensor drifted upward) into saturation instead of inf.
    """
    alpha, beta = compute_stats(x) if stats is None else stats
    y = _forward_map(x.astype(jnp.float32), alpha, beta)
    y = jnp.clip(y, -fp8.E5M2_MAX, fp8.E5M2_MAX)
    yq = fp8.truncate_e5m2(y)
    return _inverse_map(yq, alpha, beta).astype(x.dtype)


def truncate_value_e4m3(x: jnp.ndarray, stats: Optional[Tuple] = None) -> jnp.ndarray:
    """S2FP8-e4m3 ablation (paper §6 future work): one more mantissa bit
    (eps 2^-4), range pinned at 2^8 — for narrow-distribution tensors the
    squeeze absorbs the range loss and precision improves ~2x."""
    if stats is None:
        stats = compute_stats(x, target_max=TARGET_MAX_LOG2_E4M3)
    alpha, beta = stats
    y = _forward_map(x.astype(jnp.float32), alpha, beta)
    y = jnp.clip(y, -fp8.E4M3_MAX, fp8.E4M3_MAX)
    yq = fp8.truncate_e4m3(y)
    return _inverse_map(yq, alpha, beta).astype(x.dtype)


@jax.custom_vjp
def truncate_bidir_e4m3(x):
    return truncate_value_e4m3(x)


def _bidir_e4m3_fwd(x):
    return truncate_value_e4m3(x), None


def _bidir_e4m3_bwd(_, g):
    return (truncate_value_e4m3(g),)


truncate_bidir_e4m3.defvjp(_bidir_e4m3_fwd, _bidir_e4m3_bwd)


# ---------------------------------------------------------------------------
# Differentiable truncations.
#
# ``truncate_ste``      : T on the forward value, identity on the cotangent.
# ``truncate_bidir``    : T on the forward value AND T on the cotangent.
#
# Placing ``truncate_bidir`` on each GEMM operand and on the GEMM output
# reproduces the paper's Figure 4 exactly: forward GEMM sees truncated
# A, W and its stored output is truncated; backward GEMMs consume a truncated
# dY (the output-T's cotangent rule) and emit truncated dX / dW (the
# operand-Ts' cotangent rules).  Master weights stay FP32 in the optimizer.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def truncate_ste(x):
    return truncate_value(x)


def _ste_fwd(x):
    return truncate_value(x), None


def _ste_bwd(_, g):
    return (g,)


truncate_ste.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def truncate_bidir(x):
    return truncate_value(x)


def _bidir_fwd(x):
    return truncate_value(x), None


def _bidir_bwd(_, g):
    return (truncate_value(g),)


truncate_bidir.defvjp(_bidir_fwd, _bidir_bwd)


# Plain-FP8 analogues (the paper's baseline): raw e5m2 RNE truncation with
# the same gradient conventions.  Out-of-range values overflow to inf /
# underflow to zero — that is the behaviour whose divergence the paper
# documents, so it is deliberately unguarded.

@jax.custom_vjp
def fp8_truncate_bidir(x):
    return fp8.truncate_e5m2(x)


def _fp8_fwd(x):
    return fp8.truncate_e5m2(x), None


def _fp8_bwd(_, g):
    return (fp8.truncate_e5m2(g),)


fp8_truncate_bidir.defvjp(_fp8_fwd, _fp8_bwd)


# ---------------------------------------------------------------------------
# Stats tracking (paper Fig. 5): expose (mu, m, alpha, beta) for logging.
# ---------------------------------------------------------------------------

def tensor_stats(x: jnp.ndarray) -> dict:
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    nonzero = absx > 0.0
    logx = jnp.where(nonzero, jnp.log2(jnp.where(nonzero, absx, 1.0)), 0.0)
    count = jnp.maximum(jnp.sum(nonzero), 1)
    mu = jnp.sum(logx) / count
    m = jnp.max(jnp.where(nonzero, logx, -jnp.inf))
    alpha, beta = compute_stats(x)
    return {"mu": mu, "m": m, "alpha": alpha, "beta": beta}
