"""qdot_train: the differentiable payload-domain training GEMM.

The paper's §5 tensor processing engine consumes FP8 payloads plus
(alpha, beta) directly; this module makes that the *training* execution of
``Policy.dot`` (and friends) instead of the composed Fig. 4 chain of three
f32-in/f32-out truncation passes around an f32 GEMM.

Forward::

    qA = quantize(A, bank stats)      # elementwise, 1-byte HBM write
    qB = quantize(B, bank stats)
    Y  = qmatmul(qA, qB, epilogue_stats=out-site stats)
         #  payload tiles stream HBM->VMEM at 1 B/elt, dequant on the VPU,
         #  f32 MXU accumulation, Eq. 5 epilogue on the output tile in VMEM

and the residuals saved for backward are the *payloads* plus scalar stats
— a ~4x activation-residual cut vs the Fig. 4 chain's truncated-f32
operands.

Backward (paper Fig. 4's two transposed GEMMs, payload-domain)::

    qG = quantize(g, cotangent-site stats)        # truncate+store, 1 pass
    dA = qmatmul(qG, qB, layout="nt", epilogue_stats=a-site bwd stats)
    dB = qmatmul(qA, qG, layout="tn", epilogue_stats=b-site bwd stats)

The NT/TN layouts read the saved payloads through swapped BlockSpec index
maps — no transpose is materialized.  For non-"nn" forward layouts (the
attention-logits ``nt`` contraction) the backward pair comes from
``_BWD_GEMMS`` — the same table, re-oriented.

Batched contractions (MoE expert einsums, attention score/value products,
im2col'd convs) ride the same machinery through a
:class:`repro.core.backend.QdotPlan`: the operands reshape (1-byte moves)
onto a ``(G, ., .)`` batched payload GEMM — broadcast-on-B shapes like
``becd,edf`` keep B stored once at ``Gb < G`` and dB accumulates the
``G // Gb`` broadcast groups in-kernel (``out_batch``).  The six-direction
StatsBank node and the payload residuals are shape-agnostic, so a batched
node costs exactly what a dense node costs in stats state.

Numerics anchor: ``dequantize(quantize(x, s)) == truncate(x, s)``
elementwise, so with shared (bank) stats the payload-domain forward equals
the Fig. 4 chain *bitwise* — asserted ref-vs-pallas in
tests/test_qdot_train.py (dense) and tests/test_qdot_batched.py (batched).
Stale bank stats saturate at the format max inside quantize and the
epilogue (never inf).

Stats lifecycle: inside a StatsBank session each ``qdot_train`` call is
one bank node with six per-direction states (statsbank.GEMM_DIRS); all
refreshes run under ``lax.cond`` on the session cadence, so steady-state
steps execute ZERO stats reductions and exactly three payload GEMMs +
three elementwise quantizations per node.  Outside a session the exact
path quantizes with fresh per-call stats (eval / ad-hoc callers);
discovery traces route through that same exact path, so step-0 numerics
match every later step (site registration still happens first).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import collectives
from repro.core import s2fp8
from repro.core import statsbank
from repro.core.backend import QdotPlan
from repro.kernels import flash_attention as _fkern

# Backward GEMM table: forward layout -> ((dA lhs, dA rhs, dA layout),
# (dB lhs, dB rhs, dB layout)) with operands named from {"a", "b", "g"}
# (saved payloads + quantized cotangent).  Derivation: transpose the
# forward contraction; every entry reads the stored payloads through
# index-map swaps only.
_BWD_GEMMS = {
    "nn": (("g", "b", "nt"), ("a", "g", "tn")),
    "nt": (("g", "b", "nn"), ("g", "a", "tn")),
    "tn": (("b", "g", "nt"), ("a", "g", "nn")),
}


def _qmm(be, qx, qy, layout, *, out_batch=None, epilogue_stats=None,
         fmt="e5m2"):
    """Rank dispatch: 2-D payloads -> ``qmatmul``, 3-D -> the batched
    kernel (``out_batch`` reduces broadcast groups, e.g. dB of a
    broadcast weight)."""
    if qx.payload.ndim == 2:
        return be.qmatmul(qx, qy, layout=layout,
                          epilogue_stats=epilogue_stats, fmt=fmt)
    return be.qmatmul_batched(qx, qy, layout=layout, out_batch=out_batch,
                              epilogue_stats=epilogue_stats, fmt=fmt)


def _epilogue_qmatmul(qa, qb, layout, st, pred_f, step_f, cfg, fmt,
                      backend, target_max, out_batch=None):
    """Sited payload GEMM with fused output truncation.

    Steady state (no refresh due): ONE kernel launch — the Eq. 5 epilogue
    runs on each accumulated output tile in VMEM with the site's carried
    (alpha, beta).  Refresh steps take the other ``lax.cond`` branch: raw
    GEMM, stats refresh from the raw output, elementwise truncate
    (refresh-then-use, same cadence semantics as ``Session.truncate``).
    Returns (y, new_state).
    """
    be = nbackend.get_backend(backend)
    need = jnp.logical_or(pred_f > 0, st["last"] < 0)

    def _refresh(_):
        y_raw = _qmm(be, qa, qb, layout, out_batch=out_batch, fmt=fmt)
        new = statsbank.refresh_state(
            y_raw, st, step_f, ema_decay=cfg.ema_decay,
            target_max=target_max, backend=backend, axis_name=cfg.axis_name, fmt=fmt)
        return be.truncate(y_raw, stats=(new["alpha"], new["beta"]),
                           fmt=fmt), new

    def _fused(_):
        y = _qmm(be, qa, qb, layout, out_batch=out_batch,
                 epilogue_stats=(st["alpha"], st["beta"]), fmt=fmt)
        return y, st

    return jax.lax.cond(need, _refresh, _fused, None)


def _gemm_structure(plan: Optional[QdotPlan]):
    """(fwd layout, dA/dB specs) for a plan; plan=None is the dense "nn"
    family.  Each backward spec is (lhs name, rhs name, layout,
    out_batch): out_batch reduces the broadcast groups when the
    differentiated operand is stored broadcast (Gb < G)."""
    layout = "nn" if plan is None else plan.layout
    (da_l, da_r, da_lay), (db_l, db_r, db_lay) = _BWD_GEMMS[layout]
    if plan is None or plan.batch == 1:
        a_ob = b_ob = None
    else:
        a_ob, b_ob = plan.batch, plan.b_batch
    return layout, (da_l, da_r, da_lay, a_ob), (db_l, db_r, db_lay, b_ob)


@functools.lru_cache(maxsize=None)
def _qdot_banked(backend: Optional[str], fmt: str, cfg: statsbank.StatsConfig,
                 plan: Optional[QdotPlan] = None,
                 fsdp: Optional[collectives.FSDPInfo] = None):
    """custom_vjp payload GEMM over (a2, b2, entry, pred_f, step_f); cached
    per (backend, fmt, cfg, plan, fsdp) so the callable is stable under
    jit tracing.  The bank entry is a differentiated argument whose
    cotangent is the refreshed entry (the StatsBank update idiom).

    With ``fsdp`` (the quantized-FSDP payload handoff), ``b`` is the
    owner's dim-0 SHARD of the logical B operand.  Its ``b.fwd`` stats
    refresh psums partials over ``cfg.axis_name`` exactly as in the
    replicated case — the shards partition the leaf over the fsdp axis,
    so the psum'd stats ARE the leaf-global (alpha, beta) and every owner
    quantizes coherently ("quantize-at-owner", once per refresh
    interval).  The 1-byte payload then all-gathers into the full GEMM B
    slot (never an f32/bf16 copy), and the backward reduce-scatters dB to
    the owner shard (psum over lead batch axes + psum_scatter over the
    fsdp axis; bf16 leg when the leaf routes compressed), so the b
    cotangent exits shard-shaped and pre-synced."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]
    layout, da_spec, db_spec = _gemm_structure(plan)

    def _fwd(a, b, entry, pred_f, step_f):
        be = nbackend.get_backend(backend)
        aa, ab, new_af = statsbank.maybe_refresh(
            a, entry["a.fwd"], pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        ba, bb, new_bf = statsbank.maybe_refresh(
            b, entry["b.fwd"], pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        qa = be.quantize(a, stats=(aa, ab), fmt=fmt)
        qb = be.quantize(b, stats=(ba, bb), fmt=fmt)
        if fsdp is not None:
            qb = collectives.payload_gather_axis(qb, fsdp.axis)
        y, new_of = _epilogue_qmatmul(qa, qb, layout, entry["out.fwd"],
                                      pred_f, step_f, cfg, fmt, backend,
                                      target_max)
        # Residuals: 1-byte payloads + scalar site states.  The f32
        # operands are NOT saved — asserted by shape inspection in
        # tests/test_qdot_train.py.
        res = (qa, qb, new_af, new_bf, new_of, entry["a.bwd"],
               entry["b.bwd"], entry["out.bwd"], pred_f, step_f)
        return y, res

    @jax.custom_vjp
    def qdot(a, b, entry, pred_f, step_f):
        return _fwd(a, b, entry, pred_f, step_f)[0]

    def _bwd(res, g):
        (qa, qb, new_af, new_bf, new_of, a_bwd, b_bwd, out_bwd,
         pred_f, step_f) = res
        be = nbackend.get_backend(backend)
        ga, gb, new_ob = statsbank.maybe_refresh(
            g, out_bwd, pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        qg = be.quantize(g, stats=(ga, gb), fmt=fmt)
        ops = {"a": qa, "b": qb, "g": qg}
        dl, dr, dlay, dob = da_spec
        dA, new_ab = _epilogue_qmatmul(ops[dl], ops[dr], dlay, a_bwd,
                                       pred_f, step_f, cfg, fmt, backend,
                                       target_max, out_batch=dob)
        dl, dr, dlay, dob = db_spec
        dB, new_bb = _epilogue_qmatmul(ops[dl], ops[dr], dlay, b_bwd,
                                       pred_f, step_f, cfg, fmt, backend,
                                       target_max, out_batch=dob)
        if fsdp is not None:
            # full local dB -> owner shard: psum lead batch axes +
            # reduce-scatter over the fsdp axis.  The b cotangent leaves
            # jax.grad pre-synced; the trainer's replicated grad sync
            # skips this leaf.
            dB = collectives.param_scatter_axis(dB, fsdp)
        entry_cot = {"a.fwd": new_af, "a.bwd": new_ab, "b.fwd": new_bf,
                     "b.bwd": new_bb, "out.fwd": new_of, "out.bwd": new_ob}
        return (dA, dB, entry_cot,
                jnp.zeros_like(pred_f), jnp.zeros_like(step_f))

    qdot.defvjp(_fwd, _bwd)
    qdot.fwd_impl = _fwd      # residual-inspection hook (tests)
    return qdot


@functools.lru_cache(maxsize=None)
def _qdot_frozen(backend: Optional[str], fmt: str,
                 plan: Optional[QdotPlan] = None):
    """Frozen-stats serving variant (forward-only, no VJP): operands
    quantize with (alpha, beta) re-derived from the exported bank entry's
    carried moments (:func:`statsbank.frozen_stats` — pure scalar
    arithmetic), and the output truncates through the fused Eq. 5
    epilogue with the out site's frozen stats.  ZERO stats reductions by
    construction — no ``maybe_refresh``, no ``lax.cond`` — which is the
    serving invariant the engine tests assert by jaxpr inspection."""
    layout, _, _ = _gemm_structure(plan)

    def qdot(a, b, entry):
        be = nbackend.get_backend(backend)
        qa = be.quantize(a, stats=statsbank.frozen_stats(entry["a.fwd"], fmt),
                         fmt=fmt)
        qb = be.quantize(b, stats=statsbank.frozen_stats(entry["b.fwd"], fmt),
                         fmt=fmt)
        return _qmm(be, qa, qb, layout,
                    epilogue_stats=statsbank.frozen_stats(entry["out.fwd"],
                                                          fmt),
                    fmt=fmt)

    return qdot


@functools.lru_cache(maxsize=None)
def _qdot_exact(backend: Optional[str], fmt: str,
                plan: Optional[QdotPlan] = None):
    """Sessionless variant: fresh exact stats per call (one reduction per
    tensor, like the exact-stats Fig. 4 chain) but still payload-domain
    compute and payload residuals."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]
    layout, da_spec, db_spec = _gemm_structure(plan)

    def _fwd(a, b):
        be = nbackend.get_backend(backend)
        qa = be.quantize(a, fmt=fmt)
        qb = be.quantize(b, fmt=fmt)
        y_raw = _qmm(be, qa, qb, layout, fmt=fmt)
        so = be.compute_stats(y_raw, fmt=fmt)
        return be.truncate(y_raw, stats=so, fmt=fmt), (qa, qb)

    @jax.custom_vjp
    def qdot(a, b):
        return _fwd(a, b)[0]

    def _bwd(res, g):
        qa, qb = res
        be = nbackend.get_backend(backend)
        qg = be.quantize(g, fmt=fmt)
        ops = {"a": qa, "b": qb, "g": qg}
        grads = []
        for dl, dr, dlay, dob in (da_spec, db_spec):
            d = _qmm(be, ops[dl], ops[dr], dlay, out_batch=dob, fmt=fmt)
            grads.append(be.truncate(d, stats=be.compute_stats(d, fmt=fmt),
                                     fmt=fmt))
        return tuple(grads)

    qdot.defvjp(_fwd, _bwd)
    qdot.fwd_impl = _fwd
    return qdot


def qdot_train(a: jnp.ndarray, b: jnp.ndarray, *,
               plan: Optional[QdotPlan] = None,
               backend: Optional[str] = None, fmt: str = "e5m2"
               ) -> jnp.ndarray:
    """Differentiable payload-domain contraction.

    Without ``plan``: the dense ``[..., K] x [K, N] -> [..., N]`` family
    (every MLP/projection GEMM).  With a :class:`QdotPlan` (from
    ``backend.plan_einsum`` / ``backend.plan_qdot_general``): any planned
    contraction, including batched and broadcast-on-B shapes — the
    operands reshape onto the plan's payload layout (1-byte moves after
    quantization; the f32 reshapes here are views).

    Inside a StatsBank session this is one GEMM bank node (six
    per-direction states, zero steady-state reductions); outside — and in
    discovery traces — exact per-call stats.  Returns f32 (the caller
    casts, matching ``Policy.dot``).

    ``b`` may be a :class:`repro.core.collectives.FSDPPayloadParam` (the
    quantized-FSDP handoff, dense family only): the local shard quantizes
    with leaf-global bank stats and all-gathers as a 1-byte payload into
    the GEMM B slot — no f32/bf16 copy of the leaf is ever materialized —
    and the b gradient exits reduce-scattered to the owner shard.
    Requires an active (non-discovery) session whose StatsConfig
    ``axis_name`` covers the fsdp axis (the leaf-global stats contract).
    """
    fsdp = None
    if isinstance(b, collectives.FSDPPayloadParam):
        if plan is not None:
            raise ValueError("FSDP payload operands support the dense "
                             "[..., K] x [K, N] family only (planned/"
                             "batched contractions coerce through the "
                             "f32 gather in Policy)")
        fsdp = b.info
        b = b.shard
        k_full = b.shape[0] * fsdp.axis_size
        if b.ndim != 2 or a.ndim < 1 or a.shape[-1] != k_full:
            raise ValueError(f"qdot_train wants [..., K] x [K, N]; got "
                             f"{a.shape} x FSDP shard {b.shape} "
                             f"(full K = {k_full})")
        out_shape = a.shape[:-1] + (b.shape[-1],)
        a2_shape, b2_shape = (-1, a.shape[-1]), b.shape
    elif plan is None:
        if b.ndim != 2 or a.ndim < 1 or a.shape[-1] != b.shape[0]:
            raise ValueError(f"qdot_train wants [..., K] x [K, N]; got "
                             f"{a.shape} x {b.shape}")
        out_shape = a.shape[:-1] + (b.shape[-1],)
        a2_shape, b2_shape = (-1, a.shape[-1]), b.shape
    else:
        out_shape = plan.out_shape
        a2_shape, b2_shape = plan.a2_shape, plan.b2_shape
    # f32 at the custom_vjp boundary: quantization is f32-in anyway, and
    # the casts' own VJPs return bf16 cotangents to bf16 callers
    a2 = a.reshape(a2_shape).astype(jnp.float32)
    b2 = b.reshape(b2_shape).astype(jnp.float32)
    sess = statsbank.current_session()
    if fsdp is not None:
        if sess is None or sess.discovery:
            raise ValueError(
                "FSDP payload operands need an active StatsBank session "
                "(make_train_step(param_sharding='fsdp_q', stats=...)); "
                "discovery traces see full unwrapped params")
        axes = sess.cfg.axis_name
        axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
        if fsdp.axis not in axes:
            raise ValueError(
                f"fsdp_q needs leaf-global stats: StatsConfig.axis_name "
                f"{axes!r} must include the fsdp axis {fsdp.axis!r}")
    if sess is None:
        y2 = _qdot_exact(backend, fmt, plan)(a2, b2)
    elif sess.discovery:
        # register the bank node, then run the SAME exact payload path a
        # sessionless call takes — step-0 (discovery-traced) numerics
        # match every later step instead of a raw untruncated f32 dot
        sess.qdot_site()
        y2 = _qdot_exact(backend, fmt, plan)(a2, b2)
    elif sess.frozen:
        # serving: frozen export-time stats, forward-only, zero reductions
        if fsdp is not None:
            raise ValueError("FSDP payload operands are a training-path "
                             "feature; frozen serving sessions see "
                             "replicated params")
        entry = sess.qdot_site()
        y2 = _qdot_frozen(backend, fmt, plan)(a2, b2, entry)
    else:
        entry = sess.qdot_site()
        y2 = _qdot_banked(backend, fmt, sess.cfg, plan, fsdp)(
            a2, b2, entry, sess.pred_f, sess.step_f)
    return y2.reshape(out_shape)


# ===========================================================================
# qflash_attention: the differentiable payload-domain flash attention node
# ===========================================================================
#
# Same contract as qdot_train, fused across the whole attention op: the
# forward consumes 1-byte Q/K/V payloads, keeps the [S, S] score/prob
# tensor in VMEM tiles only (never HBM), and applies the Eq. 5 epilogue to
# the output tile with the out site's bank stats.  The backward is the
# flash recompute schedule over PAYLOAD residuals: only the 1-byte
# Q/K/V/out payloads plus the rowwise logsumexp are saved, and the score
# tiles are rebuilt from the payloads — so attention residuals are
# ~4x smaller than the Fig. 4 flash chain's four truncated-f32 tensors,
# on top of the O(S^2) -> O(S) flash residual cut itself.


def _payload_flash_fwd(be, qq, qk, qv, causal, window, fmt, bq, bk,
                       out_stats):
    """Raw payload flash forward -> (out f32, lse [B,KV,G,Sq,1]).

    Pallas backend: the fused kernel (epilogue truncation in VMEM when
    ``out_stats`` is given).  Ref backend: dequantize + the pure-jnp
    grouped flash reference, then an elementwise truncate — same numerics
    by the truncate == dequant(quant) anchor.
    """
    b, kvh, g, sq, d = qq.payload.shape
    sk = qk.payload.shape[2]
    scale = 1.0 / math.sqrt(d)
    if isinstance(be, nbackend.PallasBackend):
        out, lse = _fkern.qflash_fwd_pallas(
            qq.payload.reshape(b * kvh * g, sq, d),
            qk.payload.reshape(b * kvh, sk, d),
            qv.payload.reshape(b * kvh, sk, d),
            (qq.alpha, qq.beta), (qk.alpha, qk.beta), (qv.alpha, qv.beta),
            g=g, causal=causal, window=window, scale=scale,
            out_stats=out_stats, fmt=fmt, bq=bq, bk=bk)
        return (out.reshape(b, kvh, g, sq, d),
                lse.reshape(b, kvh, g, sq, 1))
    out, lse = _fkern.flash_fwd_reference(
        be.dequantize(qq), be.dequantize(qk), be.dequantize(qv),
        causal=causal, window=window, q_chunk=bq, kv_chunk=bk)
    if out_stats is not None:
        out = be.truncate(out, stats=out_stats, fmt=fmt)
    return out, lse


def _payload_flash_bwd(be, qq, qk, qv, qg, lse, delta, causal, window,
                       fmt, bq, bk):
    """Raw payload flash backward -> (dq, dk, dv) f32, grouped layout.

    Score tiles are recomputed from the 1-byte payloads.  The pallas path
    runs the two-kernel schedule (dq; per-head dk/dv) and reduces the
    query-group axis here; the ref path is the pure-jnp recompute
    reference on dequantized payloads.
    """
    b, kvh, g, sq, d = qq.payload.shape
    sk = qk.payload.shape[2]
    scale = 1.0 / math.sqrt(d)
    if isinstance(be, nbackend.PallasBackend):
        dq, dkh, dvh = _fkern.qflash_bwd_pallas(
            qq.payload.reshape(b * kvh * g, sq, d),
            qk.payload.reshape(b * kvh, sk, d),
            qv.payload.reshape(b * kvh, sk, d),
            qg.payload.reshape(b * kvh * g, sq, d),
            (qq.alpha, qq.beta), (qk.alpha, qk.beta), (qv.alpha, qv.beta),
            (qg.alpha, qg.beta),
            lse.reshape(b * kvh * g, sq), delta.reshape(b * kvh * g, sq),
            g=g, causal=causal, window=window, scale=scale, bq=bq, bk=bk)
        dq = dq.reshape(b, kvh, g, sq, d)
        # the kernel emits per-head dk/dv (each output block written once);
        # the grouped-query reduction over g happens here
        dk = dkh.reshape(b, kvh, g, sk, d).sum(axis=2)
        dv = dvh.reshape(b, kvh, g, sk, d).sum(axis=2)
        return dq, dk, dv
    return _fkern.flash_bwd_reference(
        be.dequantize(qq), be.dequantize(qk), be.dequantize(qv),
        be.dequantize(qg), lse, delta,
        causal=causal, window=window, q_chunk=bq, kv_chunk=bk)


@functools.lru_cache(maxsize=None)
def _qflash_banked(backend: Optional[str], fmt: str,
                   cfg: statsbank.StatsConfig, causal: bool,
                   window: Optional[int], bq: int, bk: int):
    """custom_vjp payload flash attention over (q, k, v, entry, pred_f,
    step_f).  ``entry`` is one statsbank.FLASH_DIRS bank node; its
    cotangent is the refreshed entry (the StatsBank update idiom)."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]

    def _fwd(q, k, v, entry, pred_f, step_f):
        be = nbackend.get_backend(backend)
        qa, qb_, new_qf = statsbank.maybe_refresh(
            q, entry["q.fwd"], pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        ka, kb, new_kf = statsbank.maybe_refresh(
            k, entry["k.fwd"], pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        va, vb, new_vf = statsbank.maybe_refresh(
            v, entry["v.fwd"], pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        qq = be.quantize(q, stats=(qa, qb_), fmt=fmt)
        qk = be.quantize(k, stats=(ka, kb), fmt=fmt)
        qv = be.quantize(v, stats=(va, vb), fmt=fmt)

        st = entry["out.fwd"]
        need = jnp.logical_or(pred_f > 0, st["last"] < 0)

        def _refresh(_):
            raw, lse = _payload_flash_fwd(be, qq, qk, qv, causal, window,
                                          fmt, bq, bk, None)
            new = statsbank.refresh_state(
                raw, st, step_f, ema_decay=cfg.ema_decay,
                target_max=target_max, backend=backend,
                axis_name=cfg.axis_name, fmt=fmt)
            out = be.truncate(raw, stats=(new["alpha"], new["beta"]),
                              fmt=fmt)
            return out, lse, new["alpha"], new["beta"], new

        def _fused(_):
            out, lse = _payload_flash_fwd(be, qq, qk, qv, causal, window,
                                          fmt, bq, bk,
                                          (st["alpha"], st["beta"]))
            return out, lse, st["alpha"], st["beta"], st

        out, lse, oa, ob, new_of = jax.lax.cond(need, _refresh, _fused, None)
        # `out` is already in the out site's representable set, so this
        # quantization is its exact 1-byte payload — the residual the
        # backward dequantizes for the delta identity.
        qo = be.quantize(out, stats=(oa, ob), fmt=fmt)
        res = (qq, qk, qv, qo, lse, new_qf, new_kf, new_vf, new_of,
               entry["q.bwd"], entry["k.bwd"], entry["v.bwd"],
               entry["out.bwd"], pred_f, step_f)
        return out, res

    @jax.custom_vjp
    def qflash(q, k, v, entry, pred_f, step_f):
        return _fwd(q, k, v, entry, pred_f, step_f)[0]

    def _bwd(res, g):
        (qq, qk, qv, qo, lse, new_qf, new_kf, new_vf, new_of,
         q_bwd, k_bwd, v_bwd, out_bwd, pred_f, step_f) = res
        be = nbackend.get_backend(backend)
        g = g.astype(jnp.float32)
        ga, gb, new_ob = statsbank.maybe_refresh(
            g, out_bwd, pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        qg = be.quantize(g, stats=(ga, gb), fmt=fmt)
        # flash-2 rowwise identity D = sum(dout * out) on the dequantized
        # payloads — the backward's single algorithmic reduction.
        delta = jnp.sum(be.dequantize(qg) * be.dequantize(qo),
                        axis=-1, keepdims=True)
        dq_raw, dk_raw, dv_raw = _payload_flash_bwd(
            be, qq, qk, qv, qg, lse, delta, causal, window, fmt, bq, bk)
        a, b, new_qb = statsbank.maybe_refresh(
            dq_raw, q_bwd, pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        dq = be.truncate(dq_raw, stats=(a, b), fmt=fmt)
        a, b, new_kb = statsbank.maybe_refresh(
            dk_raw, k_bwd, pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        dk = be.truncate(dk_raw, stats=(a, b), fmt=fmt)
        a, b, new_vb = statsbank.maybe_refresh(
            dv_raw, v_bwd, pred_f, step_f, cfg, target_max, backend, fmt=fmt)
        dv = be.truncate(dv_raw, stats=(a, b), fmt=fmt)
        entry_cot = {"q.fwd": new_qf, "q.bwd": new_qb,
                     "k.fwd": new_kf, "k.bwd": new_kb,
                     "v.fwd": new_vf, "v.bwd": new_vb,
                     "out.fwd": new_of, "out.bwd": new_ob}
        return (dq, dk, dv, entry_cot,
                jnp.zeros_like(pred_f), jnp.zeros_like(step_f))

    qflash.defvjp(_fwd, _bwd)
    qflash.fwd_impl = _fwd      # residual-inspection hook (tests)
    return qflash


@functools.lru_cache(maxsize=None)
def _qflash_frozen(backend: Optional[str], fmt: str, causal: bool,
                   window: Optional[int], bq: int, bk: int):
    """Frozen-stats serving flash attention (forward-only, mirrors
    ``_qdot_frozen``): Q/K/V quantize with the exported bank node's frozen
    stats, the fused kernel truncates the output tile with the frozen out
    stats — zero stats reductions (the softmax's own rowwise max/sum are
    algorithmic, present in the fp32 baseline too)."""

    def qflash(q, k, v, entry):
        be = nbackend.get_backend(backend)
        qq = be.quantize(q, stats=statsbank.frozen_stats(entry["q.fwd"], fmt),
                         fmt=fmt)
        qk = be.quantize(k, stats=statsbank.frozen_stats(entry["k.fwd"], fmt),
                         fmt=fmt)
        qv = be.quantize(v, stats=statsbank.frozen_stats(entry["v.fwd"], fmt),
                         fmt=fmt)
        out, _ = _payload_flash_fwd(
            be, qq, qk, qv, causal, window, fmt, bq, bk,
            statsbank.frozen_stats(entry["out.fwd"], fmt))
        return out

    return qflash


@functools.lru_cache(maxsize=None)
def _qflash_exact(backend: Optional[str], fmt: str, causal: bool,
                  window: Optional[int], bq: int, bk: int):
    """Sessionless variant: fresh exact stats per call, payload-domain
    compute and payload residuals (mirrors ``_qdot_exact``)."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]

    def _fwd(q, k, v):
        be = nbackend.get_backend(backend)
        qq = be.quantize(q, fmt=fmt)
        qk = be.quantize(k, fmt=fmt)
        qv = be.quantize(v, fmt=fmt)
        raw, lse = _payload_flash_fwd(be, qq, qk, qv, causal, window, fmt,
                                      bq, bk, None)
        so = be.compute_stats(raw, fmt=fmt)
        out = be.truncate(raw, stats=so, fmt=fmt)
        qo = be.quantize(out, stats=so, fmt=fmt)
        return out, (qq, qk, qv, qo, lse)

    @jax.custom_vjp
    def qflash(q, k, v):
        return _fwd(q, k, v)[0]

    def _bwd(res, g):
        qq, qk, qv, qo, lse = res
        be = nbackend.get_backend(backend)
        g = g.astype(jnp.float32)
        qg = be.quantize(g, fmt=fmt)
        delta = jnp.sum(be.dequantize(qg) * be.dequantize(qo),
                        axis=-1, keepdims=True)
        raws = _payload_flash_bwd(be, qq, qk, qv, qg, lse, delta, causal,
                                  window, fmt, bq, bk)
        return tuple(be.truncate(d, stats=be.compute_stats(d, fmt=fmt),
                                 fmt=fmt) for d in raws)

    qflash.defvjp(_fwd, _bwd)
    qflash.fwd_impl = _fwd
    return qflash


def qflash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     causal: bool = True, window: Optional[int] = None,
                     backend: Optional[str] = None, fmt: str = "e5m2",
                     q_chunk: int = 512, kv_chunk: int = 512
                     ) -> jnp.ndarray:
    """Differentiable payload-domain flash attention.

    Layout matches models/flash.py: q ``[B, KV, G, Sq, d]``,
    k/v ``[B, KV, Sk, d]`` (grouped-query).  Inside a StatsBank session
    this is ONE bank node (eight per-direction states,
    statsbank.FLASH_DIRS) with zero steady-state stats reductions;
    outside — and in discovery traces — exact per-call stats.  Returns
    f32 (the caller casts, matching ``Policy`` conventions).
    """
    if q.ndim != 5 or k.ndim != 4 or v.ndim != 4:
        raise ValueError(f"qflash_attention wants q [B,KV,G,Sq,d], "
                         f"k/v [B,KV,Sk,d]; got {q.shape}, {k.shape}, "
                         f"{v.shape}")
    if (k.shape != v.shape or q.shape[:2] != k.shape[:2]
            or q.shape[-1] != k.shape[-1]):
        raise ValueError(f"inconsistent attention shapes: {q.shape}, "
                         f"{k.shape}, {v.shape}")
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    window = None if window is None else int(window)
    sess = statsbank.current_session()
    if sess is None:
        return _qflash_exact(backend, fmt, causal, window,
                             q_chunk, kv_chunk)(q, k, v)
    if sess.discovery:
        # register the bank node, then run the exact payload path so
        # step-0 (discovery-traced) numerics match later steps
        sess.qflash_site()
        return _qflash_exact(backend, fmt, causal, window,
                             q_chunk, kv_chunk)(q, k, v)
    if sess.frozen:
        # serving: frozen export-time stats, forward-only, zero reductions
        entry = sess.qflash_site()
        return _qflash_frozen(backend, fmt, causal, window,
                              q_chunk, kv_chunk)(q, k, v, entry)
    entry = sess.qflash_site()
    return _qflash_banked(backend, fmt, sess.cfg, causal, window,
                          q_chunk, kv_chunk)(q, k, v, entry,
                                             sess.pred_f, sess.step_f)
