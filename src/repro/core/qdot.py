"""qdot_train: the differentiable payload-domain training GEMM.

The paper's §5 tensor processing engine consumes FP8 payloads plus
(alpha, beta) directly; this module makes that the *training* execution of
``Policy.dot`` (and friends) instead of the composed Fig. 4 chain of three
f32-in/f32-out truncation passes around an f32 GEMM.

Forward::

    qA = quantize(A, bank stats)      # elementwise, 1-byte HBM write
    qB = quantize(B, bank stats)
    Y  = qmatmul(qA, qB, epilogue_stats=out-site stats)
         #  payload tiles stream HBM->VMEM at 1 B/elt, dequant on the VPU,
         #  f32 MXU accumulation, Eq. 5 epilogue on the output tile in VMEM

and the residuals saved for backward are the *payloads* plus scalar stats
— a ~4x activation-residual cut vs the Fig. 4 chain's truncated-f32
operands.

Backward (paper Fig. 4's two transposed GEMMs, payload-domain)::

    qG = quantize(g, cotangent-site stats)        # truncate+store, 1 pass
    dA = qmatmul(qG, qB, layout="nt", epilogue_stats=a-site bwd stats)
    dB = qmatmul(qA, qG, layout="tn", epilogue_stats=b-site bwd stats)

The NT/TN layouts read the saved payloads through swapped BlockSpec index
maps — no transpose is materialized.

Numerics anchor: ``dequantize(quantize(x, s)) == truncate(x, s)``
elementwise, so with shared (bank) stats the payload-domain forward equals
the Fig. 4 chain *bitwise* — asserted ref-vs-pallas in
tests/test_qdot_train.py.  Stale bank stats saturate at the format max
inside quantize and the epilogue (never inf).

Stats lifecycle: inside a StatsBank session each ``qdot_train`` call is
one bank node with six per-direction states (statsbank.GEMM_DIRS); all
refreshes run under ``lax.cond`` on the session cadence, so steady-state
steps execute ZERO stats reductions and exactly three payload GEMMs +
three elementwise quantizations per node.  Outside a session the exact
path quantizes with fresh per-call stats (eval / ad-hoc callers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import s2fp8
from repro.core import statsbank


def _epilogue_qmatmul(qa, qb, layout, st, pred_f, step_f, cfg, fmt,
                      backend, target_max):
    """Sited payload GEMM with fused output truncation.

    Steady state (no refresh due): ONE kernel launch — the Eq. 5 epilogue
    runs on each accumulated output tile in VMEM with the site's carried
    (alpha, beta).  Refresh steps take the other ``lax.cond`` branch: raw
    GEMM, stats refresh from the raw output, elementwise truncate
    (refresh-then-use, same cadence semantics as ``Session.truncate``).
    Returns (y, new_state).
    """
    be = nbackend.get_backend(backend)
    need = jnp.logical_or(pred_f > 0, st["last"] < 0)

    def _refresh(_):
        y_raw = be.qmatmul(qa, qb, layout=layout)
        new = statsbank.refresh_state(
            y_raw, st, step_f, ema_decay=cfg.ema_decay,
            target_max=target_max, backend=backend, axis_name=cfg.axis_name)
        return be.truncate(y_raw, stats=(new["alpha"], new["beta"]),
                           fmt=fmt), new

    def _fused(_):
        y = be.qmatmul(qa, qb, layout=layout,
                       epilogue_stats=(st["alpha"], st["beta"]), fmt=fmt)
        return y, st

    return jax.lax.cond(need, _refresh, _fused, None)


@functools.lru_cache(maxsize=None)
def _qdot_banked(backend: Optional[str], fmt: str, cfg: statsbank.StatsConfig):
    """custom_vjp payload GEMM over (a2, b, entry, pred_f, step_f); cached
    per (backend, fmt, cfg) so the callable is stable under jit tracing.
    The bank entry is a differentiated argument whose cotangent is the
    refreshed entry (the StatsBank update idiom)."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]

    def _fwd(a, b, entry, pred_f, step_f):
        be = nbackend.get_backend(backend)
        aa, ab, new_af = statsbank.maybe_refresh(
            a, entry["a.fwd"], pred_f, step_f, cfg, target_max, backend)
        ba, bb, new_bf = statsbank.maybe_refresh(
            b, entry["b.fwd"], pred_f, step_f, cfg, target_max, backend)
        qa = be.quantize(a, stats=(aa, ab), fmt=fmt)
        qb = be.quantize(b, stats=(ba, bb), fmt=fmt)
        y, new_of = _epilogue_qmatmul(qa, qb, "nn", entry["out.fwd"],
                                      pred_f, step_f, cfg, fmt, backend,
                                      target_max)
        # Residuals: 1-byte payloads + scalar site states.  The f32
        # operands are NOT saved — asserted by shape inspection in
        # tests/test_qdot_train.py.
        res = (qa, qb, new_af, new_bf, new_of, entry["a.bwd"],
               entry["b.bwd"], entry["out.bwd"], pred_f, step_f)
        return y, res

    @jax.custom_vjp
    def qdot(a, b, entry, pred_f, step_f):
        return _fwd(a, b, entry, pred_f, step_f)[0]

    def _bwd(res, g):
        (qa, qb, new_af, new_bf, new_of, a_bwd, b_bwd, out_bwd,
         pred_f, step_f) = res
        be = nbackend.get_backend(backend)
        ga, gb, new_ob = statsbank.maybe_refresh(
            g, out_bwd, pred_f, step_f, cfg, target_max, backend)
        qg = be.quantize(g, stats=(ga, gb), fmt=fmt)
        dA, new_ab = _epilogue_qmatmul(qg, qb, "nt", a_bwd, pred_f, step_f,
                                       cfg, fmt, backend, target_max)
        dB, new_bb = _epilogue_qmatmul(qa, qg, "tn", b_bwd, pred_f, step_f,
                                       cfg, fmt, backend, target_max)
        entry_cot = {"a.fwd": new_af, "a.bwd": new_ab, "b.fwd": new_bf,
                     "b.bwd": new_bb, "out.fwd": new_of, "out.bwd": new_ob}
        return (dA, dB, entry_cot,
                jnp.zeros_like(pred_f), jnp.zeros_like(step_f))

    qdot.defvjp(_fwd, _bwd)
    qdot.fwd_impl = _fwd      # residual-inspection hook (tests)
    return qdot


@functools.lru_cache(maxsize=None)
def _qdot_exact(backend: Optional[str], fmt: str):
    """Sessionless variant: fresh exact stats per call (one reduction per
    tensor, like the exact-stats Fig. 4 chain) but still payload-domain
    compute and payload residuals."""
    target_max = s2fp8.FMT_TARGET_MAX[fmt]

    def _fwd(a, b):
        be = nbackend.get_backend(backend)
        qa = be.quantize(a, fmt=fmt)
        qb = be.quantize(b, fmt=fmt)
        y_raw = be.qmatmul(qa, qb)
        so = be.compute_stats(y_raw, fmt=fmt)
        return be.truncate(y_raw, stats=so, fmt=fmt), (qa, qb)

    @jax.custom_vjp
    def qdot(a, b):
        return _fwd(a, b)[0]

    def _bwd(res, g):
        qa, qb = res
        be = nbackend.get_backend(backend)
        qg = be.quantize(g, fmt=fmt)
        dA = be.qmatmul(qg, qb, layout="nt")
        dA = be.truncate(dA, stats=be.compute_stats(dA, fmt=fmt), fmt=fmt)
        dB = be.qmatmul(qa, qg, layout="tn")
        dB = be.truncate(dB, stats=be.compute_stats(dB, fmt=fmt), fmt=fmt)
        return dA, dB

    qdot.defvjp(_fwd, _bwd)
    qdot.fwd_impl = _fwd
    return qdot


def qdot_train(a: jnp.ndarray, b: jnp.ndarray, *,
               backend: Optional[str] = None, fmt: str = "e5m2"
               ) -> jnp.ndarray:
    """Differentiable payload-domain GEMM: ``[..., K] x [K, N] -> [..., N]``.

    Inside a StatsBank session this is one GEMM bank node (six
    per-direction states, zero steady-state reductions); outside, exact
    per-call stats.  Returns f32 (the caller casts, matching
    ``Policy.dot``).
    """
    if b.ndim != 2 or a.ndim < 1 or a.shape[-1] != b.shape[0]:
        raise ValueError(f"qdot_train wants [..., K] x [K, N]; got "
                         f"{a.shape} x {b.shape}")
    out_shape = a.shape[:-1] + (b.shape[-1],)
    # f32 at the custom_vjp boundary: quantization is f32-in anyway, and
    # the casts' own VJPs return bf16 cotangents to bf16 callers
    a2 = a.reshape(-1, a.shape[-1]).astype(jnp.float32)
    b = b.astype(jnp.float32)
    sess = statsbank.current_session()
    if sess is None:
        y2 = _qdot_exact(backend, fmt)(a2, b)
    elif sess.discovery:
        sess.qdot_site()
        y2 = jnp.dot(a2.astype(jnp.float32), b.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    else:
        entry = sess.qdot_site()
        y2 = _qdot_banked(backend, fmt, sess.cfg)(
            a2, b, entry, sess.pred_f, sess.step_f)
    return y2.reshape(out_shape)
