"""Plain FP8 (1/5/2 a.k.a. e5m2) helpers — the paper's baseline format.

The paper's FP8 is IEEE-style 1 sign / 5 exponent / 2 mantissa with denormals
and RNE rounding (Table A1): normal range [2^-14, (1-2^-3)*2^16], denormals
down to 2^-16, machine epsilon 2^-3.  That is bit-identical to ml_dtypes'
``float8_e5m2``, which JAX exposes as ``jnp.float8_e5m2``; ``astype`` performs
round-to-nearest-even.

We also expose e4m3 for the mixed-format ablation (not used by the paper).
"""
from __future__ import annotations

import jax.numpy as jnp

# Format constants (paper Table A1 / Figure A1).
E5M2_MAX = 57344.0          # (1 - 2**-3) * 2**16
E5M2_MIN_NORMAL = 2.0 ** -14
E5M2_MIN_SUBNORMAL = 2.0 ** -16
E4M3_MAX = 448.0


def truncate_e5m2(x: jnp.ndarray) -> jnp.ndarray:
    """RNE-truncate to FP8 e5m2 and return in the input's float dtype.

    Overflow goes to +-inf in e5m2; the paper's S2FP8 construction guarantees
    |Y| <= 2^15 so saturation never triggers post-transform, but raw FP8
    baselines *do* overflow — that divergence is part of the reproduction, so
    we intentionally do not clamp here.
    """
    return x.astype(jnp.float8_e5m2).astype(x.dtype)


def truncate_e4m3(x: jnp.ndarray) -> jnp.ndarray:
    """RNE-truncate to FP8 e4m3 (ablation format)."""
    return x.astype(jnp.float8_e4m3fn).astype(x.dtype)


def cast_e5m2(x: jnp.ndarray) -> jnp.ndarray:
    """Cast to the 1-byte payload dtype (storage, not simulation)."""
    return x.astype(jnp.float8_e5m2)
