"""Numerics-backend registry: one interface, swappable engines.

Every S2FP8 operation the framework performs — stats, quantize, dequantize,
the Eq. 5 truncation that ``Policy`` wraps around each GEMM, and the
payload-domain GEMM (``qmatmul``: NN/NT/TN operand layouts, optional fused
Eq. 5 output epilogue, e5m2/e4m3 payloads; ``qdot_general`` maps restricted
higher-rank contractions onto it) — goes through a
:class:`NumericsBackend`.  Two engines ship:

  * ``"ref"``    — the pure-jnp implementation in core/s2fp8.py (today's
    semantics, the semantic ground truth, and the fast CPU path);
  * ``"pallas"`` — the fused Pallas kernels in kernels/ via the
    shape-generalizing dispatch layer (kernels/dispatch.py).  Its default
    stats mode computes (alpha, beta) with the same monolithic reduction
    the ref uses and fuses apply->FP8-RNE->inverse into one elementwise
    kernel — bitwise-identical outputs, two HBM passes instead of five.
    ``PallasBackend(stats_mode="fused")`` moves the stats reduction
    in-kernel as well (single two-phase ``pallas_call``; float-tolerance
    parity).

``"auto"`` resolves to ``"pallas"`` on TPU and ``"ref"`` elsewhere; the
``Policy`` dataclass carries the selection (core/policy.py), the launchers
expose it as ``--backend``, and ArchConfig carries a per-arch default.

Delayed-stats mode: every backend's ``truncate`` and ``quantize`` accept
precomputed ``stats=(alpha, beta)``.  The StatsBank subsystem
(core/statsbank.py) is the first-class consumer: a jit-carried, sharded,
checkpointable bank of per-site stats refreshed every k steps inside the
train step, plus ``HostStatsBank`` for eager callers (serving, checkpoint
compression).  :func:`truncate_delayed` remains the low-level functional
hook, and :class:`DelayedStatsCache` is a deprecated shim over the host
bank.  Tensor distributions drift slowly between adjacent steps (the
premise behind amortized scaling in FP8 training recipes), so stale-by-k
stats cost little accuracy while removing the stats reduction — the only
non-elementwise pass — from the hot loop.

Stats locality is explicit: ``compute_stats(x)`` reduces over the tensor
the caller holds (per-shard inside ``shard_map``), while
``compute_stats(x, axis_name=...)`` all-reduces the raw
``compute_stats_partials`` triplet for exact global stats.
"""
from __future__ import annotations

import functools
import string
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import s2fp8
from repro.core.s2fp8 import S2FP8Tensor

_TARGET_MAX = s2fp8.FMT_TARGET_MAX


def all_reduce_stats_partials(partials, axis_name: str):
    """Combine per-shard (log_sum, log_max, count) stats partials across a
    mapped/shard_map axis: sums and counts add, maxes max.  This is the one
    place global-stats semantics live — every caller (backend
    ``compute_stats(axis_name=...)``, the StatsBank refresh) reduces the
    same triplet, so global stats are exact, not shard-averaged."""
    log_sum, log_max, count = partials
    return (jax.lax.psum(log_sum, axis_name),
            jax.lax.pmax(log_max, axis_name),
            jax.lax.psum(count, axis_name))


class NumericsBackend:
    """Interface every numerics engine implements.

    ``stats`` arguments/returns are (alpha, beta) f32 scalar pairs;
    ``fmt`` selects the payload format ("e5m2" — the paper's — or "e4m3").

    Stats semantics are explicit: ``compute_stats(x)`` reduces over the
    tensor the caller holds (LOCAL — inside a ``shard_map`` body that is
    the shard); ``compute_stats(x, axis_name=...)`` all-reduces the raw
    partials across that mesh axis first (GLOBAL — every shard gets the
    stats of the logical tensor).  ``compute_stats_partials`` exposes the
    raw (sum, max, count) triplet for callers that combine shards
    themselves (the StatsBank refresh).
    """

    name = "abstract"

    def compute_stats(self, x: jnp.ndarray, *, fmt: str = "e5m2",
                      axis_name: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def compute_stats_partials(self, x: jnp.ndarray
                               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def quantize(self, x: jnp.ndarray, *, stats=None,
                 fmt: str = "e5m2") -> S2FP8Tensor:
        raise NotImplementedError

    def dequantize(self, t: S2FP8Tensor, dtype=jnp.float32) -> jnp.ndarray:
        raise NotImplementedError

    def truncate(self, x: jnp.ndarray, *, stats=None,
                 fmt: str = "e5m2") -> jnp.ndarray:
        raise NotImplementedError

    def qmatmul(self, a: S2FP8Tensor, b: S2FP8Tensor, *, layout: str = "nn",
                epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
        """Payload-domain GEMM on 2-D payloads.

        ``layout`` selects transposed operand consumption ("nn"/"nt"/"tn",
        kernels/ref.py ``GEMM_CONTRACT``) — the backward GEMMs of
        core/qdot.py read the forward's saved payloads without
        materializing a transpose.  ``epilogue_stats=(alpha, beta)`` fuses
        the output site's Eq. 5 truncation into the GEMM epilogue
        (``fmt`` = the truncation's payload format)."""
        raise NotImplementedError

    def qmatmul_batched(self, a: S2FP8Tensor, b: S2FP8Tensor, *,
                        layout: str = "nn", out_batch: Optional[int] = None,
                        epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
        """Batched payload-domain GEMM on 3-D payloads.

        ``a.payload`` is ``[Ga, ...]``, ``b.payload`` ``[Gb, ...]`` with a
        per-slice shape per ``layout`` (kernels/ref.py ``gemm_dims`` on the
        trailing two dims).  The combined batch is ``G = max(Ga, Gb)``;
        ``Ga`` and ``Gb`` must divide it, and the slice an operand
        contributes to combined step ``g`` is ``g % Gx`` — the
        trailing-aligned broadcast the MoE broadcast-on-B shapes
        (``becd,edf``) flatten to.  ``out_batch`` (default ``G``) < ``G``
        sums groups of ``G // out_batch`` adjacent-in-``g // out_batch``
        slices into one output slice — the weight-gradient reduction of a
        broadcast operand.  ``epilogue_stats`` fuses the output-site Eq. 5
        truncation exactly as in :meth:`qmatmul`."""
        raise NotImplementedError

    def qdot_general(self, a: S2FP8Tensor, b: S2FP8Tensor, dimension_numbers,
                     *, epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
        """General-rank payload-domain contraction.

        Maps a ``lax.dot_general``-style contraction — single contracting
        dim at the boundary of each operand's free dims, batch dims (if
        any) leading and in order — onto the 2-D ``qmatmul`` or the
        batched ``qmatmul_batched`` via payload reshapes (1-byte moves).
        Raises ``ValueError`` for contractions outside that family;
        callers gate on :func:`qdot_general_supported`."""
        plan = plan_qdot_general(a.shape, b.shape, dimension_numbers)
        if plan is None:
            raise ValueError(
                f"qdot_general cannot map dimension_numbers "
                f"{dimension_numbers} on {a.shape} x {b.shape} onto a "
                f"payload GEMM; gate with qdot_general_supported()")
        return execute_qdot_plan(self, plan, a, b,
                                 epilogue_stats=epilogue_stats, fmt=fmt)

    def __repr__(self):
        return f"<NumericsBackend {self.name!r}>"


class QdotPlan(NamedTuple):
    """How one contraction maps onto the payload GEMM kernels.

    The first four fields keep the PR-3 tuple layout (layout, operand
    reshape targets, final output shape); ``batch`` / ``b_batch`` carry
    the batched extension.  ``batch == 1`` is a plain 2-D GEMM (the shapes
    are 2-D); ``batch > 1`` makes ``a2_shape`` a full-combined-batch 3-D
    ``(G, ., .)`` and ``b2_shape`` a ``(Gb, ., .)`` with ``Gb | G`` —
    ``Gb < G`` broadcasts B across the leading ``G // Gb`` groups (the
    ``becd,edf`` family)."""

    layout: str
    a2_shape: Tuple[int, ...]
    b2_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    batch: int = 1
    b_batch: int = 1


def execute_qdot_plan(backend_obj: NumericsBackend, plan: QdotPlan,
                      a: S2FP8Tensor, b: S2FP8Tensor, *,
                      epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
    """Run a planned contraction on quantized operands: payload reshapes
    (1-byte moves), then the 2-D or batched kernel, then the output
    reshape."""
    qa, qb = a.reshape(plan.a2_shape), b.reshape(plan.b2_shape)
    if plan.batch == 1:
        y = backend_obj.qmatmul(qa, qb, layout=plan.layout,
                                epilogue_stats=epilogue_stats, fmt=fmt)
    else:
        y = backend_obj.qmatmul_batched(qa, qb, layout=plan.layout,
                                        epilogue_stats=epilogue_stats,
                                        fmt=fmt)
    return y.reshape(plan.out_shape)


def _prod(dims) -> int:
    p = 1
    for d in dims:
        p *= d
    return p


def _plan_from_parts(layout: str, batch_dims, b_batch_dims, m: int, k: int,
                     n: int, out_shape) -> Optional[QdotPlan]:
    """Assemble a QdotPlan from the decomposed contraction: combined batch
    dims (all of A's leading dims), B's stored batch dims (a trailing
    subset), per-slice (m, k, n), and the logical output shape."""
    g, gb = _prod(batch_dims), _prod(b_batch_dims)
    if 0 in (g, gb, m, k, n):
        return None                      # degenerate sizes: no kernel path
    if layout == "nn":
        a2, b2 = (m, k), (k, n)
    elif layout == "nt":
        a2, b2 = (m, k), (n, k)
    elif layout == "tn":
        a2, b2 = (k, m), (k, n)
    else:
        return None
    if g == 1:
        return QdotPlan(layout, a2, b2, tuple(out_shape))
    return QdotPlan(layout, (g,) + a2, (gb,) + b2, tuple(out_shape), g, gb)


def plan_qdot_general(a_shape, b_shape, dimension_numbers
                      ) -> Optional[QdotPlan]:
    """Map a dot_general onto a payload GEMM, or None when unsupported.

    Supported: a single contracting dim per operand positioned at the
    boundary of the free dims (first or last of the non-batch dims, so
    the rest flatten contiguously), and batch dims — if any — leading and
    in order on BOTH operands (the shape einsum lowering produces for the
    MoE/attention contractions).  The plan's output shape follows the
    dot_general convention ``batch + a_free + b_free``.  (first, last) on
    (a, b) — the "tt" case — has no kernel layout and returns None.
    """
    (ca, cb), (batch_a, batch_b) = dimension_numbers
    if len(ca) != 1 or len(cb) != 1:
        return None
    nb = len(batch_a)
    if tuple(batch_a) != tuple(range(nb)) or \
            tuple(batch_b) != tuple(range(nb)):
        return None
    if a_shape[:nb] != b_shape[:nb]:
        return None
    ca, cb = ca[0], cb[0]
    if ca not in (nb, len(a_shape) - 1) or cb not in (nb, len(b_shape) - 1):
        return None
    a_last = ca == len(a_shape) - 1
    b_first = cb == nb
    if not a_last and not b_first:
        return None                      # "tt": no layout variant
    k = a_shape[ca]
    if k != b_shape[cb]:
        return None
    a_rest = tuple(d for i, d in enumerate(a_shape) if i >= nb and i != ca)
    b_rest = tuple(d for i, d in enumerate(b_shape) if i >= nb and i != cb)
    layout = "nn" if (a_last and b_first) else ("nt" if a_last else "tn")
    return _plan_from_parts(layout, a_shape[:nb], a_shape[:nb],
                            _prod(a_rest), k, _prod(b_rest),
                            a_shape[:nb] + a_rest + b_rest)




def plan_einsum(spec: str, a_shape, b_shape) -> Optional[QdotPlan]:
    """Map a two-operand einsum onto a payload GEMM, or None.

    The supported family generalizes the PR-3 ``"...k,kn->...n"``
    whitelist to every contraction the batched kernels execute:

      * exactly one contracted label, sitting first or last among each
        operand's non-batch labels (no "tt", no multi-label contraction,
        no sum-over-free);
      * B's labels are ``shared-batch + free/contract``; A's are
        ``lead + shared-batch + free/contract`` where ``lead`` are free
        labels only (they broadcast B — the ``becd,edf`` family);
      * the output is exactly ``lead + shared + a_free + b_free`` — the
        order the batched GEMM produces, so the plan is pure reshapes.

    This covers the dense ``bsd,df->bsf`` family (empty batch), the MoE
    expert einsums ``ecd,edf->ecf`` / ``becd,edf->becf``, and the
    attention contractions ``bkgqd,bksd->bkgqs`` / ``bkgqs,bksd->bkgqd``.
    """
    if "->" not in spec:
        return None
    lhs, lo = spec.replace(" ", "").split("->")
    parts = lhs.split(",")
    if len(parts) != 2:
        return None
    la, lb = parts
    if "." in lb:
        return None                      # ellipsis rhs: ambiguous layout
    if "..." in la:
        # concretize "..." with fresh labels, shared between lhs and out
        n_ell = len(a_shape) - (len(la) - 3)
        if n_ell < 0 or "..." not in lo:
            return None
        fresh = "".join(c for c in string.ascii_letters
                        if c not in spec)[:n_ell]
        if len(fresh) != n_ell:
            return None
        la = la.replace("...", fresh)
        lo = lo.replace("...", fresh)
    if "." in la + lo or len(la) != len(a_shape) or len(lb) != len(b_shape):
        return None
    if len(set(la)) != len(la) or len(set(lb)) != len(lb) \
            or len(set(lo)) != len(lo):
        return None
    sa, sb, so = set(la), set(lb), set(lo)
    if not so <= (sa | sb):
        return None
    contract = (sa & sb) - so
    if len(contract) != 1:
        return None
    k_lab = contract.pop()
    if (sa - {k_lab}) - so or (sb - {k_lab}) - so:
        return None                      # sum-over-free: not a pure GEMM
    shared = "".join(c for c in la if c in sb and c != k_lab)
    if not lb.startswith(shared):
        return None
    rb = lb[len(shared):]
    if shared:
        i0 = la.index(shared[0])
        if la[i0:i0 + len(shared)] != shared:
            return None
        lead, ra = la[:i0], la[i0 + len(shared):]
        if any(c in sb for c in lead):
            return None                  # shared labels must be contiguous
    else:
        lead, ra = "", la
    fa = "".join(c for c in ra if c != k_lab)
    fb = "".join(c for c in rb if c != k_lab)
    if ra not in (fa + k_lab, k_lab + fa) or rb not in (k_lab + fb, fb + k_lab):
        return None
    if lo != lead + shared + fa + fb:
        return None
    a_last, b_first = ra.endswith(k_lab), rb.startswith(k_lab)
    if not a_last and not b_first:
        return None                      # "tt"
    dims = dict(zip(la, a_shape))
    for c, d in zip(lb, b_shape):
        if dims.setdefault(c, d) != d:
            return None
    layout = "nn" if (a_last and b_first) else ("nt" if a_last else "tn")
    return _plan_from_parts(
        layout, tuple(dims[c] for c in lead + shared),
        tuple(dims[c] for c in shared),
        _prod(dims[c] for c in fa), dims[k_lab], _prod(dims[c] for c in fb),
        tuple(dims[c] for c in lo))


def qdot_general_supported(a_shape, b_shape, dimension_numbers) -> bool:
    return plan_qdot_general(a_shape, b_shape, dimension_numbers) is not None


def _make_ref_truncate():
    # one jitted program over the existing oracle — no second fmt dispatch
    from repro.kernels import ref
    return jax.jit(ref.s2fp8_truncate_ref, static_argnames=("fmt",))


_ref_truncate = _make_ref_truncate()


class RefBackend(NumericsBackend):
    """Pure-jnp reference engine (core/s2fp8.py + kernels/ref.py).

    ``compute_stats`` and ``truncate`` each run as one jitted program —
    the execution shape every real caller (jitted train/eval steps) sees.
    This pins down ONE set of XLA fusion/FMA decisions per stage, which is
    what makes ref-vs-pallas bitwise parity well-defined: op-by-op eager
    dispatch of the same chain differs from any compiled version by 1-ulp
    FMA rounding.
    """

    name = "ref"

    def compute_stats(self, x, *, fmt: str = "e5m2", axis_name=None):
        if axis_name is not None:
            partials = all_reduce_stats_partials(
                self.compute_stats_partials(x), axis_name)
            return s2fp8.stats_from_reduction(*partials, _TARGET_MAX[fmt])
        return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])

    def compute_stats_partials(self, x):
        return s2fp8.compute_stats_partials_jit(x)

    def quantize(self, x, *, stats=None, fmt: str = "e5m2"):
        return s2fp8.quantize(x, stats=stats, fmt=fmt)

    def dequantize(self, t, dtype=jnp.float32):
        return s2fp8.dequantize(t, dtype)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        if stats is None:
            stats = self.compute_stats(x, fmt=fmt)
        return _ref_truncate(x, stats, fmt=fmt)

    def qmatmul(self, a, b, *, layout: str = "nn", epilogue_stats=None,
                fmt: str = "e5m2"):
        from repro.kernels import ref
        y = ref.s2fp8_matmul_ref(a.payload, a.alpha, a.beta,
                                 b.payload, b.alpha, b.beta, layout=layout)
        if epilogue_stats is not None:
            # the "epilogue" through this engine's pinned truncate program
            # — bitwise-comparable with a separate output truncation
            y = self.truncate(y, stats=epilogue_stats, fmt=fmt)
        return y

    def qmatmul_batched(self, a, b, *, layout: str = "nn", out_batch=None,
                        epilogue_stats=None, fmt: str = "e5m2"):
        from repro.kernels import ref
        y = ref.s2fp8_matmul_batched_ref(a.payload, a.alpha, a.beta,
                                         b.payload, b.alpha, b.beta,
                                         layout=layout, out_batch=out_batch)
        if epilogue_stats is not None:
            y = self.truncate(y, stats=epilogue_stats, fmt=fmt)
        return y


class PallasBackend(NumericsBackend):
    """Fused Pallas-kernel engine via kernels/dispatch.py.

    ``stats_mode``:
      * "exact" (default) — (alpha, beta) from the same monolithic jnp
        reduction the ref uses; truncation output is bitwise-identical to
        the ref backend (including under interpret mode off-TPU).
      * "fused"           — in-kernel blocked stats reduction (the
        two-phase single-kernel path); float-tolerance parity.
    ``interpret=None`` auto-detects the platform per call.
    """

    name = "pallas"

    def __init__(self, *, stats_mode: str = "exact",
                 interpret: Optional[bool] = None, block=None,
                 name: Optional[str] = None):
        if stats_mode not in ("exact", "fused"):
            raise ValueError(f"stats_mode must be 'exact' or 'fused', "
                             f"got {stats_mode!r}")
        from repro.kernels.s2fp8_quant import DEFAULT_BLOCK
        self.stats_mode = stats_mode
        self.interpret = interpret
        self.block = DEFAULT_BLOCK if block is None else block
        if name is not None:
            self.name = name

    def compute_stats(self, x, *, fmt: str = "e5m2", axis_name=None):
        from repro.kernels import dispatch
        if axis_name is not None:
            partials = all_reduce_stats_partials(
                self.compute_stats_partials(x), axis_name)
            return s2fp8.stats_from_reduction(*partials, _TARGET_MAX[fmt])
        if self.stats_mode == "exact":
            # Same compiled program as RefBackend — the bitwise-parity anchor.
            return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])
        return dispatch.stats_nd(x, target_max=_TARGET_MAX[fmt],
                                 block=self.block, interpret=self.interpret)

    def compute_stats_partials(self, x):
        if self.stats_mode == "exact":
            return s2fp8.compute_stats_partials_jit(x)
        from repro.kernels import dispatch
        return dispatch.stats_partials_nd(x, block=self.block,
                                          interpret=self.interpret)

    def quantize(self, x, *, stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        # exact mode: stats from the shared compiled reduction, so stored
        # (alpha, beta) match RefBackend.quantize and this backend's own
        # compute_stats bit-for-bit; fused mode keeps the reduction in-kernel
        if stats is None and self.stats_mode == "exact":
            stats = s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])
        payload, alpha, beta = dispatch.quant_nd(x, stats=stats, fmt=fmt,
                                                 block=self.block,
                                                 interpret=self.interpret)
        return S2FP8Tensor(payload=payload, alpha=alpha, beta=beta, fmt=fmt)

    def dequantize(self, t, dtype=jnp.float32):
        from repro.kernels import dispatch
        return dispatch.dequant_nd(t.payload, t.alpha, t.beta, dtype=dtype,
                                   block=self.block, interpret=self.interpret)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        # stats=None + fused_stats=False -> truncate_nd's default branch
        # computes exact stats via the shared compute_stats_jit program
        return dispatch.truncate_nd(x, stats=stats, fmt=fmt,
                                    fused_stats=(self.stats_mode == "fused"),
                                    block=self.block, interpret=self.interpret)

    def qmatmul(self, a, b, *, layout: str = "nn", epilogue_stats=None,
                fmt: str = "e5m2"):
        from repro.kernels import dispatch
        return dispatch.qmatmul_nd(a.payload, a.alpha, a.beta,
                                   b.payload, b.alpha, b.beta,
                                   layout=layout, epilogue_stats=epilogue_stats,
                                   fmt=fmt, interpret=self.interpret)

    def qmatmul_batched(self, a, b, *, layout: str = "nn", out_batch=None,
                        epilogue_stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        return dispatch.qmatmul_batched_nd(
            a.payload, a.alpha, a.beta, b.payload, b.alpha, b.beta,
            layout=layout, out_batch=out_batch,
            epilogue_stats=epilogue_stats, fmt=fmt, interpret=self.interpret)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, NumericsBackend] = {}


def register_backend(name: str, backend: NumericsBackend,
                     overwrite: bool = False) -> NumericsBackend:
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_backend_name() -> str:
    """Platform default: the fused kernels where they compile, ref elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def get_backend(name: Optional[str] = None) -> NumericsBackend:
    """Resolve a backend by name; ``None``/"auto" picks the platform default."""
    if name is None or name == "auto":
        name = default_backend_name()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown numerics backend {name!r}; "
                       f"registered: {available_backends()}") from None


register_backend("ref", RefBackend())
register_backend("pallas", PallasBackend())
register_backend("pallas_fused", PallasBackend(stats_mode="fused",
                                               name="pallas_fused"))


# ---------------------------------------------------------------------------
# differentiable truncations (paper Fig. 4 wiring), per backend
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def bidir_truncate(backend: Optional[str] = None, fmt: str = "e5m2"):
    """Backend-routed analogue of ``s2fp8.truncate_bidir``: Eq. 5 on the
    forward value AND on the cotangent.  Cached per (backend, fmt) so the
    returned callable is a stable object under repeated jit tracing; the
    NAME is cached, not the engine — resolution happens per call, so
    ``register_backend(..., overwrite=True)`` takes effect immediately."""

    @jax.custom_vjp
    def _trunc(x):
        return get_backend(backend).truncate(x, fmt=fmt)

    def _fwd(x):
        return get_backend(backend).truncate(x, fmt=fmt), None

    def _bwd(_, g):
        return (get_backend(backend).truncate(g, fmt=fmt),)

    _trunc.defvjp(_fwd, _bwd)
    return _trunc


# ---------------------------------------------------------------------------
# delayed stats
# ---------------------------------------------------------------------------

def truncate_delayed(x: jnp.ndarray, stats, *, refresh=False,
                     backend: Optional[str] = None, fmt: str = "e5m2"):
    """Functional delayed-stats truncation for jitted loops.

    Returns ``(truncated, stats_used)``.  Callers thread ``stats_used``
    into the next step; pass ``refresh=True`` (a Python bool, e.g.
    ``step % k == 0`` resolved outside jit or via two jitted branches)
    every k steps to recompute the reduction.  ``stats=None`` always
    refreshes.
    """
    be = get_backend(backend)
    if refresh or stats is None:
        stats = be.compute_stats(x, fmt=fmt)
    return be.truncate(x, stats=stats, fmt=fmt), stats


class DelayedStatsCache:
    """DEPRECATED shim over :class:`repro.core.statsbank.HostStatsBank`.

    There is one stats-caching story now — the StatsBank subsystem
    (core/statsbank.py): jit-carried banks for train steps, and
    ``HostStatsBank`` for eager callers (serving, checkpoint compression).
    This class keeps the old constructor/``truncate``/``clear`` surface
    (plus the ``_stats`` / ``_last_refresh`` views) and warns on use.
    """

    def __init__(self, backend: Optional[str] = None,
                 refresh_every: int = 16, fmt: str = "e5m2"):
        import warnings
        warnings.warn(
            "DelayedStatsCache is deprecated; use "
            "repro.core.statsbank.HostStatsBank (same semantics, shared "
            "with the jit-carried StatsBank)", DeprecationWarning,
            stacklevel=2)
        from repro.core import statsbank
        self._impl = statsbank.HostStatsBank(backend=backend,
                                             refresh_every=refresh_every,
                                             fmt=fmt)
        self.backend = backend
        self.refresh_every = refresh_every
        self.fmt = fmt

    def truncate(self, x: jnp.ndarray, key: str, step: int) -> jnp.ndarray:
        return self._impl.truncate(x, key, step)

    def clear(self):
        self._impl.clear()

    @property
    def _stats(self) -> Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]:
        return {k: (e["alpha"], e["beta"]) for k, e in self._impl.bank.items()}

    @property
    def _last_refresh(self) -> Dict[str, int]:
        return {k: int(e["last"]) for k, e in self._impl.bank.items()}
