"""Numerics-backend registry: one interface, swappable engines.

Every S2FP8 operation the framework performs — stats, quantize, dequantize,
the Eq. 5 truncation that ``Policy`` wraps around each GEMM, and the
payload-domain GEMM (``qmatmul``: NN/NT/TN operand layouts, optional fused
Eq. 5 output epilogue, e5m2/e4m3 payloads; ``qdot_general`` maps restricted
higher-rank contractions onto it) — goes through a
:class:`NumericsBackend`.  Two engines ship:

  * ``"ref"``    — the pure-jnp implementation in core/s2fp8.py (today's
    semantics, the semantic ground truth, and the fast CPU path);
  * ``"pallas"`` — the fused Pallas kernels in kernels/ via the
    shape-generalizing dispatch layer (kernels/dispatch.py).  Its default
    stats mode computes (alpha, beta) with the same monolithic reduction
    the ref uses and fuses apply->FP8-RNE->inverse into one elementwise
    kernel — bitwise-identical outputs, two HBM passes instead of five.
    ``PallasBackend(stats_mode="fused")`` moves the stats reduction
    in-kernel as well (single two-phase ``pallas_call``; float-tolerance
    parity).

``"auto"`` resolves to ``"pallas"`` on TPU and ``"ref"`` elsewhere; the
``Policy`` dataclass carries the selection (core/policy.py), the launchers
expose it as ``--backend``, and ArchConfig carries a per-arch default.

Delayed-stats mode: every backend's ``truncate`` and ``quantize`` accept
precomputed ``stats=(alpha, beta)``.  The StatsBank subsystem
(core/statsbank.py) is the first-class consumer: a jit-carried, sharded,
checkpointable bank of per-site stats refreshed every k steps inside the
train step, plus ``HostStatsBank`` for eager callers (serving, checkpoint
compression).  :func:`truncate_delayed` remains the low-level functional
hook, and :class:`DelayedStatsCache` is a deprecated shim over the host
bank.  Tensor distributions drift slowly between adjacent steps (the
premise behind amortized scaling in FP8 training recipes), so stale-by-k
stats cost little accuracy while removing the stats reduction — the only
non-elementwise pass — from the hot loop.

Stats locality is explicit: ``compute_stats(x)`` reduces over the tensor
the caller holds (per-shard inside ``shard_map``), while
``compute_stats(x, axis_name=...)`` all-reduces the raw
``compute_stats_partials`` triplet for exact global stats.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import s2fp8
from repro.core.s2fp8 import S2FP8Tensor

_TARGET_MAX = s2fp8.FMT_TARGET_MAX


def all_reduce_stats_partials(partials, axis_name: str):
    """Combine per-shard (log_sum, log_max, count) stats partials across a
    mapped/shard_map axis: sums and counts add, maxes max.  This is the one
    place global-stats semantics live — every caller (backend
    ``compute_stats(axis_name=...)``, the StatsBank refresh) reduces the
    same triplet, so global stats are exact, not shard-averaged."""
    log_sum, log_max, count = partials
    return (jax.lax.psum(log_sum, axis_name),
            jax.lax.pmax(log_max, axis_name),
            jax.lax.psum(count, axis_name))


class NumericsBackend:
    """Interface every numerics engine implements.

    ``stats`` arguments/returns are (alpha, beta) f32 scalar pairs;
    ``fmt`` selects the payload format ("e5m2" — the paper's — or "e4m3").

    Stats semantics are explicit: ``compute_stats(x)`` reduces over the
    tensor the caller holds (LOCAL — inside a ``shard_map`` body that is
    the shard); ``compute_stats(x, axis_name=...)`` all-reduces the raw
    partials across that mesh axis first (GLOBAL — every shard gets the
    stats of the logical tensor).  ``compute_stats_partials`` exposes the
    raw (sum, max, count) triplet for callers that combine shards
    themselves (the StatsBank refresh).
    """

    name = "abstract"

    def compute_stats(self, x: jnp.ndarray, *, fmt: str = "e5m2",
                      axis_name: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def compute_stats_partials(self, x: jnp.ndarray
                               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def quantize(self, x: jnp.ndarray, *, stats=None,
                 fmt: str = "e5m2") -> S2FP8Tensor:
        raise NotImplementedError

    def dequantize(self, t: S2FP8Tensor, dtype=jnp.float32) -> jnp.ndarray:
        raise NotImplementedError

    def truncate(self, x: jnp.ndarray, *, stats=None,
                 fmt: str = "e5m2") -> jnp.ndarray:
        raise NotImplementedError

    def qmatmul(self, a: S2FP8Tensor, b: S2FP8Tensor, *, layout: str = "nn",
                epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
        """Payload-domain GEMM on 2-D payloads.

        ``layout`` selects transposed operand consumption ("nn"/"nt"/"tn",
        kernels/ref.py ``GEMM_CONTRACT``) — the backward GEMMs of
        core/qdot.py read the forward's saved payloads without
        materializing a transpose.  ``epilogue_stats=(alpha, beta)`` fuses
        the output site's Eq. 5 truncation into the GEMM epilogue
        (``fmt`` = the truncation's payload format)."""
        raise NotImplementedError

    def qdot_general(self, a: S2FP8Tensor, b: S2FP8Tensor, dimension_numbers,
                     *, epilogue_stats=None, fmt: str = "e5m2") -> jnp.ndarray:
        """General-rank payload-domain contraction.

        Maps a restricted ``lax.dot_general``-style contraction — single
        contracting dim sitting first or last on each operand, no batch
        dims — onto the 2-D ``qmatmul`` via payload reshapes (1-byte
        moves) and a layout pick.  Raises ``ValueError`` for contractions
        outside that family; callers gate on
        :func:`qdot_general_supported`."""
        plan = plan_qdot_general(a.shape, b.shape, dimension_numbers)
        if plan is None:
            raise ValueError(
                f"qdot_general cannot map dimension_numbers "
                f"{dimension_numbers} on {a.shape} x {b.shape} onto a "
                f"payload GEMM; gate with qdot_general_supported()")
        layout, a2_shape, b2_shape, out_shape = plan
        y = self.qmatmul(a.reshape(a2_shape), b.reshape(b2_shape),
                         layout=layout, epilogue_stats=epilogue_stats,
                         fmt=fmt)
        return y.reshape(out_shape)

    def __repr__(self):
        return f"<NumericsBackend {self.name!r}>"


def plan_qdot_general(a_shape, b_shape, dimension_numbers):
    """(layout, a2_shape, b2_shape, out_shape) mapping a restricted
    dot_general onto one 2-D payload GEMM, or None when unsupported.

    Supported: a single contracting dim per operand, positioned first or
    last (so the remaining dims flatten contiguously), and no batch dims.
    (first, last) on (a, b) — the "tt" case — has no kernel layout and
    returns None.
    """
    (ca, cb), (batch_a, batch_b) = dimension_numbers
    if batch_a or batch_b or len(ca) != 1 or len(cb) != 1:
        return None
    ca, cb = ca[0], cb[0]
    if ca not in (0, len(a_shape) - 1) or cb not in (0, len(b_shape) - 1):
        return None
    a_last = ca == len(a_shape) - 1
    b_first = cb == 0
    if not a_last and not b_first:
        return None                      # "tt": no layout variant
    k = a_shape[ca]
    if k != b_shape[cb]:
        return None
    a_rest = tuple(d for i, d in enumerate(a_shape) if i != ca)
    b_rest = tuple(d for i, d in enumerate(b_shape) if i != cb)
    m = 1
    for d in a_rest:
        m *= d
    n = 1
    for d in b_rest:
        n *= d
    if a_last and b_first:
        layout, a2, b2 = "nn", (m, k), (k, n)
    elif a_last:                         # b contracts on its last dim
        layout, a2, b2 = "nt", (m, k), (n, k)
    else:                                # a contracts on its first dim
        layout, a2, b2 = "tn", (k, m), (k, n)
    return layout, a2, b2, a_rest + b_rest


def qdot_general_supported(a_shape, b_shape, dimension_numbers) -> bool:
    return plan_qdot_general(a_shape, b_shape, dimension_numbers) is not None


def _make_ref_truncate():
    # one jitted program over the existing oracle — no second fmt dispatch
    from repro.kernels import ref
    return jax.jit(ref.s2fp8_truncate_ref, static_argnames=("fmt",))


_ref_truncate = _make_ref_truncate()


class RefBackend(NumericsBackend):
    """Pure-jnp reference engine (core/s2fp8.py + kernels/ref.py).

    ``compute_stats`` and ``truncate`` each run as one jitted program —
    the execution shape every real caller (jitted train/eval steps) sees.
    This pins down ONE set of XLA fusion/FMA decisions per stage, which is
    what makes ref-vs-pallas bitwise parity well-defined: op-by-op eager
    dispatch of the same chain differs from any compiled version by 1-ulp
    FMA rounding.
    """

    name = "ref"

    def compute_stats(self, x, *, fmt: str = "e5m2", axis_name=None):
        if axis_name is not None:
            partials = all_reduce_stats_partials(
                self.compute_stats_partials(x), axis_name)
            return s2fp8.stats_from_reduction(*partials, _TARGET_MAX[fmt])
        return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])

    def compute_stats_partials(self, x):
        return s2fp8.compute_stats_partials_jit(x)

    def quantize(self, x, *, stats=None, fmt: str = "e5m2"):
        return s2fp8.quantize(x, stats=stats, fmt=fmt)

    def dequantize(self, t, dtype=jnp.float32):
        return s2fp8.dequantize(t, dtype)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        if stats is None:
            stats = self.compute_stats(x, fmt=fmt)
        return _ref_truncate(x, stats, fmt=fmt)

    def qmatmul(self, a, b, *, layout: str = "nn", epilogue_stats=None,
                fmt: str = "e5m2"):
        from repro.kernels import ref
        y = ref.s2fp8_matmul_ref(a.payload, a.alpha, a.beta,
                                 b.payload, b.alpha, b.beta, layout=layout)
        if epilogue_stats is not None:
            # the "epilogue" through this engine's pinned truncate program
            # — bitwise-comparable with a separate output truncation
            y = self.truncate(y, stats=epilogue_stats, fmt=fmt)
        return y


class PallasBackend(NumericsBackend):
    """Fused Pallas-kernel engine via kernels/dispatch.py.

    ``stats_mode``:
      * "exact" (default) — (alpha, beta) from the same monolithic jnp
        reduction the ref uses; truncation output is bitwise-identical to
        the ref backend (including under interpret mode off-TPU).
      * "fused"           — in-kernel blocked stats reduction (the
        two-phase single-kernel path); float-tolerance parity.
    ``interpret=None`` auto-detects the platform per call.
    """

    name = "pallas"

    def __init__(self, *, stats_mode: str = "exact",
                 interpret: Optional[bool] = None, block=None,
                 name: Optional[str] = None):
        if stats_mode not in ("exact", "fused"):
            raise ValueError(f"stats_mode must be 'exact' or 'fused', "
                             f"got {stats_mode!r}")
        from repro.kernels.s2fp8_quant import DEFAULT_BLOCK
        self.stats_mode = stats_mode
        self.interpret = interpret
        self.block = DEFAULT_BLOCK if block is None else block
        if name is not None:
            self.name = name

    def compute_stats(self, x, *, fmt: str = "e5m2", axis_name=None):
        from repro.kernels import dispatch
        if axis_name is not None:
            partials = all_reduce_stats_partials(
                self.compute_stats_partials(x), axis_name)
            return s2fp8.stats_from_reduction(*partials, _TARGET_MAX[fmt])
        if self.stats_mode == "exact":
            # Same compiled program as RefBackend — the bitwise-parity anchor.
            return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])
        return dispatch.stats_nd(x, target_max=_TARGET_MAX[fmt],
                                 block=self.block, interpret=self.interpret)

    def compute_stats_partials(self, x):
        if self.stats_mode == "exact":
            return s2fp8.compute_stats_partials_jit(x)
        from repro.kernels import dispatch
        return dispatch.stats_partials_nd(x, block=self.block,
                                          interpret=self.interpret)

    def quantize(self, x, *, stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        # exact mode: stats from the shared compiled reduction, so stored
        # (alpha, beta) match RefBackend.quantize and this backend's own
        # compute_stats bit-for-bit; fused mode keeps the reduction in-kernel
        if stats is None and self.stats_mode == "exact":
            stats = s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])
        payload, alpha, beta = dispatch.quant_nd(x, stats=stats, fmt=fmt,
                                                 block=self.block,
                                                 interpret=self.interpret)
        return S2FP8Tensor(payload=payload, alpha=alpha, beta=beta, fmt=fmt)

    def dequantize(self, t, dtype=jnp.float32):
        from repro.kernels import dispatch
        return dispatch.dequant_nd(t.payload, t.alpha, t.beta, dtype=dtype,
                                   block=self.block, interpret=self.interpret)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        # stats=None + fused_stats=False -> truncate_nd's default branch
        # computes exact stats via the shared compute_stats_jit program
        return dispatch.truncate_nd(x, stats=stats, fmt=fmt,
                                    fused_stats=(self.stats_mode == "fused"),
                                    block=self.block, interpret=self.interpret)

    def qmatmul(self, a, b, *, layout: str = "nn", epilogue_stats=None,
                fmt: str = "e5m2"):
        from repro.kernels import dispatch
        return dispatch.qmatmul_nd(a.payload, a.alpha, a.beta,
                                   b.payload, b.alpha, b.beta,
                                   layout=layout, epilogue_stats=epilogue_stats,
                                   fmt=fmt, interpret=self.interpret)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, NumericsBackend] = {}


def register_backend(name: str, backend: NumericsBackend,
                     overwrite: bool = False) -> NumericsBackend:
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_backend_name() -> str:
    """Platform default: the fused kernels where they compile, ref elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def get_backend(name: Optional[str] = None) -> NumericsBackend:
    """Resolve a backend by name; ``None``/"auto" picks the platform default."""
    if name is None or name == "auto":
        name = default_backend_name()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown numerics backend {name!r}; "
                       f"registered: {available_backends()}") from None


register_backend("ref", RefBackend())
register_backend("pallas", PallasBackend())
register_backend("pallas_fused", PallasBackend(stats_mode="fused",
                                               name="pallas_fused"))


# ---------------------------------------------------------------------------
# differentiable truncations (paper Fig. 4 wiring), per backend
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def bidir_truncate(backend: Optional[str] = None, fmt: str = "e5m2"):
    """Backend-routed analogue of ``s2fp8.truncate_bidir``: Eq. 5 on the
    forward value AND on the cotangent.  Cached per (backend, fmt) so the
    returned callable is a stable object under repeated jit tracing; the
    NAME is cached, not the engine — resolution happens per call, so
    ``register_backend(..., overwrite=True)`` takes effect immediately."""

    @jax.custom_vjp
    def _trunc(x):
        return get_backend(backend).truncate(x, fmt=fmt)

    def _fwd(x):
        return get_backend(backend).truncate(x, fmt=fmt), None

    def _bwd(_, g):
        return (get_backend(backend).truncate(g, fmt=fmt),)

    _trunc.defvjp(_fwd, _bwd)
    return _trunc


# ---------------------------------------------------------------------------
# delayed stats
# ---------------------------------------------------------------------------

def truncate_delayed(x: jnp.ndarray, stats, *, refresh=False,
                     backend: Optional[str] = None, fmt: str = "e5m2"):
    """Functional delayed-stats truncation for jitted loops.

    Returns ``(truncated, stats_used)``.  Callers thread ``stats_used``
    into the next step; pass ``refresh=True`` (a Python bool, e.g.
    ``step % k == 0`` resolved outside jit or via two jitted branches)
    every k steps to recompute the reduction.  ``stats=None`` always
    refreshes.
    """
    be = get_backend(backend)
    if refresh or stats is None:
        stats = be.compute_stats(x, fmt=fmt)
    return be.truncate(x, stats=stats, fmt=fmt), stats


class DelayedStatsCache:
    """DEPRECATED shim over :class:`repro.core.statsbank.HostStatsBank`.

    There is one stats-caching story now — the StatsBank subsystem
    (core/statsbank.py): jit-carried banks for train steps, and
    ``HostStatsBank`` for eager callers (serving, checkpoint compression).
    This class keeps the old constructor/``truncate``/``clear`` surface
    (plus the ``_stats`` / ``_last_refresh`` views) and warns on use.
    """

    def __init__(self, backend: Optional[str] = None,
                 refresh_every: int = 16, fmt: str = "e5m2"):
        import warnings
        warnings.warn(
            "DelayedStatsCache is deprecated; use "
            "repro.core.statsbank.HostStatsBank (same semantics, shared "
            "with the jit-carried StatsBank)", DeprecationWarning,
            stacklevel=2)
        from repro.core import statsbank
        self._impl = statsbank.HostStatsBank(backend=backend,
                                             refresh_every=refresh_every,
                                             fmt=fmt)
        self.backend = backend
        self.refresh_every = refresh_every
        self.fmt = fmt

    def truncate(self, x: jnp.ndarray, key: str, step: int) -> jnp.ndarray:
        return self._impl.truncate(x, key, step)

    def clear(self):
        self._impl.clear()

    @property
    def _stats(self) -> Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]:
        return {k: (e["alpha"], e["beta"]) for k, e in self._impl.bank.items()}

    @property
    def _last_refresh(self) -> Dict[str, int]:
        return {k: int(e["last"]) for k, e in self._impl.bank.items()}
