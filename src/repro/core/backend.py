"""Numerics-backend registry: one interface, swappable engines.

Every S2FP8 operation the framework performs — stats, quantize, dequantize,
the Eq. 5 truncation that ``Policy`` wraps around each GEMM, and the
payload-domain GEMM — goes through a :class:`NumericsBackend`.  Two engines
ship:

  * ``"ref"``    — the pure-jnp implementation in core/s2fp8.py (today's
    semantics, the semantic ground truth, and the fast CPU path);
  * ``"pallas"`` — the fused Pallas kernels in kernels/ via the
    shape-generalizing dispatch layer (kernels/dispatch.py).  Its default
    stats mode computes (alpha, beta) with the same monolithic reduction
    the ref uses and fuses apply->FP8-RNE->inverse into one elementwise
    kernel — bitwise-identical outputs, two HBM passes instead of five.
    ``PallasBackend(stats_mode="fused")`` moves the stats reduction
    in-kernel as well (single two-phase ``pallas_call``; float-tolerance
    parity).

``"auto"`` resolves to ``"pallas"`` on TPU and ``"ref"`` elsewhere; the
``Policy`` dataclass carries the selection (core/policy.py), the launchers
expose it as ``--backend``, and ArchConfig carries a per-arch default.

Delayed-stats mode: every backend's ``truncate`` accepts precomputed
``stats=(alpha, beta)``.  :func:`truncate_delayed` and
:class:`DelayedStatsCache` build the two idioms on top — a functional
carry for jitted loops (refresh the reduction every k steps, reuse the
scalars in between) and a host-side keyed cache for eager callers
(serving, checkpoint compression).  Tensor distributions drift slowly
between adjacent steps (the premise behind amortized scaling in FP8
training recipes), so stale-by-k stats cost little accuracy while removing
the stats reduction — the only non-elementwise pass — from the hot loop.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import s2fp8
from repro.core.s2fp8 import S2FP8Tensor

_TARGET_MAX = s2fp8.FMT_TARGET_MAX


class NumericsBackend:
    """Interface every numerics engine implements.

    ``stats`` arguments/returns are (alpha, beta) f32 scalar pairs;
    ``fmt`` selects the payload format ("e5m2" — the paper's — or "e4m3").
    """

    name = "abstract"

    def compute_stats(self, x: jnp.ndarray, *, fmt: str = "e5m2"
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def quantize(self, x: jnp.ndarray) -> S2FP8Tensor:
        raise NotImplementedError

    def dequantize(self, t: S2FP8Tensor, dtype=jnp.float32) -> jnp.ndarray:
        raise NotImplementedError

    def truncate(self, x: jnp.ndarray, *, stats=None,
                 fmt: str = "e5m2") -> jnp.ndarray:
        raise NotImplementedError

    def qmatmul(self, a: S2FP8Tensor, b: S2FP8Tensor) -> jnp.ndarray:
        raise NotImplementedError

    def __repr__(self):
        return f"<NumericsBackend {self.name!r}>"


def _make_ref_truncate():
    # one jitted program over the existing oracle — no second fmt dispatch
    from repro.kernels import ref
    return jax.jit(ref.s2fp8_truncate_ref, static_argnames=("fmt",))


_ref_truncate = _make_ref_truncate()


class RefBackend(NumericsBackend):
    """Pure-jnp reference engine (core/s2fp8.py + kernels/ref.py).

    ``compute_stats`` and ``truncate`` each run as one jitted program —
    the execution shape every real caller (jitted train/eval steps) sees.
    This pins down ONE set of XLA fusion/FMA decisions per stage, which is
    what makes ref-vs-pallas bitwise parity well-defined: op-by-op eager
    dispatch of the same chain differs from any compiled version by 1-ulp
    FMA rounding.
    """

    name = "ref"

    def compute_stats(self, x, *, fmt: str = "e5m2"):
        return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])

    def quantize(self, x):
        return s2fp8.quantize(x)

    def dequantize(self, t, dtype=jnp.float32):
        return s2fp8.dequantize(t, dtype)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        if stats is None:
            stats = self.compute_stats(x, fmt=fmt)
        return _ref_truncate(x, stats, fmt=fmt)

    def qmatmul(self, a, b):
        from repro.kernels import ref
        return ref.s2fp8_matmul_ref(a.payload, a.alpha, a.beta,
                                    b.payload, b.alpha, b.beta)


class PallasBackend(NumericsBackend):
    """Fused Pallas-kernel engine via kernels/dispatch.py.

    ``stats_mode``:
      * "exact" (default) — (alpha, beta) from the same monolithic jnp
        reduction the ref uses; truncation output is bitwise-identical to
        the ref backend (including under interpret mode off-TPU).
      * "fused"           — in-kernel blocked stats reduction (the
        two-phase single-kernel path); float-tolerance parity.
    ``interpret=None`` auto-detects the platform per call.
    """

    name = "pallas"

    def __init__(self, *, stats_mode: str = "exact",
                 interpret: Optional[bool] = None, block=None,
                 name: Optional[str] = None):
        if stats_mode not in ("exact", "fused"):
            raise ValueError(f"stats_mode must be 'exact' or 'fused', "
                             f"got {stats_mode!r}")
        from repro.kernels.s2fp8_quant import DEFAULT_BLOCK
        self.stats_mode = stats_mode
        self.interpret = interpret
        self.block = DEFAULT_BLOCK if block is None else block
        if name is not None:
            self.name = name

    def compute_stats(self, x, *, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        if self.stats_mode == "exact":
            # Same compiled program as RefBackend — the bitwise-parity anchor.
            return s2fp8.compute_stats_jit(x, target_max=_TARGET_MAX[fmt])
        return dispatch.stats_nd(x, target_max=_TARGET_MAX[fmt],
                                 block=self.block, interpret=self.interpret)

    def quantize(self, x):
        from repro.kernels import dispatch
        # exact mode: stats from the shared compiled reduction, so stored
        # (alpha, beta) match RefBackend.quantize and this backend's own
        # compute_stats bit-for-bit; fused mode keeps the reduction in-kernel
        stats = (s2fp8.compute_stats_jit(x) if self.stats_mode == "exact"
                 else None)
        payload, alpha, beta = dispatch.quant_nd(x, stats=stats,
                                                 block=self.block,
                                                 interpret=self.interpret)
        return S2FP8Tensor(payload=payload, alpha=alpha, beta=beta)

    def dequantize(self, t, dtype=jnp.float32):
        from repro.kernels import dispatch
        return dispatch.dequant_nd(t.payload, t.alpha, t.beta, dtype=dtype,
                                   block=self.block, interpret=self.interpret)

    def truncate(self, x, *, stats=None, fmt: str = "e5m2"):
        from repro.kernels import dispatch
        # stats=None + fused_stats=False -> truncate_nd's default branch
        # computes exact stats via the shared compute_stats_jit program
        return dispatch.truncate_nd(x, stats=stats, fmt=fmt,
                                    fused_stats=(self.stats_mode == "fused"),
                                    block=self.block, interpret=self.interpret)

    def qmatmul(self, a, b):
        from repro.kernels import dispatch
        return dispatch.qmatmul_nd(a.payload, a.alpha, a.beta,
                                   b.payload, b.alpha, b.beta,
                                   interpret=self.interpret)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, NumericsBackend] = {}


def register_backend(name: str, backend: NumericsBackend,
                     overwrite: bool = False) -> NumericsBackend:
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_backend_name() -> str:
    """Platform default: the fused kernels where they compile, ref elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def get_backend(name: Optional[str] = None) -> NumericsBackend:
    """Resolve a backend by name; ``None``/"auto" picks the platform default."""
    if name is None or name == "auto":
        name = default_backend_name()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown numerics backend {name!r}; "
                       f"registered: {available_backends()}") from None


register_backend("ref", RefBackend())
register_backend("pallas", PallasBackend())
register_backend("pallas_fused", PallasBackend(stats_mode="fused",
                                               name="pallas_fused"))


# ---------------------------------------------------------------------------
# differentiable truncations (paper Fig. 4 wiring), per backend
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def bidir_truncate(backend: Optional[str] = None, fmt: str = "e5m2"):
    """Backend-routed analogue of ``s2fp8.truncate_bidir``: Eq. 5 on the
    forward value AND on the cotangent.  Cached per (backend, fmt) so the
    returned callable is a stable object under repeated jit tracing; the
    NAME is cached, not the engine — resolution happens per call, so
    ``register_backend(..., overwrite=True)`` takes effect immediately."""

    @jax.custom_vjp
    def _trunc(x):
        return get_backend(backend).truncate(x, fmt=fmt)

    def _fwd(x):
        return get_backend(backend).truncate(x, fmt=fmt), None

    def _bwd(_, g):
        return (get_backend(backend).truncate(g, fmt=fmt),)

    _trunc.defvjp(_fwd, _bwd)
    return _trunc


# ---------------------------------------------------------------------------
# delayed stats
# ---------------------------------------------------------------------------

def truncate_delayed(x: jnp.ndarray, stats, *, refresh=False,
                     backend: Optional[str] = None, fmt: str = "e5m2"):
    """Functional delayed-stats truncation for jitted loops.

    Returns ``(truncated, stats_used)``.  Callers thread ``stats_used``
    into the next step; pass ``refresh=True`` (a Python bool, e.g.
    ``step % k == 0`` resolved outside jit or via two jitted branches)
    every k steps to recompute the reduction.  ``stats=None`` always
    refreshes.
    """
    be = get_backend(backend)
    if refresh or stats is None:
        stats = be.compute_stats(x, fmt=fmt)
    return be.truncate(x, stats=stats, fmt=fmt), stats


class DelayedStatsCache:
    """Host-side keyed (alpha, beta) cache for eager callers.

    ``cache.truncate(x, key, step)`` reuses the stats stored under ``key``
    and refreshes them every ``refresh_every`` steps — between refreshes
    the truncation is a single elementwise pass (no reduction).
    """

    def __init__(self, backend: Optional[str] = None,
                 refresh_every: int = 16, fmt: str = "e5m2"):
        if refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")
        self.backend = backend
        self.refresh_every = refresh_every
        self.fmt = fmt
        self._stats: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._last_refresh: Dict[str, int] = {}

    def truncate(self, x: jnp.ndarray, key: str, step: int) -> jnp.ndarray:
        refresh = (key not in self._stats or
                   step - self._last_refresh[key] >= self.refresh_every)
        out, stats = truncate_delayed(x, self._stats.get(key),
                                      refresh=refresh, backend=self.backend,
                                      fmt=self.fmt)
        if refresh:
            self._stats[key] = stats
            self._last_refresh[key] = step
        return out

    def clear(self):
        self._stats.clear()
        self._last_refresh.clear()
