"""StatsBank: first-class, jit-carried, sharded, checkpointable per-tensor
S2FP8 statistics.

The paper's mechanism is a pair of learnable statistics (shift beta,
squeeze alpha) per tensor, evolving across training steps (Fig. 5).  The
seed recomputed them from scratch inside every truncation; PR 1 amortized
that for eager callers with a host-side dict.  This module makes the
statistics *state*: a flat, keyed pytree — the **bank** — that is a
functional carry of the train step, refreshed *inside* jit every
``refresh_every`` steps, sharded like any other state under pjit, and
saved/restored by the checkpoint manager so a resumed run starts with
warm stats.

Bank layout (plain nested dicts — nothing to register, trivially
checkpointable)::

    bank = {
      "seg0:dense/attn/t0": {            # one entry per truncation site
         "fwd": {alpha, beta, ema_mu, ema_m, last},   # forward value stats
         "bwd": {alpha, beta, ema_mu, ema_m, last},   # cotangent stats
      },
      "seg0:dense/mlp/qt0": {            # one entry per payload-GEMM node
         "a.fwd": {...}, "a.bwd": {...},              # (core/qdot.py):
         "b.fwd": {...}, "b.bwd": {...},              # operand, output and
         "out.fwd": {...}, "out.bwd": {...},          # cotangent stats
      },
      ...
    }

``ema_mu`` / ``ema_m`` are EMAs of the *raw* log2-domain moments of paper
Eq. 3–4 (mean and max of log2|X| over nonzeros); (alpha, beta) are derived
from the EMAs at each refresh and stored so the bank literally carries the
paper's statistics.  ``last`` is the last-refresh step (f32; -1 = never —
forces a bootstrap refresh on first use so step 0 never truncates with
identity stats).  Sites inside a scanned layer segment hold [L]-shaped
leaves, one row per layer.

How state flows through jit (the part that makes this work under
``lax.scan`` over layers, ``jax.checkpoint`` remat, and pjit):

  * READS — a :func:`bind` context activates a :class:`Session` for the
    duration of the loss trace.  ``Policy``'s truncation wrappers route
    through ``session.truncate``, which resolves a stable site key from
    the active scope stack and pulls that entry out of the bank.  For
    scanned segments the model threads the per-layer entries through the
    scan's ``xs`` (``segment_sites`` + ``segment_ctx``), so each layer
    reads its own row.
  * WRITES — the bank is an extra *differentiated* argument of the loss,
    and each site's ``custom_vjp`` defines the cotangent of its entry to
    BE the refreshed entry (the delayed-scaling idiom from FP8 training
    systems).  ``jax.grad`` w.r.t. the bank therefore returns the new
    bank: scan transposition stacks per-layer rows back up, remat replays
    are deterministic, and no out-of-band state escapes the trace.
  * REFRESH — the Eq. 3–4 reduction runs under ``lax.cond`` on
    ``step % refresh_every == 0`` (or bootstrap), so non-refresh steps
    execute ZERO stats reductions — truncation is one elementwise pass.
    Under ``shard_map`` a session bound with ``axis_name`` all-reduces the
    raw (sum, max, count) partials so every shard refreshes with exact
    GLOBAL stats (``backend.compute_stats_partials`` +
    ``backend.all_reduce_stats_partials``).

``HostStatsBank`` is the eager, host-side view over the same per-site
state for serving/compression callers (it absorbs the deprecated
``DelayedStatsCache``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import backend as nbackend
from repro.core import s2fp8
from repro.obs import metrics as obs_metrics

STATE_FIELDS = ("alpha", "beta", "ema_mu", "ema_m", "last")

# Directions of a payload-domain GEMM node (core/qdot.py ``qdot_train``):
# operand sites ("a", "b"), the output site ("out"), each with forward-value
# and cotangent stats — the same six Fig. 4 sites the composed
# ``Policy.dot`` chain visits, keyed flat so bank plumbing (stacking,
# checkpointing, bookkeeping) is structure-agnostic.  The states are
# per-TENSOR scalars (paper Eq. 3–4), so one node covers any contraction
# shape the planner maps onto the kernels — dense, batched (MoE expert
# einsums, attention score/value products) and im2col'd convs cost the
# same six scalars.
GEMM_DIRS = ("a.fwd", "a.bwd", "b.fwd", "b.bwd", "out.fwd", "out.bwd")

# Directions of a payload-domain flash-attention node (core/qdot.py
# ``qflash_attention``): the q/k/v operands and the attention output, each
# with forward-value and cotangent stats.  Like GEMM_DIRS these are
# per-tensor scalars, so a fused attention node costs eight scalars
# regardless of sequence length; every direction has a "bwd" twin, which
# is what :func:`merge_updates` keys on.
FLASH_DIRS = ("q.fwd", "q.bwd", "k.fwd", "k.bwd", "v.fwd", "v.bwd",
              "out.fwd", "out.bwd")


@dataclasses.dataclass(frozen=True)
class StatsConfig:
    """Static StatsBank policy (carried by the session, not the bank).

    * ``refresh_every`` — recompute the Eq. 3–4 reduction every k steps;
      between refreshes truncation is a single elementwise pass.
    * ``ema_decay`` — EMA coefficient on the raw (mu, m) moments; 0.0
      means each refresh replaces them (pure delayed stats).
    * ``axis_name`` — when set, refreshes all-reduce the (sum, max, count)
      partials over that mapped axis (a name or tuple of names — psum
      accepts either): global stats inside shard_map.  Use
      :func:`for_mesh` to derive it from a mesh's batch axes.
    * ``telemetry`` — when True, site states carry the per-site FP8
      health-metric leaves (:data:`repro.obs.metrics.TELE_FIELDS`),
      updated inside the refresh ``lax.cond`` (steady steps stay
      reduction-free); drained by :mod:`repro.obs.telemetry`.
    """

    refresh_every: int = 16
    ema_decay: float = 0.0
    axis_name: Optional[Union[str, Tuple[str, ...]]] = None
    telemetry: bool = False

    def __post_init__(self):
        if self.refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")
        if not (0.0 <= self.ema_decay < 1.0):
            raise ValueError("ema_decay must be in [0, 1)")
        if isinstance(self.axis_name, list):
            # keep the config hashable (it keys lru_caches in core/qdot.py)
            object.__setattr__(self, "axis_name", tuple(self.axis_name))


def for_mesh(cfg: StatsConfig, mesh) -> StatsConfig:
    """Mesh-aware refresh entry point: bind ``cfg``'s global-stats
    reduction to ``mesh``'s batch axes, so every refresh inside the
    mesh-native train step all-reduces the (sum, max, count) partials
    across the data shards — bank stats are stats of the GLOBAL batch,
    not the local shard.  ``mesh=None`` (or a mesh with no batch axes)
    clears ``axis_name``: single-device semantics."""
    if mesh is None:
        return dataclasses.replace(cfg, axis_name=None)
    from repro.parallel import sharding as shd
    axes = shd.mesh_batch_axes(mesh)
    if not axes:
        return dataclasses.replace(cfg, axis_name=None)
    return dataclasses.replace(
        cfg, axis_name=axes[0] if len(axes) == 1 else axes)


def init_site_state(length: Optional[int] = None,
                    telemetry: bool = False) -> Dict[str, jnp.ndarray]:
    """Fresh per-direction site state: identity stats, empty EMA,
    ``last = -1`` (bootstrap-refresh on first use).  ``telemetry=True``
    adds zeroed health-metric leaves (a cold site reports clean)."""
    shape = () if length is None else (length,)

    def full(v):
        return jnp.full(shape, v, jnp.float32)

    state = {"alpha": full(1.0), "beta": full(0.0), "ema_mu": full(0.0),
             "ema_m": full(0.0), "last": full(-1.0)}
    if telemetry:
        state.update(obs_metrics.init_tele_state(shape))
    return state


def refresh_state(x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                  step_f: jnp.ndarray, *, ema_decay: float = 0.0,
                  target_max: float = s2fp8.TARGET_MAX_LOG2,
                  backend: Optional[str] = None,
                  axis_name: Optional[str] = None,
                  fmt: Optional[str] = None) -> Dict[str, jnp.ndarray]:
    """One unconditional refresh: raw moments of ``x`` folded into the
    EMAs, (alpha, beta) re-derived.  The single source of refresh numerics
    — the in-jit ``lax.cond`` branch, the shard_map global path and the
    eager :class:`HostStatsBank` all call this.

    A telemetry-enabled ``state`` (extra :data:`TELE_FIELDS
    <repro.obs.metrics.TELE_FIELDS>` leaves) additionally gets its health
    metrics recomputed here — measured against the PRE-refresh carried
    stats, i.e. how unhealthy the delayed stats had become by the time
    this refresh fired.  ``fmt`` names the payload format for the
    saturation threshold; when None it is reverse-derived from
    ``target_max``."""
    be = nbackend.get_backend(backend)
    log_sum, log_max, count = be.compute_stats_partials(x)
    if axis_name is not None:
        log_sum, log_max, count = nbackend.all_reduce_stats_partials(
            (log_sum, log_max, count), axis_name)
    has = count > 0
    mu_t = log_sum / jnp.maximum(count, 1.0)
    m_t = jnp.where(has, log_max, 0.0)
    # `last >= 0` doubles as "the EMA moments are valid": a refresh that
    # saw only zeros leaves BOTH untouched (last stays -1), so the site
    # keeps bootstrapping until real data arrives — the placeholder-zero
    # moments are never folded into a later EMA.
    first = state["last"] < 0
    d = jnp.where(first, 0.0, jnp.float32(ema_decay))
    ema_mu = jnp.where(has, d * state["ema_mu"] + (1.0 - d) * mu_t,
                       state["ema_mu"])
    ema_m = jnp.where(has, d * state["ema_m"] + (1.0 - d) * m_t,
                      state["ema_m"])
    # No moments yet at all (all-zero tensor on the bootstrap refresh):
    # stay on identity stats via the epilogue's empty-tensor convention.
    valid = jnp.logical_or(has, jnp.logical_not(first))
    alpha, beta = s2fp8.stats_from_reduction(
        ema_mu, ema_m, jnp.where(valid, 1.0, 0.0), target_max)
    new_last = jnp.where(has, jnp.float32(step_f), state["last"])
    new = {"alpha": alpha, "beta": beta, "ema_mu": ema_mu, "ema_m": ema_m,
           "last": new_last}
    if obs_metrics.has_telemetry(state):
        new.update(obs_metrics.health_update(
            x, state, new, mu_t, m_t, has, first, count,
            fmt=obs_metrics.resolve_fmt(fmt, target_max),
            backend=backend, axis_name=axis_name))
    return new


def maybe_refresh(x, state, pred_f, step_f, cfg: StatsConfig,
                  target_max: float, backend: Optional[str],
                  fmt: Optional[str] = None):
    """(alpha_used, beta_used, new_state) with the reduction under
    ``lax.cond`` — non-refresh steps run zero reductions.  Refresh steps
    truncate with the freshly derived stats (refresh-then-use), matching
    the host-side cadence semantics."""
    need = jnp.logical_or(pred_f > 0, state["last"] < 0)

    def do(operand):
        x_, st = operand
        new = refresh_state(x_, st, step_f, ema_decay=cfg.ema_decay,
                            target_max=target_max, backend=backend,
                            axis_name=cfg.axis_name, fmt=fmt)
        return new["alpha"], new["beta"], new

    def keep(operand):
        _, st = operand
        return st["alpha"], st["beta"], st

    return jax.lax.cond(need, do, keep, (x, state))


# ---------------------------------------------------------------------------
# session (the trace-time object behind `bind`)
# ---------------------------------------------------------------------------

_ACTIVE = threading.local()


def current_session() -> Optional["Session"]:
    return getattr(_ACTIVE, "session", None)


def frozen_stats(state: Dict[str, jnp.ndarray], fmt: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(alpha, beta) re-derived from a site state's carried raw
    (ema_mu, ema_m) moments for ``fmt``'s target range, gradient-stopped.

    The single derivation shared by :meth:`Session.operand_stats`, the
    frozen serving session, and the serving KV-cache stats extraction —
    sharing it is what makes the serving engines' scalars bit-identical
    to the bank's.  The moments are format-agnostic, so a bank warmed
    under one format serves the other correctly (for the warming format
    the derivation reproduces the stored (alpha, beta) exactly).
    Never-refreshed sites (``last < 0``) fall through to identity stats
    via the empty-tensor convention of ``stats_from_reduction``."""
    alpha, beta = s2fp8.stats_from_reduction(
        state["ema_mu"], state["ema_m"],
        (state["last"] >= 0).astype(jnp.float32),
        s2fp8.FMT_TARGET_MAX[fmt])
    return jax.lax.stop_gradient(alpha), jax.lax.stop_gradient(beta)


class Session:
    """Trace-scoped view of a bank: resolves site keys, serves entries,
    and (in discovery mode) records the sites a model visits."""

    # Frozen (export-time) sessions override this; core/qdot.py branches
    # on it to pick the forward-only frozen-stats execution.
    frozen = False

    def __init__(self, bank: Optional[Dict[str, Any]], step,
                 cfg: StatsConfig, discovery: bool = False):
        self.bank = bank
        self.cfg = cfg
        self.discovery = discovery
        if not discovery:
            step = jnp.asarray(step, jnp.int32)
            self.step_f = step.astype(jnp.float32)
            self.pred_f = (step % cfg.refresh_every == 0).astype(jnp.float32)
        self._scopes: list = []
        self._counters: Dict[str, int] = {}
        self._segment: Optional[Tuple[str, Optional[Dict[str, Any]]]] = None
        # discovery outputs
        self.recorded: Dict[str, Dict[str, Any]] = {}
        self.segment_lengths: Dict[str, int] = {}

    # -- naming ---------------------------------------------------------
    def _site_key(self, kind: str) -> str:
        prefix = "/".join(self._scopes)
        ckey = f"{prefix}|{kind}"
        n = self._counters.get(ckey, 0)
        self._counters[ckey] = n + 1
        return f"{prefix}/{kind}{n}" if prefix else f"{kind}{n}"

    @contextlib.contextmanager
    def scope(self, name: str):
        self._scopes.append(name)
        try:
            yield
        finally:
            self._scopes.pop()

    # -- scanned segments ------------------------------------------------
    def segment_sites(self, name: str, length: int):
        """Stacked [L, ...] entries for every site under segment ``name``
        — the pytree the model threads through its layer scan's ``xs``.
        Returns None when the bank has no sites there (or in discovery)."""
        if self.discovery:
            self.segment_lengths[name] = length
            return None
        sites = {k: v for k, v in self.bank.items()
                 if k.startswith(name + "/")}
        if not sites:
            return None
        leaf = jax.tree_util.tree_leaves(sites)[0]
        if leaf.shape[:1] != (length,):
            raise ValueError(
                f"StatsBank segment {name!r} holds per-layer stats of "
                f"length {leaf.shape[:1]}, but the model scans {length} "
                f"layers — re-run statsbank.init_bank for this model")
        return sites

    @contextlib.contextmanager
    def segment_ctx(self, name: str, sliced_sites):
        """Inside a scan body: serve this layer's entry slices (pytree of
        scalars, one row of ``segment_sites``) to sites under ``name``."""
        if self._segment is not None:
            raise RuntimeError("StatsBank segments do not nest")
        self._segment = (name, sliced_sites)
        self._scopes.append(name)
        try:
            yield
        finally:
            self._scopes.pop()
            self._segment = None

    # -- entry resolution -------------------------------------------------
    def _lookup(self, key: str):
        if self._segment is not None:
            name, sites = self._segment
            entry = None if sites is None else sites.get(key)
        else:
            entry = self.bank.get(key)
        if entry is None:
            raise KeyError(
                f"truncation site {key!r} has no StatsBank entry — the "
                f"model structure changed since the bank was initialized; "
                f"re-run statsbank.init_bank")
        return entry

    # -- the two site kinds -----------------------------------------------
    def truncate(self, x: jnp.ndarray, *, fmt: str = "e5m2",
                 backend: Optional[str] = None) -> jnp.ndarray:
        """Bank-routed bidirectional truncation (paper Fig. 4): Eq. 5 on
        the forward value with the site's "fwd" stats and on the cotangent
        with its "bwd" stats; refreshed entries ride out as the bank
        argument's cotangent."""
        key = self._site_key("t")
        if self.discovery:
            self.recorded[key] = {"segment": self._segment[0] if self._segment
                                  else None, "dirs": ("fwd", "bwd")}
            return x
        entry = self._lookup(key)
        target_max = s2fp8.FMT_TARGET_MAX[fmt]
        cfg = self.cfg

        def routed(v, alpha, beta):
            return nbackend.get_backend(backend).truncate(
                v, stats=(alpha, beta), fmt=fmt)

        @jax.custom_vjp
        def t(x, fs, bs, pred_f, step_f):
            a, b, _ = maybe_refresh(x, fs, pred_f, step_f, cfg,
                                     target_max, backend, fmt=fmt)
            return routed(x, a, b)

        def t_fwd(x, fs, bs, pred_f, step_f):
            a, b, new_fs = maybe_refresh(x, fs, pred_f, step_f, cfg,
                                          target_max, backend, fmt=fmt)
            return routed(x, a, b), (new_fs, bs, pred_f, step_f)

        def t_bwd(res, g):
            new_fs, bs, pred_f, step_f = res
            a, b, new_bs = maybe_refresh(g, bs, pred_f, step_f, cfg,
                                          target_max, backend, fmt=fmt)
            # cotangents of (fs, bs) are the REFRESHED entries — this is
            # how the new bank leaves the trace (grad w.r.t. the bank).
            return (routed(g, a, b), new_fs, new_bs,
                    jnp.zeros_like(pred_f), jnp.zeros_like(step_f))

        t.defvjp(t_fwd, t_bwd)
        return t(x, entry["fwd"], entry["bwd"], self.pred_f, self.step_f)

    def qdot_site(self) -> Optional[Dict[str, Any]]:
        """Bank entry for a payload-domain GEMM node (core/qdot.py
        ``qdot_train``): six per-direction states keyed by
        :data:`GEMM_DIRS` — operand, output, and cotangent stats of one
        GEMM.  All six are differentiated through the node's custom_vjp,
        whose entry-cotangents are the refreshed states (the same
        bank-update idiom as :meth:`truncate`).  Returns None in
        discovery mode (after recording the site)."""
        key = self._site_key("qt")
        if self.discovery:
            self.recorded[key] = {"segment": self._segment[0] if self._segment
                                  else None, "dirs": GEMM_DIRS}
            return None
        return self._lookup(key)

    def qflash_site(self) -> Optional[Dict[str, Any]]:
        """Bank entry for a payload-domain flash-attention node
        (core/qdot.py ``qflash_attention``): eight per-direction states
        keyed by :data:`FLASH_DIRS`.  Same custom_vjp bank-update idiom as
        :meth:`qdot_site` — entry cotangents are the refreshed states.
        Returns None in discovery mode (after recording the site)."""
        key = self._site_key("qf")
        if self.discovery:
            self.recorded[key] = {"segment": self._segment[0] if self._segment
                                  else None, "dirs": FLASH_DIRS}
            return None
        return self._lookup(key)

    def operand_stats(self, x: jnp.ndarray, *, fmt: str = "e5m2"
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Read-only (alpha, beta) for a payload-domain GEMM operand
        (``Policy.qdot``).  Forward-only consumers (serving) keep the bank
        warm through :class:`HostStatsBank`; no update flows from here.

        The read is gradient-stopped: under a differentiated (banked
        train) step these entries would otherwise receive the mathematical
        dLoss/dalpha cotangent instead of a refreshed entry.  With the
        stop, their cotangent is zero and :func:`merge_updates` carries
        the old entry forward.

        (alpha, beta) are re-derived from the site's carried raw
        (ema_mu, ema_m) moments with THIS caller's ``fmt`` target — the
        moments are format-agnostic, so a bank warmed under one format
        serves the other correctly (for the warming format the derivation
        reproduces the stored scalars exactly).  Never-refreshed sites
        fall through to identity stats."""
        key = self._site_key("q")
        if self.discovery:
            self.recorded[key] = {"segment": self._segment[0] if self._segment
                                  else None, "dirs": ("fwd",)}
            return jnp.float32(1.0), jnp.float32(0.0)
        return frozen_stats(self._lookup(key)["fwd"], fmt)


class FrozenSession(Session):
    """Read-only serving session over an exported bank: every site serves
    (alpha, beta) re-derived from its carried raw moments
    (:func:`frozen_stats`) and NOTHING refreshes — no ``lax.cond``, no
    stats reduction, no custom_vjp.  This is the inference contract of the
    paper's delayed-stats idiom: a trained bank's statistics are frozen at
    export and prefill/decode run pure elementwise quantization around the
    payload kernels (the zero-reduction property the serving tests assert
    by jaxpr inspection).

    ``core/qdot.py`` dispatches on ``session.frozen`` to forward-only
    frozen-stats GEMM/flash execution; :meth:`truncate` here is the
    forward-only analogue of the banked truncation site."""

    frozen = True

    def __init__(self, bank: Dict[str, Any], cfg: StatsConfig = StatsConfig()):
        super().__init__(bank, 0, cfg)
        # never consumed on the frozen paths; zeroed so any accidental
        # maybe_refresh ride-along would still deselect the reduction
        self.pred_f = jnp.float32(0.0)
        self.step_f = jnp.float32(0.0)

    def truncate(self, x: jnp.ndarray, *, fmt: str = "e5m2",
                 backend: Optional[str] = None) -> jnp.ndarray:
        entry = self._lookup(self._site_key("t"))
        alpha, beta = frozen_stats(entry["fwd"], fmt)
        return nbackend.get_backend(backend).truncate(
            x, stats=(alpha, beta), fmt=fmt)


# ---------------------------------------------------------------------------
# module-level conveniences (no-ops without an active session)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def bind(bank: Dict[str, Any], step, cfg: StatsConfig = StatsConfig()):
    """Activate a session over ``bank`` for the current trace.  Use inside
    the function being differentiated; pass ``bank`` as a differentiated
    argument and read the refreshed bank out of its gradient."""
    if current_session() is not None:
        raise RuntimeError("a StatsBank session is already active")
    sess = Session(bank, step, cfg)
    _ACTIVE.session = sess
    try:
        yield sess
    finally:
        _ACTIVE.session = None


@contextlib.contextmanager
def freeze(bank: Dict[str, Any], cfg: StatsConfig = StatsConfig()):
    """Activate a :class:`FrozenSession` over an exported bank for the
    current trace — the serving engines' entry point.  Unlike :func:`bind`
    the bank is NOT a differentiated argument: nothing flows back.  Use
    inside the jitted prefill/decode function so the bank entries fold
    into the compiled program as constants."""
    if current_session() is not None:
        raise RuntimeError("a StatsBank session is already active")
    sess = FrozenSession(bank, cfg)
    _ACTIVE.session = sess
    try:
        yield sess
    finally:
        _ACTIVE.session = None


@contextlib.contextmanager
def scope(name: str):
    sess = current_session()
    if sess is None:
        yield
        return
    with sess.scope(name):
        yield


def segment_sites(name: str, length: int):
    sess = current_session()
    if sess is None:
        return None
    return sess.segment_sites(name, length)


@contextlib.contextmanager
def segment_ctx(name: str, sliced_sites):
    sess = current_session()
    if sess is None:
        yield
        return
    with sess.segment_ctx(name, sliced_sites):
        yield


# ---------------------------------------------------------------------------
# discovery
# ---------------------------------------------------------------------------

def init_bank(loss_fn: Callable, params, batch, policy,
              cfg: StatsConfig = StatsConfig()) -> Dict[str, Any]:
    """Discover the model's truncation sites and return a zero-initialized
    bank matching them.

    ``loss_fn(params, batch, policy) -> (loss, aux)`` is the same callable
    the trainer uses.  Discovery runs under ``jax.eval_shape`` (no FLOPs,
    no memory) with a recording session: each ``Policy`` truncation site
    reports its key and whether it sits inside a scanned layer segment;
    segment sites get [L]-stacked state rows.  Site keys are a function of
    Python execution order, which is identical between this abstract trace
    and the jitted train step.
    """
    if current_session() is not None:
        raise RuntimeError("cannot run discovery inside an active session")
    sess = Session(None, 0, cfg, discovery=True)

    def probe(p, b):
        _ACTIVE.session = sess
        try:
            loss, _ = loss_fn(p, b, policy)
        finally:
            _ACTIVE.session = None
        return loss

    jax.eval_shape(probe, params, batch)
    bank: Dict[str, Any] = {}
    for key, info in sess.recorded.items():
        length = (sess.segment_lengths.get(info["segment"])
                  if info["segment"] else None)
        bank[key] = {d: init_site_state(length, telemetry=cfg.telemetry)
                     for d in info["dirs"]}
    if not bank:
        raise ValueError(
            "no truncation sites found — StatsBank requires an s2fp8-mode "
            f"policy (got mode={getattr(policy, 'mode', policy)!r})")
    return bank


def merge_updates(bank: Dict[str, Any], updates: Dict[str, Any]
                  ) -> Dict[str, Any]:
    """Assemble the next-step bank from the loss gradient w.r.t. the bank.

    Sites with any cotangent-carrying direction — truncation sites
    ("bwd") and payload-GEMM nodes (every :data:`GEMM_DIRS` state) — emit
    their refreshed entry as their cotangent: take ``updates``.  Read-only
    operand-stats sites ("fwd"-only entries, gradient-stopped reads) have
    zero cotangents — carry the old entry forward unchanged."""
    return {k: updates[k] if any("bwd" in d for d in bank[k]) else bank[k]
            for k in bank}


def force_refresh(bank: Dict[str, Any]) -> Dict[str, Any]:
    """Host-side forced refresh: set ``last = -1`` on every
    cotangent-carrying site so each bootstrap-refreshes (EMA re-seeded
    with d=0 — exactly the reset wanted after numeric distress) on its
    next use.  Read-only operand-stats sites are left alone:
    :func:`merge_updates` carries their INPUT entry forward, so a -1
    planted there would never clear and the trainer's cold-start probe
    would report a refresh every step.  The escalation ladder's rung 2
    (training/guard.py docstring) calls this between steps."""
    out = {}
    for site, entry in bank.items():
        if any("bwd" in d for d in entry):
            out[site] = {d: dict(st, last=jnp.full_like(st["last"], -1.0))
                         for d, st in entry.items()}
        else:
            out[site] = entry
    return out


def bookkeeping_last(bank: Dict[str, Any]) -> jnp.ndarray:
    """Every site-direction's last-refresh scalar, concatenated — the
    trainer's O(n_sites) cold-start probe (``min < 0`` => some site still
    bootstraps this step).  Structure-agnostic over plain truncation
    entries, read-only operand sites, and GEMM nodes."""
    return jnp.concatenate([jnp.ravel(st["last"])
                            for e in bank.values() for st in e.values()])


# ---------------------------------------------------------------------------
# jaxpr inspection: prove non-refresh steps run zero stats reductions
# ---------------------------------------------------------------------------

REDUCE_PRIMS = ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "argmax", "argmin")


try:                                    # jax >= 0.4.33; jax.core alias
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:                     # pragma: no cover - older jax
    from jax.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr


def _extract_jaxprs(v):
    out = []
    if isinstance(v, (_Jaxpr, _ClosedJaxpr)):
        out.append(v)
    elif isinstance(v, (tuple, list)):
        for item in v:
            out.extend(_extract_jaxprs(item))
    return out


def count_reductions(jaxpr, include_cond: bool = True,
                     prims: Tuple[str, ...] = REDUCE_PRIMS) -> int:
    """Count reduction primitives in a (closed) jaxpr, recursing into
    sub-jaxprs (scan/pjit/remat/custom_vjp).  ``include_cond=False`` skips
    ``lax.cond`` branches — code that does NOT execute on steps where the
    predicate deselects it.  A StatsBank train step keeps every stats
    reduction inside cond branches, so its ``include_cond=False`` count
    equals the numerics-free (fp32) baseline's."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in prims:
            n += 1
        for pname, pval in eqn.params.items():
            if (eqn.primitive.name == "cond" and pname == "branches"
                    and not include_cond):
                continue
            for sub in _extract_jaxprs(pval):
                n += count_reductions(sub, include_cond, prims)
    return n


# ---------------------------------------------------------------------------
# host-side bank (absorbs DelayedStatsCache)
# ---------------------------------------------------------------------------

class HostStatsBank:
    """Eager, host-side keyed bank for non-jit callers (serving loops,
    checkpoint compression).  Same per-site state and refresh numerics as
    the jit-carried bank — ``refresh_state`` is shared — with the refresh
    decision taken on the host: ``truncate(x, key, step)`` refreshes when
    the key is new or ``step - last >= refresh_every``, else it is a
    single elementwise pass reusing the stored (alpha, beta)."""

    def __init__(self, backend: Optional[str] = None,
                 refresh_every: int = 16, ema_decay: float = 0.0,
                 fmt: str = "e5m2"):
        if refresh_every < 1:
            raise ValueError("refresh_every must be >= 1")
        self.backend = backend
        self.refresh_every = refresh_every
        self.ema_decay = ema_decay
        self.fmt = fmt
        self.bank: Dict[str, Dict[str, jnp.ndarray]] = {}

    def stats(self, key: str) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
        st = self.bank.get(key)
        return None if st is None else (st["alpha"], st["beta"])

    def _site(self, x, key: str, step: int):
        """The site's state, refreshed when the key is new or stale."""
        st = self.bank.get(key)
        if st is None or step - float(st["last"]) >= self.refresh_every:
            st = refresh_state(
                x, st if st is not None else init_site_state(),
                jnp.float32(step), ema_decay=self.ema_decay,
                target_max=s2fp8.FMT_TARGET_MAX[self.fmt],
                backend=self.backend)
            self.bank[key] = st
        return st

    def truncate(self, x: jnp.ndarray, key: str, step: int) -> jnp.ndarray:
        st = self._site(x, key, step)
        be = nbackend.get_backend(self.backend)
        return be.truncate(x, stats=(st["alpha"], st["beta"]), fmt=self.fmt)

    def quantize(self, x: jnp.ndarray, key: str, step: int):
        """Bank-stats quantization to S2FP8 storage (compression callers)."""
        st = self._site(x, key, step)
        be = nbackend.get_backend(self.backend)
        return be.quantize(x, stats=(st["alpha"], st["beta"]), fmt=self.fmt)

    def clear(self):
        self.bank.clear()
