"""Mesh-native train step: parity, routing, jaxpr and checkpoint suite.

Covers the ISSUE 5 acceptance criteria:
  * the banked payload train step on a 1-device mesh matches the existing
    unsharded step BITWISE (toy fast lane + transformer slow lane);
  * an 8-way host mesh with f32 grad-sync matches the 1-device banked
    step bitwise (order-exact toy, tests/mesh_toy.py), incl. a sharded
    checkpoint saved on 8 devices restoring on a single device with
    bit-exact params and bank stats;
  * s2fp8 grad-sync: tolerance vs 1-device + convergence smoke
    (transformer, subprocess);
  * jaxpr asserts: steady-state sharded steps run ZERO stats reductions
    outside lax.cond, and the s2fp8 sync mode contains NO f32 psum of a
    large gradient leaf (the compressed reduce-scatter/all-gather legs
    replace it);
  * per-leaf sync routing (collectives.leaf_sync_route) and the
    psum-aware global-norm clip (1- vs N-device bitwise).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mesh_toy
from repro.core import collectives, statsbank
from repro.core.policy import make_policy
from repro.launch.mesh import make_mesh_from_spec
from repro.optim import optimizers, schedules
from repro.parallel import sharding as shd
from repro.training.trainer import make_train_step

jax.config.update("jax_platform_name", "cpu")

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_TESTS = os.path.dirname(__file__)


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([_SRC, _TESTS])
    return env


def _assert_trees_bitwise(a, b, msg=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (pa, xa), (_, xb) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"{msg} leaf {jax.tree_util.keystr(pa)}")


# ---------------------------------------------------------------------------
# per-leaf sync routing (the compressed_grad_sync fallback audit)
# ---------------------------------------------------------------------------

def test_leaf_sync_route_per_leaf():
    route = collectives.leaf_sync_route
    big = (1 << 16,)
    # the happy path: large float leaves compress
    assert route(big, jnp.float32, 8) == "compressed"
    assert route((256, 512), jnp.bfloat16, 8) == "compressed"
    # non-float leaves bypass compression (no log2 image; sums must be
    # exact)
    assert route(big, jnp.int32, 8) == "plain"
    assert route(big, jnp.bool_, 8) == "plain"
    # 0-d scalars bypass
    assert route((), jnp.float32, 8) == "plain"
    # below the floor: stats overhead dominates
    assert route((100,), jnp.float32, 8) == "plain"
    assert route(((1 << 16) - 8,), jnp.float32, 8) == "plain"
    # length not divisible by the axis: tiled scatter/gather need equal
    # shards
    assert route(((1 << 16) + 1,), jnp.float32, 8) == "plain"
    # floor is configurable
    assert route((128,), jnp.float32, 8, min_size=64) == "compressed"


def test_compressed_grad_sync_routes_int_leaves_plain():
    """An integer leaf large enough to compress must still take the exact
    psum path (end-to-end, 1-device mesh: the sync is then an identity
    mean and must return the leaf bit-exactly, which the lossy S2FP8
    round-trip would not)."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"counts": jnp.arange(1 << 16, dtype=jnp.int32),
         "big": jnp.linspace(-1.0, 1.0, 1 << 16, dtype=jnp.float32)}
    out = collectives.compressed_grad_sync(g, mesh, "data")
    assert out["counts"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out["counts"]),
                                  np.asarray(g["counts"]))
    # the float leaf DID take the compressed path: S2FP8 round-trip error
    assert not np.array_equal(np.asarray(out["big"]), np.asarray(g["big"]))


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------

class _StubMesh:
    def __init__(self, axes, sizes):
        self.axis_names = axes
        self.shape = sizes


def test_mesh_batch_axes_and_specs():
    host = _StubMesh(("data", "model"), {"data": 8, "model": 1})
    pod = _StubMesh(("pod", "data", "model"),
                    {"pod": 2, "data": 8, "model": 16})
    assert shd.mesh_batch_axes(host) == ("data",)
    assert shd.mesh_batch_axes(pod) == ("pod", "data")
    assert shd.mesh_batch_size(pod) == 16

    from jax.sharding import PartitionSpec as P
    batch = {"tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((16,), jnp.int32),
             "scalar": jax.ShapeDtypeStruct((), jnp.float32)}
    specs = shd.mesh_batch_specs(batch, host)
    assert specs["tokens"] == P("data")
    assert specs["labels"] == P("data")
    assert specs["scalar"] == P()
    assert shd.mesh_batch_specs(batch, pod)["tokens"] == P(("pod", "data"))
    # the divisibility guard is ALL-OR-NOTHING: one ragged leaf replicates
    # the whole batch (per-leaf guarding would pair a sharded leaf's shard
    # with another leaf's full batch inside the body)
    ragged = dict(batch, odd=jax.ShapeDtypeStruct((6, 4), jnp.float32))
    specs_r = shd.mesh_batch_specs(ragged, host)
    assert all(s == P() for s in specs_r.values()), specs_r


def test_statsbank_for_mesh():
    cfg = statsbank.StatsConfig(refresh_every=4)
    assert statsbank.for_mesh(cfg, None).axis_name is None
    host = _StubMesh(("data", "model"), {"data": 8, "model": 1})
    assert statsbank.for_mesh(cfg, host).axis_name == "data"
    pod = _StubMesh(("pod", "data", "model"),
                    {"pod": 2, "data": 8, "model": 16})
    assert statsbank.for_mesh(cfg, pod).axis_name == ("pod", "data")
    nobatch = _StubMesh(("model",), {"model": 4})
    assert statsbank.for_mesh(cfg, nobatch).axis_name is None


def test_make_mesh_from_spec():
    mesh = make_mesh_from_spec("1x1")
    assert mesh.axis_names == ("data", "model")
    with pytest.raises(ValueError, match="mesh spec"):
        make_mesh_from_spec("abc")
    with pytest.raises(ValueError, match="factors"):
        make_mesh_from_spec("1x1x1x1")


def test_make_train_step_validations():
    pol = make_policy("fp32")
    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    with pytest.raises(ValueError, match="grad_sync_mode"):
        make_train_step(mesh_toy.loss_fn, opt, sched, pol,
                        grad_sync_mode="bf16")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="grad_sync"):
        make_train_step(mesh_toy.loss_fn, opt, sched, pol, mesh=mesh,
                        grad_sync=lambda g: g)


# ---------------------------------------------------------------------------
# 1-device mesh == unsharded, bitwise (fast toy lane)
# ---------------------------------------------------------------------------

def test_mesh1_toy_matches_unsharded_bitwise():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sm, pm, om, bm, _ = mesh_toy.setup(mesh=mesh)
    s0, p0, o0, b0, _ = mesh_toy.setup(mesh=None)
    rm = mesh_toy.run(sm, pm, om, bm, 4)
    r0 = mesh_toy.run(s0, p0, o0, b0, 4)
    _assert_trees_bitwise(rm[:3], r0[:3], "mesh1-vs-unsharded")
    assert float(rm[3]["loss"]) == float(r0[3]["loss"])


@pytest.mark.slow
def test_mesh1_transformer_banked_payload_bitwise():
    """The real model: banked payload train step on a 1-device mesh vs
    the existing unsharded step, bit for bit (params, opt state, bank)."""
    from repro.configs import get_reduced_config
    from repro.data import synthetic
    from repro.models import transformer as tlm

    cfg = get_reduced_config("minicpm_2b").replace(
        n_layers=2, remat=False, vocab=64)
    pol = make_policy("s2fp8", gemm_mode="payload")
    params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw()
    sched = schedules.constant(3e-3)
    table = synthetic.make_markov_table(0, cfg.vocab)

    def loss_fn(p, b, pol_):
        return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

    def data_fn(s):
        return synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)

    scfg = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, scfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step_plain = jax.jit(make_train_step(loss_fn, opt, sched, pol,
                                         stats=scfg))
    step_mesh = jax.jit(make_train_step(loss_fn, opt, sched, pol,
                                        stats=scfg, mesh=mesh))
    p1, s1, b1 = params, opt.init(params), bank
    p2, s2, b2 = params, opt.init(params), bank
    for s in range(3):
        batch = data_fn(s)
        p1, s1, b1, m1 = step_plain(p1, s1, b1, batch, jnp.int32(s))
        p2, s2, b2, m2 = step_mesh(p2, s2, b2, batch, jnp.int32(s))
    _assert_trees_bitwise((p1, s1, b1), (p2, s2, b2), "transformer-mesh1")
    assert float(m1["loss"]) == float(m2["loss"])


# ---------------------------------------------------------------------------
# jaxpr structure asserts
# ---------------------------------------------------------------------------

def _collect_eqns(jaxpr, out):
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        out.append(eqn)
        for pv in eqn.params.values():
            for sub in statsbank._extract_jaxprs(pv):
                _collect_eqns(sub, out)
    return out


def _toy_sharded_jaxpr(mesh, policy, stats_cfg, grad_sync_mode="f32",
                       min_size=1 << 16, param_sharding="replicated"):
    opt = optimizers.adamw()
    params = mesh_toy.make_params()
    args = [params, opt.init(params)]
    if stats_cfg is not None:
        args.append(statsbank.init_bank(mesh_toy.loss_fn, params,
                                        mesh_toy.make_batch(0), policy,
                                        stats_cfg))
    args += [mesh_toy.make_batch(0), jnp.int32(1)]
    step = make_train_step(mesh_toy.loss_fn, opt, schedules.constant(1e-3),
                           policy, stats=stats_cfg, mesh=mesh,
                           grad_sync_mode=grad_sync_mode,
                           grad_sync_min_size=min_size,
                           param_sharding=param_sharding)
    return jax.make_jaxpr(step)(*args)


def test_sharded_steady_state_runs_zero_stats_reductions():
    """The banked SHARDED step keeps every Eq. 3-4 reduction inside
    lax.cond: outside cond it runs exactly the reductions of the sharded
    fp32 baseline plus the one O(n_sites) bookkeeping min."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64)
    jx_bank = _toy_sharded_jaxpr(mesh, pol, scfg)
    jx_fp32 = _toy_sharded_jaxpr(mesh, make_policy("fp32"), None)
    n_bank = statsbank.count_reductions(jx_bank, include_cond=False)
    n_bank_all = statsbank.count_reductions(jx_bank, include_cond=True)
    n_fp32 = statsbank.count_reductions(jx_fp32, include_cond=False)
    assert n_bank == n_fp32 + 1, (n_bank, n_fp32)
    assert n_bank_all > n_bank, (n_bank_all, n_bank)


def test_s2fp8_sync_has_no_large_f32_allreduce():
    """Acceptance jaxpr assert: in s2fp8 grad-sync mode the program
    contains NO f32 psum of a compressible-size gradient leaf — the
    compressed reduce-scatter (bf16) + all-gather (1-byte payload) legs
    carry them instead.  f32 mode shows the large psum."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64)
    min_size = 64                        # toy grad leaf is 8x16 = 128

    def summarize(jx):
        eqns = _collect_eqns(jx, [])
        large_f32_psum = [e for e in eqns if e.primitive.name == "psum"
                          and any(np.prod(v.aval.shape) >= min_size
                                  and v.aval.dtype == jnp.float32
                                  for v in e.outvars)]
        names = {e.primitive.name for e in eqns}
        return large_f32_psum, names

    big_psums, names = summarize(_toy_sharded_jaxpr(
        mesh, pol, scfg, grad_sync_mode="s2fp8", min_size=min_size))
    assert not big_psums, [str(e) for e in big_psums]
    assert "reduce_scatter" in names and "all_gather" in names, names

    big_psums_f32, names_f32 = summarize(_toy_sharded_jaxpr(
        mesh, pol, scfg, grad_sync_mode="f32", min_size=min_size))
    assert big_psums_f32, "f32 mode should psum the large grad leaf"
    assert "reduce_scatter" not in names_f32, names_f32


# ---------------------------------------------------------------------------
# 8-way host mesh: f32 bitwise + sharded-checkpoint restore + psum clip
# ---------------------------------------------------------------------------

_MESH8_SCRIPT = r"""
import os, sys, json, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
import mesh_toy
from repro.checkpoint.manager import CheckpointManager
from repro.optim import optimizers

out = {}
mesh = jax.make_mesh((8, 1), ("data", "model"))

# --- 8-way f32 grad-sync vs 1-device, bitwise over 6 steps -----------------
s8, p8, o8, b8, _ = mesh_toy.setup(mesh=mesh, grad_sync_mode="f32")
s1, p1, o1, b1, _ = mesh_toy.setup(mesh=None)

pa, oa, ba = p8, o8, b8
ckdir = tempfile.mkdtemp()
ck = CheckpointManager(ckdir)
for s in range(6):
    pa, oa, ba, ma = s8(pa, oa, ba, mesh_toy.make_batch(s), jnp.int32(s))
    if s == 2:      # sharded save after 3 steps (leaves live on 8 devices)
        ck.save(3, (pa, oa, ba))
r1 = mesh_toy.run(s1, p1, o1, b1, 6)

def bitwise(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

out["step8_vs_step1_bitwise"] = bitwise((pa, oa, ba), r1[:3])
out["loss_bitwise"] = float(ma["loss"]) == float(r1[3]["loss"])

# --- sharded checkpoint restores on ONE device, bit-exact, and continues ---
template = jax.tree_util.tree_map(np.zeros_like,
                                  jax.tree_util.tree_map(np.asarray,
                                                         (p8, o8, b8)))
(rp, ro, rb), start = ck.restore(template)
out["restore_step"] = start
# restored leaves equal the 1-device run's state after 3 steps, bit for bit
mid = mesh_toy.run(s1, p1, o1, b1, 3)
out["restored_bitwise_vs_1dev"] = bitwise((rp, ro, rb), mid[:3])
# continue UNSHARDED from the sharded checkpoint: must land on the same
# final state
cont = mesh_toy.run(s1, rp, ro, rb, 6, start=3)
out["resume_1dev_matches_8way_final"] = bitwise(cont[:3], (pa, oa, ba))

# --- psum-aware global-norm clip: 1- vs 8-device bitwise -------------------
# integer-valued grads => every sum of squares is exact => order-free
g = {"a": (jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) - 60.0),
     "b": jnp.tile(jnp.arange(8, dtype=jnp.float32)[:, None], (1, 4)) - 3.0}
full_c, full_n = optimizers.clip_by_global_norm(g, 1.0)

def body(gl):
    c, nrm = optimizers.clip_by_global_norm(gl, 1.0, axis_name="data")
    return c, nrm[None]

sh_c, sh_n = shard_map(body, mesh=mesh, in_specs=P("data"),
                       out_specs=(P("data"), P("data")),
                       check_rep=False)(g)
out["clip_values_bitwise"] = bitwise(sh_c, full_c)
out["clip_norm_bitwise"] = bool((np.asarray(sh_n) == float(full_n)).all())
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh8_f32_bitwise_ckpt_and_clip():
    proc = subprocess.run([sys.executable, "-c", _MESH8_SCRIPT],
                          env=_subprocess_env(), capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["restore_step"] == 3
    assert all(v is True or v == 3 for v in out.values()), out


# ---------------------------------------------------------------------------
# 8-way s2fp8 grad-sync: tolerance + convergence smoke (transformer)
# ---------------------------------------------------------------------------

_S2FP8_SYNC_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced_config
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step

cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False,
                                               vocab=64)
pol = make_policy("s2fp8", gemm_mode="payload")
params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
opt = optimizers.adamw()
sched = schedules.constant(3e-3)
table = synthetic.make_markov_table(0, cfg.vocab)

def loss_fn(p, b, pol_):
    return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

def data_fn(s):
    return synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)

scfg = statsbank.StatsConfig(refresh_every=4)
bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, scfg)
mesh = jax.make_mesh((8, 1), ("data", "model"))

def run(step, n):
    p, o, b = params, opt.init(params), bank
    losses = []
    for s in range(n):
        p, o, b, m = step(p, o, b, data_fn(s), jnp.int32(s))
        losses.append(float(m["loss"]))
    return p, losses

# compressed sync on the 8-way mesh (floor lowered so the transformer's
# reduced-config leaves actually compress) vs the 1-device banked step
step_c = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg,
                                 mesh=mesh, grad_sync_mode="s2fp8",
                                 grad_sync_min_size=1 << 10))
step_1 = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg))
pc, losses_c = run(step_c, 12)
p1, losses_1 = run(step_1, 12)

rel = []
for a, b in zip(jax.tree_util.tree_leaves(pc), jax.tree_util.tree_leaves(p1)):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    denom = np.abs(b)
    nz = denom > 1e-12
    if nz.any():
        rel.append(np.median(np.abs(a - b)[nz] / denom[nz]))
out = {
    "median_param_rel": float(np.median(rel)),
    "max_leaf_median_rel": float(np.max(rel)),
    "loss_first": losses_c[0], "loss_last": losses_c[-1],
    "loss_gap_last": abs(losses_c[-1] - losses_1[-1]) / abs(losses_1[-1]),
}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh8_s2fp8_sync_tolerance_and_convergence():
    proc = subprocess.run([sys.executable, "-c", _S2FP8_SYNC_SCRIPT],
                          env=_subprocess_env(), capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # compressed-sync run stays close to the f32 1-device run...
    assert out["median_param_rel"] < 0.05, out
    assert out["loss_gap_last"] < 0.15, out
    # ...and converges on its own
    assert out["loss_last"] < out["loss_first"] * 0.8, out


# ---------------------------------------------------------------------------
# Quantized FSDP (ISSUE 9): shard params/opt, stream S2FP8 payloads
# ---------------------------------------------------------------------------

def test_fsdp_leaf_eligibility_and_specs():
    from jax.sharding import PartitionSpec as P

    elig = shd.fsdp_leaf_eligible
    assert elig((8, 16), jnp.float32, 8)
    assert elig((8,), jnp.bfloat16, 4)
    assert not elig((8, 16), jnp.int32, 8)       # non-float stays replicated
    assert not elig((), jnp.float32, 8)          # scalars (opt step counter)
    assert not elig((6, 4), jnp.float32, 4)      # dim 0 not divisible
    assert elig((6, 4), jnp.float32, 1)

    host = _StubMesh(("data", "model"), {"data": 8, "model": 1})
    assert shd.fsdp_axis_entry(host) == "data"
    assert shd.fsdp_axis_size(host) == 8
    nofsdp = _StubMesh(("model",), {"model": 4})
    assert shd.fsdp_axis_entry(nofsdp) is None
    assert shd.fsdp_axis_size(nofsdp) == 1

    tree = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
            "bias": jax.ShapeDtypeStruct((6,), jnp.float32),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = shd.fsdp_param_specs(tree, host)
    assert specs["w"] == P("data")
    assert specs["bias"] == P()                  # 6 % 8 != 0: per-leaf guard
    assert specs["count"] == P()

    batch = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    in_specs, out_specs = shd.train_step_specs(
        batch, host, with_stats=True, param_sharding="fsdp",
        params=tree, opt_state={"m": tree})
    assert in_specs[0]["w"] == P("data")
    assert in_specs[1]["m"]["w"] == P("data")
    assert out_specs[0]["w"] == P("data")        # params come OUT sharded
    assert out_specs[2] == P()                   # bank stays replicated
    with pytest.raises(ValueError, match="concrete params"):
        shd.train_step_specs(batch, host, param_sharding="fsdp")


def test_make_train_step_fsdp_validations():
    pol_q = make_policy("s2fp8_e4m3", gemm_mode="payload")
    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    scfg = statsbank.StatsConfig(refresh_every=64)
    with pytest.raises(ValueError, match="param_sharding"):
        make_train_step(mesh_toy.loss_fn, opt, sched, pol_q, stats=scfg,
                        param_sharding="zero3")
    # sharded params need a mesh to shard over
    with pytest.raises(ValueError, match="mesh"):
        make_train_step(mesh_toy.loss_fn, opt, sched, pol_q, stats=scfg,
                        param_sharding="fsdp")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fsdp_q streams payloads into the banked GEMMs: stats are mandatory
    with pytest.raises(ValueError, match="fsdp_q"):
        make_train_step(mesh_toy.loss_fn, opt, sched, pol_q, mesh=mesh,
                        param_sharding="fsdp_q")
    # ...and so is a payload-GEMM policy (fp32 can't even carry a bank)
    with pytest.raises(ValueError, match="s2fp8"):
        make_train_step(mesh_toy.loss_fn, opt, sched, make_policy("fp32"),
                        mesh=mesh, stats=scfg, param_sharding="fsdp_q")
    # plain fsdp (f32 gather) has no stats requirement
    make_train_step(mesh_toy.loss_fn, opt, sched, make_policy("fp32"),
                    mesh=mesh, param_sharding="fsdp")


def test_mesh1_toy_fsdp_modes_match_unsharded_bitwise():
    """Fast lane: both FSDP modes on a 1-device mesh reproduce the
    unsharded banked step bit for bit (gather/scatter are identities at
    axis size 1, and the payload round-trip is the same quantize the
    dense path runs)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s0, p0, o0, b0, _ = mesh_toy.setup(mesh=None)
    r0 = mesh_toy.run(s0, p0, o0, b0, 4)
    for mode in ("fsdp", "fsdp_q"):
        sm, pm, om, bm, _ = mesh_toy.setup(mesh=mesh, param_sharding=mode)
        rm = mesh_toy.run(sm, pm, om, bm, 4)
        _assert_trees_bitwise(rm[:3], r0[:3], f"{mode}-mesh1-vs-unsharded")
        assert float(rm[3]["loss"]) == float(r0[3]["loss"]), mode
    # fsdp composes with the compressed grad-sync route (trace + run)
    sc, pc, oc, bc, _ = mesh_toy.setup(mesh=mesh, grad_sync_mode="s2fp8",
                                       param_sharding="fsdp")
    rc = mesh_toy.run(sc, pc, oc, bc, 2)
    assert np.isfinite(float(rc[3]["loss"]))


def test_fsdp_steady_state_runs_zero_stats_reductions():
    """ISSUE 9 budget anchor: the fsdp_q banked step keeps the
    steady-state stats-reduction budget at the sharded fp32 baseline + 1
    — quantize-at-owner reuses the bank's cadence, and the payload
    all-gather / grad reduce-scatter legs are collectives, not
    reductions."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64)
    jx_q = _toy_sharded_jaxpr(mesh, pol, scfg, param_sharding="fsdp_q")
    jx_fp32 = _toy_sharded_jaxpr(mesh, make_policy("fp32"), None,
                                 param_sharding="fsdp")
    n_q = statsbank.count_reductions(jx_q, include_cond=False)
    n_fp32 = statsbank.count_reductions(jx_fp32, include_cond=False)
    assert n_q == n_fp32 + 1, (n_q, n_fp32)


def test_fsdp_q_gathers_payloads_only():
    """ISSUE 9 jaxpr anchor: in fsdp_q mode NO f32/bf16 all-gather of a
    payload-eligible param leaf exists — the only full-leaf-size gather
    moves 1-byte payloads.  Plain fsdp shows the f32 gather."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64)
    w = mesh_toy.make_params()["w"]
    leaf_size = int(np.prod(w.shape))            # 8*16 = 128

    def gathers(jx):
        eqns = _collect_eqns(jx, [])
        out = {"wide": [], "byte": []}
        for e in eqns:
            if e.primitive.name != "all_gather":
                continue
            for v in e.outvars:
                if int(np.prod(v.aval.shape)) < leaf_size:
                    continue
                if v.aval.dtype in (jnp.float32, jnp.bfloat16,
                                    jnp.float16):
                    out["wide"].append(e)
                elif v.aval.dtype.itemsize == 1:
                    out["byte"].append(e)
        return out

    g_q = gathers(_toy_sharded_jaxpr(mesh, pol, scfg,
                                     param_sharding="fsdp_q"))
    assert not g_q["wide"], [str(e) for e in g_q["wide"]]
    assert g_q["byte"], "fsdp_q must all-gather the 1-byte payload"

    g_f = gathers(_toy_sharded_jaxpr(mesh, pol, scfg, param_sharding="fsdp"))
    assert g_f["wide"], "plain fsdp should all-gather the f32 leaf"


def test_fsdp8_inline_bitwise_when_devices_allow():
    """Runs 8-way in the CI fsdp lane (XLA host-device override); on a
    single-device tier-1 run it degrades to the 1-device parity check."""
    n = len(jax.devices())
    n = 8 if n >= 8 else 1
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    s0, p0, o0, b0, _ = mesh_toy.setup(mesh=None)
    r0 = mesh_toy.run(s0, p0, o0, b0, 4)
    for mode in ("fsdp", "fsdp_q"):
        sm, pm, om, bm, _ = mesh_toy.setup(mesh=mesh, param_sharding=mode)
        rm = mesh_toy.run(sm, pm, om, bm, 4)
        _assert_trees_bitwise(rm[:3], r0[:3], f"{mode}-mesh{n}")
        if n > 1:  # updates really ran shard-local (ZeRO-3)
            spec = rm[0]["w"].sharding.spec
            assert tuple(spec) == ("data",), spec


_FSDP8_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
import mesh_toy
from repro.checkpoint.manager import CheckpointManager

ckdir = os.environ["FSDP_CKDIR"]
out = {}
mesh = jax.make_mesh((8, 1), ("data", "model"))

def bitwise(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

s1, p1, o1, b1, _ = mesh_toy.setup(mesh=None)
ref = mesh_toy.run(s1, p1, o1, b1, 6)

# --- 8-way FSDP (f32 gather): bitwise vs 1-device, sharded mid-run save ----
s8, p8, o8, b8, _ = mesh_toy.setup(mesh=mesh, param_sharding="fsdp")
pa, oa, ba = p8, o8, b8
ck = CheckpointManager(ckdir)
for s in range(6):
    pa, oa, ba, ma = s8(pa, oa, ba, mesh_toy.make_batch(s), jnp.int32(s))
    if s == 2:   # leaves live SHARDED over 8 devices at save time
        out["save_spec_is_fsdp"] = tuple(pa["w"].sharding.spec) == ("data",)
        ck.save(3, (pa, oa, ba))
out["fsdp8_bitwise"] = bitwise((pa, oa, ba), ref[:3])
out["fsdp8_loss_bitwise"] = float(ma["loss"]) == float(ref[3]["loss"])
out["out_spec_is_fsdp"] = tuple(pa["w"].sharding.spec) == ("data",)

# --- 8-way FSDP-Q (payload streaming): bitwise vs 1-device -----------------
sq, pq, oq, bq, _ = mesh_toy.setup(mesh=mesh, param_sharding="fsdp_q")
rq = mesh_toy.run(sq, pq, oq, bq, 6)
out["fsdp_q8_bitwise"] = bitwise(rq[:3], ref[:3])
out["fsdp_q8_loss_bitwise"] = float(rq[3]["loss"]) == float(ref[3]["loss"])
print("RESULT " + json.dumps(out))
"""

_FSDP_RESTORE_SCRIPT = r"""
import os, sys, json
n = int(os.environ["FSDP_DEVICES"])
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % n
import jax, jax.numpy as jnp
import numpy as np
import mesh_toy
from repro.checkpoint.manager import CheckpointManager

ckdir = os.environ["FSDP_CKDIR"]
out = {}

def bitwise(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

s1, p1, o1, b1, _ = mesh_toy.setup(mesh=None)
ref6 = mesh_toy.run(s1, p1, o1, b1, 6)
ref3 = mesh_toy.run(s1, p1, o1, b1, 3)

template = jax.tree_util.tree_map(
    lambda x: np.zeros_like(np.asarray(x)), (p1, o1, b1))
(rp, ro, rb), start = CheckpointManager(ckdir).restore(template)
out["restore_step"] = start
# the 8-device sharded save restores bit-exact on this topology
out["restored_bitwise"] = bitwise((rp, ro, rb), ref3[:3])

# continue under THIS topology's FSDP mesh to step 6
if n > 1:
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    sn, _, _, _, _ = mesh_toy.setup(mesh=mesh, param_sharding="fsdp")
else:
    sn = s1
cont = mesh_toy.run(sn, rp, ro, rb, 6, start=3)
out["resume_bitwise"] = bitwise(cont[:3], ref6[:3])
print("RESULT " + json.dumps(out))
"""


_FSDP_Q_TRANSFORMER_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced_config
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step

cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False,
                                               vocab=64)
pol = make_policy("s2fp8", gemm_mode="payload")
params = tlm.init_lm(cfg, jax.random.PRNGKey(0))
opt = optimizers.adamw()
sched = schedules.constant(3e-3)
table = synthetic.make_markov_table(0, cfg.vocab)

def loss_fn(p, b, pol_):
    return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

def data_fn(s):
    return synthetic.lm_batch(0, s, 8, 64, cfg.vocab, table)

scfg = statsbank.StatsConfig(refresh_every=4)
bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, scfg)
mesh = jax.make_mesh((8, 1), ("data", "model"))

def run(step, n):
    p, o, b = params, opt.init(params), bank
    losses = []
    for s in range(n):
        p, o, b, m = step(p, o, b, data_fn(s), jnp.int32(s))
        losses.append(float(m["loss"]))
    return p, losses

# the real model (tied embeddings -> .T fallback, scan-stacked ineligible
# leaves, flash attention) under 8-way fsdp_q vs the 1-device dense step:
# sharded reduce-scatter sums reorder, so tolerance not bitwise
step_q = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg,
                                 mesh=mesh, param_sharding="fsdp_q"))
step_1 = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=scfg))
pq, losses_q = run(step_q, 10)
p1, losses_1 = run(step_1, 10)

# payload-eligible leaves really live sharded
emb = pq["embed"]
rel = []
for a, b in zip(jax.tree_util.tree_leaves(pq), jax.tree_util.tree_leaves(p1)):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    denom = np.abs(b)
    nz = denom > 1e-12
    if nz.any():
        rel.append(np.median(np.abs(a - b)[nz] / denom[nz]))
out = {
    "embed_sharded": tuple(emb.sharding.spec) == ("data",),
    "median_param_rel": float(np.median(rel)),
    "loss_first": losses_q[0], "loss_last": losses_q[-1],
    "loss_gap_last": abs(losses_q[-1] - losses_1[-1]) / abs(losses_1[-1]),
}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_fsdp_q_transformer_tolerance_and_convergence():
    """The real model under 8-way fsdp_q: payload-eligible leaves stay
    sharded, the run tracks the 1-device dense step, and it converges."""
    proc = subprocess.run([sys.executable, "-c",
                           _FSDP_Q_TRANSFORMER_SCRIPT],
                          env=_subprocess_env(), capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["embed_sharded"] is True, out
    assert out["median_param_rel"] < 0.05, out
    assert out["loss_gap_last"] < 0.15, out
    # 10 steps on the reduced config: ~17% off the start (the 12-step
    # s2fp8-sync smoke reaches 20%; this lane's job is tracking, above)
    assert out["loss_last"] < out["loss_first"] * 0.9, out


@pytest.mark.slow
def test_fsdp8_save_restores_on_other_topologies(tmp_path):
    """ISSUE 9 acceptance: a sharded checkpoint written by an 8-device
    FSDP run restores bit-exact (params + opt + bank) on 1- and 4-device
    topologies and continues to the same final state."""
    env = _subprocess_env()
    env["FSDP_CKDIR"] = str(tmp_path)
    proc = subprocess.run([sys.executable, "-c", _FSDP8_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert all(v is True for v in out.values()), out

    for n in (1, 4):
        env["FSDP_DEVICES"] = str(n)
        proc = subprocess.run([sys.executable, "-c", _FSDP_RESTORE_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        assert proc.returncode == 0, f"n={n}: " + proc.stderr[-3000:]
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][0]
        out = json.loads(line[len("RESULT "):])
        assert out["restore_step"] == 3, (n, out)
        assert out["restored_bitwise"] is True, (n, out)
        assert out["resume_bitwise"] is True, (n, out)
