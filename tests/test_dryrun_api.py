"""Dry-run machinery: param/batch/cache structs, pspec rules with
divisibility guards, and a reduced-config multi-device lower+compile
(subprocess: needs its own XLA device-count flag)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced_config
from repro.configs.base import SHAPE_SPECS
from repro.launch import api

jax.config.update("jax_platform_name", "cpu")

SIZES = {"data": 16, "model": 16}


def test_param_struct_no_allocation_1t_model():
    """eval_shape of the 1T-param Kimi config must be instant and abstract."""
    cfg = get_config("kimi_k2_1t_a32b")
    struct = api.param_struct(cfg)
    leaves = jax.tree_util.tree_leaves(struct)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    import math
    total = sum(math.prod(l.shape) for l in leaves)
    assert total > 0.9e12            # ~1T params


def test_analytic_param_counts_sane():
    # published totals (order-of-magnitude sanity, exact configs vary)
    for arch, lo, hi in [("minicpm_2b", 2e9, 4e9),
                         ("stablelm_12b", 10e9, 14e9),
                         ("nemotron_4_340b", 300e9, 380e9),
                         ("deepseek_moe_16b", 14e9, 20e9),
                         ("kimi_k2_1t_a32b", 0.8e12, 1.3e12),
                         ("falcon_mamba_7b", 6e9, 9e9),
                         ("chameleon_34b", 30e9, 38e9)]:
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)


def test_moe_active_params_much_smaller():
    cfg = get_config("kimi_k2_1t_a32b")
    assert cfg.n_active_params() < 0.06 * cfg.n_params()


def test_param_pspecs_guard_non_divisible_heads():
    """minicpm has 36 heads -> 36*64=2304 q-projection divides 16 so the
    weight shards; gemma3 kv=1 -> kv projection (256) divides too; but a
    7-wide dim must fall back to replicated."""
    cfg = get_reduced_config("minicpm_2b")
    struct = api.param_struct(cfg)
    specs = api.param_pspecs(cfg, struct, SIZES)
    flat = jax.tree_util.tree_leaves_with_path(specs.get("head", {})) \
        if isinstance(specs, dict) else []
    # direct check on a known leaf: embed [512, 128] -> both divide 16
    embed_spec = specs["embed"]
    assert embed_spec == P("model", "data")


def test_param_pspecs_full_configs():
    for arch in ["stablelm_12b", "kimi_k2_1t_a32b", "falcon_mamba_7b",
                 "whisper_medium"]:
        cfg = get_config(arch)
        struct = api.param_struct(cfg)
        specs = api.param_pspecs(cfg, struct, SIZES)
        # every leaf got a PartitionSpec and dims divide
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(struct),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            assert isinstance(spec, P)
            for dim, entry in zip(leaf.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = 1
                for a in axes:
                    prod *= SIZES.get(a, 1)
                assert dim % prod == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("shape", list(SHAPE_SPECS))
def test_batch_structs_all_shapes(shape):
    for arch in ["minicpm_2b", "whisper_medium"]:
        cfg = get_config(arch)
        b = api.batch_struct(cfg, shape)
        seq, gbs, kind = SHAPE_SPECS[shape]
        leaves = jax.tree_util.tree_leaves(b)
        assert all(l.shape[0] == gbs for l in leaves)


def test_cache_struct_decode_shapes():
    cfg = get_config("kimi_k2_1t_a32b")
    c = api.cache_struct(cfg, "decode_32k")
    leaves = jax.tree_util.tree_leaves(c)
    assert any(l.shape[-2] == 32768 for l in leaves)        # KV seq axis
    cfg2 = get_config("falcon_mamba_7b")
    c2 = api.cache_struct(cfg2, "long_500k")
    # mamba caches are O(1) in sequence length
    assert all(l.shape[-1] <= 16_384 for l in jax.tree_util.tree_leaves(c2))


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.launch import api
from repro.parallel import sharding as shd

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
out = {}
for arch in ["minicpm_2b", "deepseek_moe_16b", "falcon_mamba_7b", "gemma3_1b"]:
    cfg = get_reduced_config(arch)
    pol = make_policy("s2fp8")
    pstruct = api.param_struct(cfg)
    pspecs = api.param_pspecs(cfg, pstruct, sizes)
    bstruct = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    bspecs = api.batch_pspecs(bstruct, sizes)
    sh = lambda specs: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
    with mesh, shd.use_rules(shd.TRAIN_RULES, sizes):
        step_fn, opt = api.make_train_step(cfg, pol)
        ostruct = jax.eval_shape(opt.init, pstruct)
        from repro.optim.optimizers import OptState
        ospecs = OptState(P(), api.param_pspecs(cfg, ostruct.m, sizes),
                          api.param_pspecs(cfg, ostruct.v, sizes))
        compiled = jax.jit(step_fn, in_shardings=(sh(pspecs), sh(ospecs),
                                                  sh(bspecs), None)) \
            .lower(pstruct, ostruct, bstruct, jnp.int32(0)).compile()
        out[arch] = "ok"
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_lower_compile_reduced():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=580)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert all(v == "ok" for v in out.values()), out
