"""S2FP8-compressed gradient collectives: numerics on a multi-device
(host-platform) mesh — runs in a subprocess so the 8-device XLA_FLAGS never
leaks into other tests' device state."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.core.collectives import compressed_grad_sync, compressed_allreduce_1d

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)

# gradients at a scale raw-FP8 would flush entirely
g_big = jax.random.normal(key, (1 << 17,)) * 1e-7
g_small = jax.random.normal(jax.random.fold_in(key, 1), (100,)) * 1e-7

out = {}

# 1-D compressed allreduce == plain sum within S2FP8 tolerance
res = jax.jit(lambda g: compressed_allreduce_1d(g, mesh, "data"))(g_big)
# every device holds a replicated copy of g; allreduce sums 8 copies
expect = np.asarray(g_big) * 8.0
r = np.asarray(res)
nz = r != 0
rel = np.abs(r[nz] - expect[nz]) / np.abs(expect[nz])
out["allreduce_median_rel"] = float(np.median(rel))
out["allreduce_frac_nz"] = float(nz.mean())

# tree sync: big leaf compressed, small leaf plain; result ~= mean == g
grads = {"big": g_big, "small": g_small}
synced = jax.jit(lambda g: compressed_grad_sync(g, mesh, "data"))(grads)
sb = np.asarray(synced["big"]); eb = np.asarray(g_big)
nzb = sb != 0
out["sync_big_median_rel"] = float(np.median(np.abs(sb[nzb] - eb[nzb]) / np.abs(eb[nzb])))
ss = np.asarray(synced["small"]); es = np.asarray(g_small)
out["sync_small_max_rel"] = float(np.max(np.abs(ss - es) / (np.abs(es) + 1e-30)))
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_compressed_collectives_numerics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    # reduce-scatter runs in bf16, gather leg in S2FP8: ~1% typical error
    assert out["allreduce_median_rel"] < 0.05
    assert out["allreduce_frac_nz"] > 0.9
    assert out["sync_big_median_rel"] < 0.05
    # small leaves take the plain f32 path: near-exact
    assert out["sync_small_max_rel"] < 1e-2
