"""StatsBank: jit-carried, sharded, checkpointable per-tensor statistics.

Covers the PR-2 acceptance criteria:
  * a jitted train step with StatsBank enabled performs ZERO stats
    reductions on non-refresh steps (jaxpr inspection: every reduction
    introduced by the numerics sits inside a ``lax.cond`` branch);
  * delayed-stats training converges within tolerance of exact-stats;
  * the bank survives a checkpoint save/restore cycle bit-exactly
    (including under compress=True) and TrainLoop resumes with warm stats;
  * global (shard_map) stats refresh matches the single-device bank
    bit-for-bit (subprocess test, power-of-two data so reductions are
    order-exact);
  * the DelayedStatsCache shim delegates to HostStatsBank and warns.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced_config
from repro.core import backend as nbackend
from repro.core import collectives, s2fp8, statsbank
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import TrainLoop, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _tiny_setup(n_layers=2, remat=False, seed=0):
    cfg = get_reduced_config("minicpm_2b").replace(
        n_layers=n_layers, remat=remat, vocab=64)
    pol = make_policy("s2fp8")
    params = tlm.init_lm(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw()
    sched = schedules.constant(3e-3)
    table = synthetic.make_markov_table(seed, cfg.vocab)

    def loss_fn(p, batch, pol_):
        return tlm.loss_fn(p, batch["tokens"], batch["labels"], cfg, pol_)

    def data_fn(s):
        return synthetic.lm_batch(seed, s, 8, 64, cfg.vocab, table)

    return cfg, pol, params, opt, sched, loss_fn, data_fn


# ---------------------------------------------------------------------------
# discovery + bank structure
# ---------------------------------------------------------------------------

def test_init_bank_discovers_sites_and_stacks_segments():
    _, pol, params, _, _, loss_fn, data_fn = _tiny_setup()
    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)
    # global sites are scalars; scanned-segment sites are [L]-stacked
    assert any(k.startswith("embed/") for k in bank)
    assert any(k.startswith("head/") for k in bank)
    seg_keys = [k for k in bank if k.startswith("seg0:dense/")]
    assert seg_keys, sorted(bank)
    for k in seg_keys:
        assert bank[k]["fwd"]["alpha"].shape == (2,), k
    assert bank["head/t0"]["bwd"]["last"].shape == ()
    # every entry bootstraps with identity stats and last = -1
    for entry in bank.values():
        for d in entry.values():
            assert float(jnp.min(d["last"])) == -1.0
            assert float(jnp.max(jnp.abs(d["alpha"] - 1.0))) == 0.0
    # named scopes from models/blocks.py show up in the keys
    assert any("/attn/" in k for k in seg_keys)
    assert any("/mlp/" in k for k in seg_keys)


def test_init_bank_rejects_numerics_free_policy():
    _, _, params, _, _, loss_fn, data_fn = _tiny_setup()
    with pytest.raises(ValueError, match="no truncation sites"):
        statsbank.init_bank(loss_fn, params, data_fn(0), make_policy("fp32"))


def test_make_train_step_validates_policy_mode():
    _, _, _, opt, sched, loss_fn, _ = _tiny_setup()
    with pytest.raises(ValueError, match="s2fp8"):
        make_train_step(loss_fn, opt, sched, make_policy("fp32"),
                        stats=statsbank.StatsConfig())


def test_stats_config_validation():
    with pytest.raises(ValueError):
        statsbank.StatsConfig(refresh_every=0)
    with pytest.raises(ValueError):
        statsbank.StatsConfig(ema_decay=1.0)


# ---------------------------------------------------------------------------
# delayed-stats numerics: in-jit bank vs exact stats over a convergence run
# ---------------------------------------------------------------------------

def test_bank_training_tracks_exact_stats():
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup()
    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)

    bank_step = jax.jit(make_train_step(loss_fn, opt, sched, pol,
                                        stats=cfg_s))
    exact_step = jax.jit(make_train_step(loss_fn, opt, sched, pol))

    pb, sb = params, opt.init(params)
    pe, se = params, opt.init(params)
    lb, le = [], []
    for s in range(16):
        batch = data_fn(s)
        pb, sb, bank, mb = bank_step(pb, sb, bank, batch, jnp.int32(s))
        pe, se, me = exact_step(pe, se, batch, jnp.int32(s))
        lb.append(float(mb["loss"]))
        le.append(float(me["loss"]))
    assert all(np.isfinite(lb)), lb
    # step 0 bootstraps fresh stats (refresh-then-use): no identity-stats
    # flush-to-zero catastrophe on the first step
    assert abs(lb[0] - le[0]) / le[0] < 0.01, (lb[0], le[0])
    # training converges, and stays within tolerance of the exact run
    assert lb[-1] < lb[0] * 0.85, lb
    assert abs(lb[-1] - le[-1]) / le[-1] < 0.10, (lb[-1], le[-1])
    # bank refreshed on cadence: last-refresh of every site is step 12
    lasts = {float(jnp.max(e[d]["last"]))
             for e in bank.values() for d in e}
    assert lasts == {12.0}, lasts


def test_refresh_every_one_matches_exact_closely():
    """k=1 refreshes every step — the bank path degenerates to fresh stats
    and must sit on top of the exact-stats run.  (Tolerance, not bitwise:
    the two programs fuse the stats epilogue differently, and 1-ulp stat
    shifts move a handful of RNE roundings per step.)"""
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup()
    cfg_s = statsbank.StatsConfig(refresh_every=1)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)
    bank_step = jax.jit(make_train_step(loss_fn, opt, sched, pol,
                                        stats=cfg_s))
    exact_step = jax.jit(make_train_step(loss_fn, opt, sched, pol))
    pb, sb = params, opt.init(params)
    pe, se = params, opt.init(params)
    for s in range(4):
        batch = data_fn(s)
        pb, sb, bank, mb = bank_step(pb, sb, bank, batch, jnp.int32(s))
        pe, se, me = exact_step(pe, se, batch, jnp.int32(s))
        np.testing.assert_allclose(float(mb["loss"]), float(me["loss"]),
                                   rtol=5e-3)


def test_bank_step_with_remat_and_ema():
    """scan + jax.checkpoint remat + EMA moments: the cotangent-carried
    bank composes with rematerialization."""
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup(remat=True)
    cfg_s = statsbank.StatsConfig(refresh_every=2, ema_decay=0.5)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)
    step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=cfg_s))
    p, st = params, opt.init(params)
    for s in range(5):
        p, st, bank, m = step(p, st, bank, data_fn(s), jnp.int32(s))
        assert np.isfinite(float(m["loss"])), s
    # EMA folded at least twice -> moments are mixes, last advanced
    st0 = bank["head/t0"]["fwd"]
    assert float(st0["last"]) == 4.0
    assert np.isfinite(float(st0["ema_mu"]))


def test_encdec_bank_single_step():
    """Enc-dec model: encoder scan, per-layer cross-KV map and decoder
    scan all thread their segment sites."""
    from repro.configs import get_config
    from repro.models import encdec
    cfg = get_config("transformer_tiny").replace(vocab=64)
    pol = make_policy("s2fp8")
    params = encdec.init_encdec(cfg, jax.random.PRNGKey(0))

    def loss_fn(p, b, pol_):
        return encdec.loss_fn(p, b["enc_tokens"], b["dec_tokens"],
                              b["dec_labels"], cfg, pol_)

    batch = synthetic.seq2seq_batch(0, 0, 4, 8, 8, cfg.vocab)
    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, params, batch, pol, cfg_s)
    assert any(k.startswith("enc/") for k in bank)
    assert any(k.startswith("dec/") for k in bank)
    assert any(k.startswith("xkv/") for k in bank)
    opt = optimizers.adamw()
    step = jax.jit(make_train_step(loss_fn, opt, schedules.constant(1e-3),
                                   pol, stats=cfg_s))
    p, st = params, opt.init(params)
    p, st, bank, m = step(p, st, bank, batch, jnp.int32(0))
    assert np.isfinite(float(m["loss"]))
    assert float(bank["dec/t0"]["fwd"]["last"].max()) == 0.0


# ---------------------------------------------------------------------------
# acceptance: zero stats reductions on non-refresh steps (jaxpr inspection)
# ---------------------------------------------------------------------------

def test_zero_stats_reductions_outside_cond():
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup()
    batch = data_fn(0)
    ost = opt.init(params)
    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, params, batch, pol, cfg_s)

    jx_bank = jax.make_jaxpr(
        make_train_step(loss_fn, opt, sched, pol, stats=cfg_s))(
        params, ost, bank, batch, jnp.int32(0))
    jx_exact = jax.make_jaxpr(
        make_train_step(loss_fn, opt, sched, pol))(
        params, ost, batch, jnp.int32(0))
    jx_fp32 = jax.make_jaxpr(
        make_train_step(loss_fn, opt, sched, make_policy("fp32")))(
        params, ost, batch, jnp.int32(0))

    n_bank = statsbank.count_reductions(jx_bank, include_cond=False)
    n_bank_all = statsbank.count_reductions(jx_bank, include_cond=True)
    n_exact = statsbank.count_reductions(jx_exact, include_cond=False)
    n_fp32 = statsbank.count_reductions(jx_fp32, include_cond=False)

    # Outside lax.cond branches the bank step runs EXACTLY the reductions
    # of the numerics-free baseline plus ONE O(n_sites) bookkeeping min
    # (the stats_refreshed metric over the concatenated last-refresh
    # scalars): zero TENSOR stats reductions on non-refresh steps.  The
    # Eq. 3-4 reductions exist, but only inside cond branches.
    assert n_bank == n_fp32 + 1, (n_bank, n_fp32)
    assert n_exact > n_bank, (n_exact, n_bank)
    assert n_bank_all > n_bank, (n_bank_all, n_bank)


# ---------------------------------------------------------------------------
# checkpoint round-trip + warm-stats resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compress", [False, True])
def test_bank_checkpoint_roundtrip_bitexact(tmp_path, compress):
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup()
    cfg_s = statsbank.StatsConfig(refresh_every=2)
    bank = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)
    step = jax.jit(make_train_step(loss_fn, opt, sched, pol, stats=cfg_s))
    p, st = params, opt.init(params)
    for s in range(3):
        p, st, bank, _ = step(p, st, bank, data_fn(s), jnp.int32(s))

    ck = CheckpointManager(str(tmp_path / f"c{compress}"), compress=compress)
    big = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 1e-5
    ck.save(3, (bank, {"w": big}))
    template = (jax.tree_util.tree_map(jnp.zeros_like, bank),
                {"w": jnp.zeros_like(big)})
    (restored, _), _ = ck.restore(template)
    # every (alpha, beta, ema_mu, ema_m, last) leaf identical, bit for bit
    for a, b in zip(jax.tree_util.tree_leaves(bank),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if compress:
        # the big leaf still went through s2fp8 compression
        d = tmp_path / "cTrue" / "step_0000000003"
        assert any(f.endswith("payload.npy") for f in os.listdir(d))


def test_trainloop_resumes_with_warm_stats(tmp_path):
    _, pol, params, opt, sched, loss_fn, data_fn = _tiny_setup()
    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank0 = statsbank.init_bank(loss_fn, params, data_fn(0), pol, cfg_s)
    step = make_train_step(loss_fn, opt, sched, pol, stats=cfg_s)

    ck = CheckpointManager(str(tmp_path))
    loop = TrainLoop(step, params, opt.init(params), data_fn,
                     ckpt_manager=ck, ckpt_every=3, log_every=0,
                     stats_bank=bank0)
    loop.run(6)
    warm = loop.stats_bank
    assert ck.latest_step() == 6

    loop2 = TrainLoop(step, params, opt.init(params), data_fn,
                      ckpt_manager=ck, ckpt_every=3, log_every=0,
                      stats_bank=statsbank.init_bank(
                          loss_fn, params, data_fn(0), pol, cfg_s))
    loop2.maybe_resume()
    assert loop2.start_step == 6
    # the restored bank is the warm one, not a cold re-init
    for a, b in zip(jax.tree_util.tree_leaves(warm),
                    jax.tree_util.tree_leaves(loop2.stats_bank)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(loop2.stats_bank["head/t0"]["fwd"]["last"]) >= 0.0


# ---------------------------------------------------------------------------
# host bank + deprecation shim
# ---------------------------------------------------------------------------

def test_host_stats_bank_cadence_and_numerics():
    hb = statsbank.HostStatsBank(backend="ref", refresh_every=4)
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-6
    be = nbackend.get_backend("ref")
    y0 = hb.truncate(x, "g", 0)
    # refresh-then-use: step 0 output == exact truncation
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(be.truncate(x)))
    # steps 1..3 reuse step-0 stats
    st0 = dict(hb.bank["g"])
    x1 = x * 1.01
    y1 = hb.truncate(x1, "g", 3)
    np.testing.assert_array_equal(
        np.asarray(y1),
        np.asarray(be.truncate(x1, stats=(st0["alpha"], st0["beta"]))))
    assert float(hb.bank["g"]["last"]) == 0.0
    hb.truncate(x1, "g", 4)
    assert float(hb.bank["g"]["last"]) == 4.0
    # quantize path shares the bank
    t = hb.quantize(x1, "g", 5)
    assert float(t.alpha) == float(hb.bank["g"]["alpha"])
    hb.clear()
    assert not hb.bank


def test_delayed_stats_cache_is_deprecated_shim():
    with pytest.warns(DeprecationWarning):
        cache = nbackend.DelayedStatsCache(backend="ref", refresh_every=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 1e-5
    outs = [cache.truncate(x * (1 + 0.001 * i), "g", i) for i in range(9)]
    assert all(np.isfinite(np.asarray(o)).all() for o in outs)
    assert cache._last_refresh["g"] == 8
    assert "g" in cache._stats
    cache.clear()
    assert cache._stats == {}


def test_zero_bootstrap_does_not_poison_ema():
    """A bootstrap refresh that sees only zeros must leave the site in
    bootstrap state (last = -1, identity stats); the first refresh with
    real data then seeds the EMA from the fresh moments instead of mixing
    in the placeholder zeros."""
    st0 = statsbank.init_site_state()
    st1 = statsbank.refresh_state(jnp.zeros((32,)), st0, jnp.float32(0.0),
                                  ema_decay=0.9, backend="ref")
    assert float(st1["last"]) == -1.0
    assert float(st1["alpha"]) == 1.0 and float(st1["beta"]) == 0.0
    st2 = statsbank.refresh_state(jnp.full((32,), 1024.0), st1,
                                  jnp.float32(5.0), ema_decay=0.9,
                                  backend="ref")
    # d = 0 on the true first refresh: ema seeded at the fresh moments
    assert abs(float(st2["ema_mu"]) - 10.0) < 1e-6
    assert abs(float(st2["ema_m"]) - 10.0) < 1e-6
    assert float(st2["last"]) == 5.0


def test_host_bank_ema_mixing():
    hb = statsbank.HostStatsBank(backend="ref", refresh_every=1,
                                 ema_decay=0.5)
    x = jnp.full((64,), 4.0)          # log2 moments: mu = m = 2
    hb.truncate(x, "w", 0)
    assert abs(float(hb.bank["w"]["ema_m"]) - 2.0) < 1e-6
    hb.truncate(x * 4.0, "w", 1)      # fresh m = 4 -> ema 0.5*2 + 0.5*4 = 3
    assert abs(float(hb.bank["w"]["ema_m"]) - 3.0) < 1e-6


def test_qdot_consumes_bank_entries():
    """Payload-domain GEMM inside a session: operand quantization reuses
    the bank's (alpha, beta) — no per-call stats reduction."""
    pol = make_policy("s2fp8", backend="ref")
    a = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 1e-6
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 16)) * 1e-6

    def loss_fn(p, batch, pol_):
        return jnp.sum(pol_.qdot(batch, p["w"]) ** 2), {}

    cfg_s = statsbank.StatsConfig(refresh_every=4)
    bank = statsbank.init_bank(loss_fn, {"w": w}, a, pol, cfg_s)
    qkeys = [k for k in bank if k.startswith("q")]
    assert len(qkeys) == 2, sorted(bank)
    # operand-stats entries are read-only: forward state only
    assert set(bank[qkeys[0]]) == {"fwd"}
    # warm the entries, then the in-session qdot must equal the exact one
    # (warm bank stats == fresh stats; the output-truncation site
    # bootstrap-refreshes, so it too uses fresh stats)
    for key, x in zip(sorted(qkeys), (a, w)):
        st = statsbank.refresh_state(x, statsbank.init_site_state(),
                                     jnp.float32(0.0), backend="ref")
        bank[key]["fwd"] = st
    with statsbank.bind(bank, 1, cfg_s):
        y = pol.qdot(a, w)
    exact = pol.qdot(a, w)        # no session: per-call exact stats
    np.testing.assert_allclose(np.asarray(y), np.asarray(exact),
                               rtol=1e-5, atol=1e-30)

    # under a differentiated (banked train) step, the read-only q-entries
    # must come through UNCHANGED — not overwritten by the mathematical
    # dLoss/dalpha cotangent (reads are gradient-stopped + merge_updates)
    opt = optimizers.adamw()
    step = jax.jit(make_train_step(loss_fn, opt, schedules.constant(1e-3),
                                   pol, stats=cfg_s))
    warm = {k: jax.tree_util.tree_map(jnp.asarray, bank[k]) for k in qkeys}
    _, _, bank2, m = step({"w": w}, opt.init({"w": w}), bank, a, jnp.int32(1))
    assert np.isfinite(float(m["loss"]))
    for k in qkeys:
        for f in statsbank.STATE_FIELDS:
            np.testing.assert_array_equal(np.asarray(bank2[k]["fwd"][f]),
                                          np.asarray(warm[k]["fwd"][f]))
    # while the truncation site's entry did refresh
    tkey = [k for k in bank if k not in qkeys][0]
    assert float(bank2[tkey]["fwd"]["last"]) == 1.0


# ---------------------------------------------------------------------------
# collectives through the backend registry (satellite)
# ---------------------------------------------------------------------------

def test_collectives_encode_decode_route_through_backend():
    x = jax.random.normal(jax.random.PRNGKey(2), (4096,)) * 1e-6
    payload, alpha, beta = collectives._encode_local(x, backend="ref")
    t = s2fp8.quantize(x)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(t.payload))
    np.testing.assert_array_equal(np.asarray(alpha), np.asarray(t.alpha))
    dec = collectives._decode_local(payload, alpha, beta, backend="ref")
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(s2fp8.dequantize(t)))
    with pytest.raises(KeyError):
        collectives._encode_local(x, backend="no-such-backend")


# ---------------------------------------------------------------------------
# sharded stats: global refresh == single-device bank, bit for bit
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import backend as nbackend
from repro.core import statsbank

mesh = jax.make_mesh((8,), ("data",))

# power-of-two magnitudes: log2 values are small integers, so the f32
# sum/max reductions are order-exact -> sharded == monolithic, bitwise
key = jax.random.PRNGKey(0)
exps = jax.random.randint(key, (8 * 2048,), -8, 9).astype(jnp.float32)
signs = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1),
                                       shape=exps.shape), 1.0, -1.0)
x = signs * (2.0 ** exps)

be = nbackend.get_backend("ref")
out = {}

# 1) backend.compute_stats: global (axis_name) vs single-device
a1, b1 = be.compute_stats(x)

def stats_body(xl):
    a, b = be.compute_stats(xl, axis_name="data")
    return a[None], b[None]

a2, b2 = shard_map(stats_body, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"), check_rep=False)(x)
out["stats_alpha_bitwise"] = bool((np.asarray(a2) == float(a1)).all())
out["stats_beta_bitwise"] = bool((np.asarray(b2) == float(b1)).all())

# 2) full bank refresh: refresh_state global vs single-device
st0 = statsbank.init_site_state()
ref_st = statsbank.refresh_state(x, st0, jnp.float32(7.0), backend="ref")

def refresh_body(xl):
    st = statsbank.refresh_state(xl, statsbank.init_site_state(),
                                 jnp.float32(7.0), backend="ref",
                                 axis_name="data")
    return {k: v[None] for k, v in st.items()}

sh_st = shard_map(refresh_body, mesh=mesh, in_specs=P("data"),
                  out_specs=P("data"), check_rep=False)(x)
for k in statsbank.STATE_FIELDS:
    out[f"refresh_{k}_bitwise"] = bool(
        (np.asarray(sh_st[k]) == float(ref_st[k])).all())
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_global_stats_refresh_matches_single_device_bitwise():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert all(out.values()), out
