"""Order-exact toy problem for mesh-vs-single-device BITWISE parity.

Floating-point summation does not commute with sharding: a data-parallel
step sums weight-gradient contractions per shard and psums the partials,
while a single device reduces the whole batch in one GEMM — generically a
1-ulp difference.  This toy is engineered so every cross-shard reduction
is EXACT in f32, making the sharded and single-device pipelines agree bit
for bit (the same trick as test_statsbank's power-of-two shard test, but
for a full banked payload train step):

  * ``x`` [B, K] one-hot rows (hot column ``(b + step) % K``, sign ±1) —
    every forward/backward contraction over the batch or feature axes is
    a single-term or disjoint-support sum;
  * ``w`` [K, n] one-hot rows of magnitude 2^-3 — constant log2 magnitude,
    so every StatsBank site bootstraps into the DEGENERATE stats branch
    (alpha=1, beta = target - m): the Eq. 5 truncation is an exact fixed
    point on these values and the refresh reductions sum small integers;
  * targets ``t`` ±1 dense, batch-mean linear loss => the cotangent is
    t / global_batch — constant magnitude again;
  * the policy is ``s2fp8_e4m3``: its forward image pins at 2^8, where
    XLA CPU's log2/exp2 are exact on powers of two — the e5m2 target 2^15
    is the ONE value where they are not (log2(32768) = 14.999999...), and
    that 1-ulp wiggle would leak full-mantissa values into the
    order-sensitive mean-of-logs reduction.

With ``refresh_every`` > the tested horizon only the bootstrap refresh
(step 0, all-exact tensors) runs; later steps are reduction-free outside
``lax.cond`` and every remaining sum (one-hot GEMMs, psums of
disjoint-support partials, the clip norm over constant-magnitude grads)
is exact integer arithmetic scaled by powers of two.
"""
import numpy as np

import jax
import jax.numpy as jnp

B = 8          # global batch == K so x's one-hot rows are a permutation
K = 8
N_FEAT = 16
LR = 1e-3
REFRESH_EVERY = 64


def make_params():
    w = np.zeros((K, N_FEAT), np.float32)
    rng = np.random.RandomState(0)
    for k in range(K):
        w[k, rng.randint(N_FEAT)] = rng.choice([-1.0, 1.0]) * 0.125
    return {"w": jnp.asarray(w)}


def make_batch(step: int):
    rng = np.random.RandomState(1000 + step)
    x = np.zeros((B, K), np.float32)
    for b in range(B):
        x[b, (b + step) % K] = rng.choice([-1.0, 1.0])
    t = rng.choice([-1.0, 1.0], size=(B, N_FEAT)).astype(np.float32)
    return {"x": jnp.asarray(x), "t": jnp.asarray(t)}


def loss_fn(params, batch, pol):
    """Batch-MEAN linear loss (the trainer's DP convention): one
    ``Policy.dot`` => one six-direction StatsBank GEMM node."""
    y = pol.dot(batch["x"], params["w"])
    return jnp.mean(jnp.sum(y * batch["t"], axis=-1)), {}


def setup(mesh=None, grad_sync_mode="f32", telemetry=False, guard=None,
          param_sharding="replicated"):
    """(step_fn, params, opt_state, bank, stats_cfg) for the toy.
    ``guard``: a ``training/guard.GuardConfig`` — the returned step then
    takes/returns the extra guard carry (build it with
    ``guard.init_state()``).  ``param_sharding``: trainer FSDP modes —
    ``w`` is [8, 16] f32, so it is gather-eligible on any fsdp axis
    dividing 8 and payload-eligible under ``fsdp_q`` (its only consumer
    is the ``Policy.dot`` GEMM B slot)."""
    from repro.core import statsbank
    from repro.core.policy import make_policy
    from repro.optim import optimizers, schedules
    from repro.training.trainer import make_train_step

    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    params = make_params()
    opt = optimizers.adamw()
    cfg = statsbank.StatsConfig(refresh_every=REFRESH_EVERY,
                                telemetry=telemetry)
    bank = statsbank.init_bank(loss_fn, params, make_batch(0), pol, cfg)
    step_fn = make_train_step(loss_fn, opt, schedules.constant(LR), pol,
                              stats=cfg, mesh=mesh,
                              grad_sync_mode=grad_sync_mode, guard=guard,
                              param_sharding=param_sharding)
    return jax.jit(step_fn), params, opt.init(params), bank, cfg


def run(step_fn, params, opt_state, bank, n_steps: int, start: int = 0):
    for s in range(start, n_steps):
        params, opt_state, bank, metrics = step_fn(
            params, opt_state, bank, make_batch(s), jnp.int32(s))
    return params, opt_state, bank, metrics
