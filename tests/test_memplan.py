"""launch/memplan.py: pin the per-leaf FSDP residency byte math."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import memplan
from repro.parallel import sharding as shd


def test_plan_leaf_byte_math():
    # [8, 16] f32, 8-way: 128 elements, 512 bytes full
    full = memplan.plan_leaf((8, 16), jnp.float32, 8, "replicated")
    assert (full.store_bytes, full.gather_bytes) == (512, 0)
    assert not full.sharded and not full.payload

    f = memplan.plan_leaf((8, 16), jnp.float32, 8, "fsdp")
    assert (f.store_bytes, f.gather_bytes) == (64, 512)   # /8 store, f32 wire
    assert f.sharded and not f.payload

    q = memplan.plan_leaf((8, 16), jnp.float32, 8, "fsdp_q")
    assert q.store_bytes == 64
    assert q.gather_bytes == 128 + memplan.PAYLOAD_STATS_BYTES  # 1 B/elt
    assert q.sharded and q.payload

    # rank-1 leaf: sharded but NOT payload (GEMM B slots are rank 2) —
    # fsdp_q still gathers it f32
    v = memplan.plan_leaf((64,), jnp.float32, 8, "fsdp_q")
    assert (v.store_bytes, v.gather_bytes) == (32, 256)
    assert v.sharded and not v.payload

    # ineligible: ragged dim 0, int dtype, scalar — full store, no gather
    for shape, dtype in [((6, 4), jnp.float32), ((8, 16), jnp.int32),
                         ((), jnp.float32)]:
        lp = memplan.plan_leaf(shape, dtype, 8, "fsdp_q")
        n = 1
        for d in shape:
            n *= d
        assert lp.store_bytes == n * memplan._itemsize(jnp.dtype(dtype))
        assert lp.gather_bytes == 0 and not lp.sharded

    # a 1-way axis never shards
    one = memplan.plan_leaf((8, 16), jnp.float32, 1, "fsdp_q")
    assert (one.store_bytes, one.gather_bytes) == (512, 0)


def test_eligibility_matches_trainer_rule():
    """memplan's jax-free predicate must agree with the trainer's
    (parallel/sharding.fsdp_leaf_eligible) everywhere — the fits verdict
    is only honest if both apply the same rule."""
    cases = [((8, 16), jnp.float32), ((8, 16), jnp.bfloat16),
             ((6, 4), jnp.float32), ((8, 16), jnp.int32),
             ((), jnp.float32), ((64,), jnp.float32),
             ((12, 4, 4), jnp.float32), ((0, 4), jnp.float32)]
    for n in (1, 4, 8):
        for shape, dtype in cases:
            assert memplan.leaf_eligible(shape, jnp.dtype(dtype), n) \
                == shd.fsdp_leaf_eligible(shape, dtype, n), (shape, dtype, n)


def test_plan_state_aggregates_and_opt_never_gathers():
    params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
              "b": jax.ShapeDtypeStruct((6,), jnp.float32)}
    opt = {"step": jax.ShapeDtypeStruct((), jnp.int32),
           "m": params, "v": params}
    plan = memplan.plan_state(params, opt, 8, "fsdp_q")
    # params: w sharded 512/8=64, b replicated 24
    assert plan["param_store_bytes"] == 64 + 24
    # opt: two sharded moment mirrors of w + two b's + the 4-byte step
    assert plan["opt_store_bytes"] == 2 * (64 + 24) + 4
    assert plan["steady_bytes"] == plan["param_store_bytes"] \
        + plan["opt_store_bytes"]
    # only w gathers, as a payload: 128 B + stats
    assert plan["gather_peak_bytes"] == 128 + memplan.PAYLOAD_STATS_BYTES
    assert plan["gather_sum_bytes"] == plan["gather_peak_bytes"]
    assert plan["peak_bytes"] == plan["steady_bytes"] \
        + plan["gather_peak_bytes"]
    assert plan["n_payload"] == 1 and plan["n_sharded"] == 1

    rep = memplan.plan_state(params, opt, 8, "replicated")
    # the ~n_shards store drop the bench lane asserts, in miniature:
    # w's 12 bytes/elt drop 8x, b's stay
    assert rep["steady_bytes"] == 3 * (512 + 24) + 4
    assert rep["gather_peak_bytes"] == 0


def test_fsdp_shards_of_and_mode_validation():
    assert memplan.fsdp_shards_of({"data": 16, "model": 16}) == 16
    assert memplan.fsdp_shards_of({"pod": 2, "data": 16, "model": 16}) == 16
    assert memplan.fsdp_shards_of({"model": 4}) == 1
    with pytest.raises(ValueError, match="mode"):
        memplan.plan_leaf((8,), jnp.float32, 8, "zero3")


def test_format_report_smoke():
    out = memplan.format_report(["transformer_tiny"],
                                {"data": 16, "model": 16})
    assert "transformer_tiny" in out and "fsdp_q" in out
    assert out.count("\n") >= 4          # header + 3 mode rows
