"""Per-architecture smoke tests (assignment requirement): REDUCED config of
the same family, one forward/train step on CPU, assert shapes + no NaNs.
Plus decode-path consistency and the modality stubs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import ARCH_IDS
from repro.core.policy import make_policy
from repro.models import encdec, ncf, resnet, transformer as tlm

jax.config.update("jax_platform_name", "cpu")

LM_ARCHS = [a for a in ARCH_IDS
            if a not in ("whisper_medium", "transformer_tiny",
                         "resnet20_cifar", "ncf_ml1m")]
SSM_ARCHS = {"zamba2_1p2b", "falcon_mamba_7b"}
# The heaviest reduced configs (>50s each on CPU): run in the slow lane.
_SLOW_SMOKE = {"gemma3_1b", "kimi_k2_1t_a32b", "zamba2_1p2b",
               "deepseek_moe_16b"}
LM_SMOKE_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
                   if a in _SLOW_SMOKE else a for a in LM_ARCHS]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", LM_SMOKE_PARAMS)
def test_lm_train_step_smoke(arch, key):
    cfg = get_reduced_config(arch)
    pol = make_policy("s2fp8")
    params = tlm.init_lm(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    loss, metrics = jax.jit(
        lambda p: tlm.loss_fn(p, toks, labels, cfg, pol))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: tlm.loss_fn(p, toks, labels, cfg, pol)[0])(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_logit_shapes(arch, key):
    cfg = get_reduced_config(arch)
    pol = make_policy("fp32")
    params = tlm.init_lm(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    x, _, _ = tlm.forward(params, toks, cfg, pol, mode="train")
    logits = tlm.lm_head(params, x, cfg, pol)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["minicpm_2b", "gemma3_1b", "deepseek_moe_16b",
                                  "kimi_k2_1t_a32b", "zamba2_1p2b",
                                  "falcon_mamba_7b", "chameleon_34b"])
def test_prefill_decode_consistency(arch, key):
    """prefill(S tokens) + decode(1) must match full forward of S+1 tokens."""
    cfg = get_reduced_config(arch).replace(remat=False,
                                           activation_dtype="float32")
    pol = make_policy("fp32")
    params = tlm.init_lm(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    caches = tlm.init_caches(cfg, B, 24, dtype=jnp.float32)
    logits_p, caches = tlm.prefill(params, toks, cfg, pol, caches)
    x, _, _ = tlm.forward(params, toks, cfg, pol, mode="train")
    ref_last = tlm.lm_head(params, x[:, -1:], cfg, pol)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(ref_last),
                               rtol=1e-4, atol=1e-4)
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, _ = tlm.decode_step(params, nxt, cfg, pol, caches, jnp.int32(S))
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    x2, _, _ = tlm.forward(params, toks2, cfg, pol, mode="train")
    ref2 = tlm.lm_head(params, x2[:, -1:], cfg, pol)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref2),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_gemma_local_ring_cache_long_decode(key):
    """Ring-buffer window cache: decoding past the window must stay finite
    and match a fresh full forward on the visible window."""
    cfg = get_reduced_config("gemma3_1b").replace(remat=False,
                                                  activation_dtype="float32")
    pol = make_policy("fp32")
    params = tlm.init_lm(cfg, key)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    caches = tlm.init_caches(cfg, B, cfg.window + 32, dtype=jnp.float32)
    logits, caches = tlm.prefill(params, toks, cfg, pol, caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(S, S + cfg.window + 8):   # decode well past the window
        logits, caches = tlm.decode_step(params, tok, cfg, pol, caches,
                                         jnp.int32(t))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all()


def test_whisper_smoke(key):
    cfg = get_reduced_config("whisper_medium")
    pol = make_policy("s2fp8")
    params = encdec.init_encdec(cfg, key)
    enc_in = jax.random.normal(key, (2, 24, cfg.d_model))   # audio stub
    dec = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    lab = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    loss, _ = encdec.loss_fn(params, enc_in, dec, lab, cfg, pol)
    assert np.isfinite(float(loss))
    # serve path
    polf = make_policy("fp32")
    bos = jnp.zeros((2, 1), jnp.int32)
    lg, st = encdec.serve_prefill(params, enc_in, bos, cfg, polf, max_dec_len=16)
    assert lg.shape == (2, 1, cfg.vocab)
    lg2, _ = encdec.serve_decode(params, jnp.argmax(lg, -1).astype(jnp.int32),
                                 st, jnp.int32(1), cfg, polf)
    assert np.isfinite(np.asarray(lg2)).all()


def test_transformer_tiny_smoke(key):
    cfg = get_reduced_config("transformer_tiny")
    pol = make_policy("s2fp8")
    params = encdec.init_encdec(cfg, key)
    src = jax.random.randint(key, (2, 16), 2, cfg.vocab)    # token encoder
    dec = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    loss, _ = encdec.loss_fn(params, src, dec, dec, cfg, pol)
    assert np.isfinite(float(loss))


def test_ncf_smoke(key):
    p = ncf.init_ncf(key, 64, 32)
    pol = make_policy("s2fp8")
    batch = {"users": jnp.arange(8) % 64, "items": jnp.arange(8) % 32,
             "labels": jnp.arange(8) % 2}
    loss, _ = ncf.loss_fn(p, batch, pol)
    assert np.isfinite(float(loss))
    hr = ncf.hit_ratio(p, jnp.arange(4) % 64, jnp.arange(4) % 32,
                       jnp.arange(4 * 9).reshape(4, 9) % 32, pol)
    assert 0.0 <= float(hr) <= 1.0


def test_resnet_smoke(key):
    params, state = resnet.init_resnet(key, 20)
    pol = make_policy("s2fp8")
    batch = {"images": jax.random.normal(key, (4, 32, 32, 3)),
             "labels": jnp.array([0, 1, 2, 3])}
    loss, (metrics, new_state) = resnet.loss_fn(params, state, batch, pol)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0
    # bn running stats updated
    assert not np.allclose(np.asarray(new_state["stem_bn"]["mean"]),
                           np.asarray(state["stem_bn"]["mean"]))


def test_moe_aux_loss_positive(key):
    cfg = get_reduced_config("deepseek_moe_16b")
    pol = make_policy("fp32")
    params = tlm.init_lm(cfg, key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    _, aux, _ = tlm.forward(params, toks, cfg, pol, mode="train")
    assert float(aux) > 0.0


def test_chunked_vs_full_attention_equivalence(key):
    """The pure-JAX flash path must equal plain attention (train graphs)."""
    from repro.models.blocks import chunked_attention, full_attention
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 2, 2, 256, 32))
    k = jax.random.normal(ks[1], (2, 2, 256, 32))
    v = jax.random.normal(ks[2], (2, 2, 256, 32))
    a = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    b = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
    aw = chunked_attention(q, k, v, causal=True, window=48, q_chunk=64, kv_chunk=64)
    bw = full_attention(q, k, v, causal=True, window=48)
    np.testing.assert_allclose(np.asarray(aw), np.asarray(bw), rtol=2e-4, atol=2e-5)
