"""Serving engine: slot batching, admission, completion, output sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.launch import api
from repro.serving.engine import LMServer, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def server():
    cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return LMServer(cfg, params, make_policy("fp32"), slots=2, max_len=64)


def test_requests_complete(server):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 512, 8, dtype=np.int32),
                    max_new_tokens=5) for _ in range(5)]
    for r in reqs:
        server.submit(r)
    ticks = server.run_to_completion(max_ticks=200)
    assert ticks < 200
    for r in reqs:
        assert len(r.out) == 5
        assert all(0 <= t < 512 for t in r.out)


def test_greedy_matches_unbatched(server):
    """A request served through the slot engine must equal a straight
    greedy decode with the same params."""
    from repro.models import transformer as tlm
    cfg, params, pol = server.cfg, server.params, server.pol
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 512, 8, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=4)
    server.submit(req)
    server.run_to_completion(max_ticks=50)

    # reference: batchless greedy
    toks = jnp.asarray(prompt, jnp.int32)[None]
    caches = tlm.init_caches(cfg, 1, 64, dtype=jnp.float32)
    logits, caches = tlm.prefill(params, toks, cfg, pol, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = tlm.decode_step(params, tok, cfg, pol, caches,
                                         jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    assert req.out == out
