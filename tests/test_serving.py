"""Serving engines: slot batching, admission, paged payload cache, parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.launch import api
from repro.serving import bank as sbank
from repro.serving import paged_cache
from repro.serving.engine import LMServer, PayloadLMServer, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("minicpm_2b").replace(n_layers=2, remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def server(setup):
    cfg, params = setup
    return LMServer(cfg, params, make_policy("fp32"), slots=2, max_len=64)


@pytest.fixture(scope="module")
def payload_setup(setup):
    """Payload policy + export-time frozen serving bank (shared: the bank
    depends on (params, cfg, policy), not on the cache format)."""
    cfg, params = setup
    pol = make_policy("s2fp8", backend="ref", gemm_mode="payload")
    bank = sbank.export_serving_bank(params, cfg, pol, prompt_len=8,
                                     batch=2, passes=1)
    return cfg, params, pol, bank


def _mk_reqs(lengths, vocab, new_tokens, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab, int(l), dtype=np.int32),
                    max_new_tokens=new_tokens) for l in lengths]


def test_requests_complete(server):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 512, 8, dtype=np.int32),
                    max_new_tokens=5) for _ in range(5)]
    for r in reqs:
        server.submit(r)
    ticks = server.run_to_completion(max_ticks=200)
    assert ticks < 200
    for r in reqs:
        assert len(r.out) == 5
        assert all(0 <= t < 512 for t in r.out)


def test_greedy_matches_unbatched(server):
    """A request served through the slot engine must equal a straight
    greedy decode with the same params."""
    from repro.models import transformer as tlm
    cfg, params, pol = server.cfg, server.params, server.pol
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 512, 8, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=4)
    server.submit(req)
    server.run_to_completion(max_ticks=50)

    # reference: batchless greedy
    toks = jnp.asarray(prompt, jnp.int32)[None]
    caches = tlm.init_caches(cfg, 1, 64, dtype=jnp.float32)
    logits, caches = tlm.prefill(params, toks, cfg, pol, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = tlm.decode_step(params, tok, cfg, pol, caches,
                                         jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    assert req.out == out


def test_staggered_prompts_match_unbatched(setup):
    """Regression for the shared-max-position decode bug: slots admitted
    with different prompt lengths decode at *their own* positions, so each
    request's greedy output equals a single-slot run of the same prompt."""
    cfg, params = setup
    pol = make_policy("fp32")
    srv = LMServer(cfg, params, pol, slots=3, max_len=64)
    reqs = _mk_reqs((4, 13, 7), cfg.vocab, 8, seed=2)
    for r in reqs:
        srv.submit(r)
    srv.run_to_completion(max_ticks=100)
    for r in reqs:
        ref_srv = LMServer(cfg, params, pol, slots=1, max_len=64)
        ref = Request(prompt=r.prompt, max_new_tokens=8)
        ref_srv.submit(ref)
        ref_srv.run_to_completion(max_ticks=100)
        assert r.out == ref.out


def test_batched_admission_bounded_shapes(setup):
    """Admissions are bucketed per tick: many requests with assorted prompt
    lengths compile at most one prefill per power-of-two bucket, not one
    per admission."""
    cfg, params = setup
    srv = LMServer(cfg, params, make_policy("fp32"), slots=4, max_len=64)
    lengths = (3, 5, 9, 12, 17, 30, 6, 11)
    reqs = _mk_reqs(lengths, cfg.vocab, 3, seed=3)
    for r in reqs:
        srv.submit(r)
    srv.run_to_completion(max_ticks=200)
    assert all(len(r.out) == 3 for r in reqs)
    assert len(srv.prefill_shapes) <= srv.max_prefill_shapes
    # buckets actually hit: 4, 8, 16, 32 -> far fewer than 8 admissions
    assert len(srv.prefill_shapes) <= 4


@pytest.mark.parametrize("fmt", ["e5m2", "e4m3"])
def test_payload_engine_token_exact(payload_setup, fmt):
    """Tentpole numerics: a payload-pool engine and an f32 comparator pool
    holding ``truncate_value`` grid-snapped values — same frozen bank, same
    policy — emit token-identical greedy outputs for >= 64 decode steps
    (dequantize(quantize(x, s)) == truncate_value(x, s) elementwise)."""
    cfg, params, pol, bank = payload_setup
    outs = {}
    for cache_fmt in (fmt, f"f32_{fmt}"):
        srv = PayloadLMServer(cfg, params, pol, bank=bank, slots=2,
                              max_len=96, block=8, cache_fmt=cache_fmt)
        reqs = _mk_reqs((5, 11), cfg.vocab, 64, seed=4)
        for r in reqs:
            srv.submit(r)
        srv.run_to_completion(max_ticks=200)
        assert all(len(r.out) == 64 for r in reqs)
        outs[cache_fmt] = [r.out for r in reqs]
    assert outs[fmt] == outs[f"f32_{fmt}"]


def test_payload_pool_is_one_byte(payload_setup):
    """Acceptance: the paged payload cache stores 1 byte/element + frozen
    per-layer stats scalars."""
    cfg, params, pol, bank = payload_setup
    srv = PayloadLMServer(cfg, params, pol, bank=bank, slots=2, max_len=32,
                          block=8, cache_fmt="e5m2")
    for seg in srv.caches:
        assert seg["kp"].dtype.itemsize == 1
        assert seg["vp"].dtype.itemsize == 1
    pool_b, stats_b = srv.cache_bytes()
    n_elts = sum(seg["kp"].size + seg["vp"].size for seg in srv.caches)
    assert pool_b == n_elts
    assert stats_b == sum(seg["kab"].size + seg["vab"].size
                          for seg in srv.caches) * 4


def test_decode_zero_stats_reductions(payload_setup):
    """Acceptance: frozen-bank payload decode performs exactly as many
    reductions as an unfrozen fp32 engine on the same paged structure —
    i.e. zero stats reductions in the steady state."""
    cfg, params, pol, bank = payload_setup
    frozen = PayloadLMServer(cfg, params, pol, bank=bank, slots=2,
                             max_len=32, block=8, cache_fmt="e5m2")
    base = PayloadLMServer(cfg, params, make_policy("fp32"), bank=None,
                           slots=2, max_len=32, block=8, cache_fmt="f32")
    nf = statsbank.count_reductions(frozen.decode_jaxpr())
    nb = statsbank.count_reductions(base.decode_jaxpr())
    assert nf == nb, (nf, nb)


def test_preemption_under_pool_pressure(payload_setup):
    """With a pool too small for all contexts, the engine preempts the
    youngest slot (requeue + restart) and still completes every request."""
    cfg, params, pol, bank = payload_setup
    srv = PayloadLMServer(cfg, params, pol, bank=bank, slots=2, max_len=32,
                          block=8, n_blocks=5, cache_fmt="e5m2")
    reqs = _mk_reqs((9, 9, 9), cfg.vocab, 20, seed=5)
    for r in reqs:
        srv.submit(r)
    ticks = srv.run_to_completion(max_ticks=500)
    assert ticks < 500
    assert srv.preemptions > 0
    assert all(len(r.out) == 20 for r in reqs)


def test_prefill_token_budget_defers_admission(payload_setup):
    """The scheduler admits at most ``prefill_token_budget`` padded prompt
    tokens per tick; excess requests wait in the queue."""
    cfg, params, pol, bank = payload_setup
    srv = PayloadLMServer(cfg, params, pol, bank=bank, slots=4, max_len=32,
                          block=8, cache_fmt="e5m2",
                          prefill_token_budget=16)
    reqs = _mk_reqs((9, 9, 9, 9), cfg.vocab, 4, seed=6)
    for r in reqs:
        srv.submit(r)
    # 9 -> bucket 16; budget 16 admits exactly one per tick
    srv.step()
    assert sum(r is not None for r in srv.slot_req) == 1
    assert len(srv.queue) == 3
    srv.run_to_completion(max_ticks=100)
    assert all(len(r.out) == 4 for r in reqs)


def test_paged_kernel_matches_reference():
    """Interpret-mode Pallas paged decode kernel vs the jnp gather oracle."""
    from repro.kernels import paged_attention as pk
    from repro.core import s2fp8
    key = jax.random.PRNGKey(7)
    b, kvh, g, hd, blk, max_b, nb = 4, 2, 3, 64, 16, 4, 9
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, kvh, g, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (nb, kvh, blk, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (nb, kvh, blk, hd), jnp.float32)
    ka, kb_ = 4.0, 1.5
    va, vb_ = 3.0, -0.5
    kp = s2fp8.quantize(kf, stats=(ka, kb_), fmt="e5m2").payload
    vp = s2fp8.quantize(vf, stats=(va, vb_), fmt="e5m2").payload
    table = jnp.asarray(
        np.array([[1, 2, 3, 4], [5, 6, 0, 0], [0, 0, 0, 0], [7, 8, 1, 2]],
                 np.int32))
    positions = jnp.asarray([5, 33, 0, 60], jnp.int32)
    out = pk.paged_decode_attention(q, kp, vp, ka, kb_, va, vb_, table,
                                    positions, fmt="e5m2", interpret=True)
    ref = pk.paged_decode_reference(q, kp, vp, ka, kb_, va, vb_, table,
                                    positions)
    assert jnp.isfinite(out).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
