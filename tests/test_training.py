"""Training substrate: optimizers, schedules, trainer loop, loss scaling,
checkpoint/restart fault tolerance, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.data import synthetic
from repro.models import transformer as tlm
from repro.optim import optimizers, schedules
from repro.training.trainer import TrainLoop, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _tiny_setup(policy_mode="s2fp8", arch="minicpm_2b", lr=3e-3, seed=0):
    cfg = get_reduced_config(arch).replace(n_layers=2, remat=False, vocab=64)
    pol = make_policy(policy_mode, loss_scale=100.0)
    params = tlm.init_lm(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw()
    sched = schedules.constant(lr)

    def loss_fn(p, batch, pol_):
        return tlm.loss_fn(p, batch["tokens"], batch["labels"], cfg, pol_)

    step = make_train_step(loss_fn, opt, sched, pol)
    table = synthetic.make_markov_table(seed, cfg.vocab)

    def data_fn(s):
        return synthetic.lm_batch(seed, s, 8, 64, cfg.vocab, table)

    return cfg, params, opt, step, data_fn


@pytest.mark.slow
def test_loss_decreases_s2fp8():
    _, params, opt, step, data_fn = _tiny_setup("s2fp8")
    opt_state = opt.init(params)
    losses = []
    jstep = jax.jit(step)
    for s in range(40):
        params, opt_state, m = jstep(params, opt_state, data_fn(s), jnp.int32(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::5]


def test_fp8_ls_unscales_gradients():
    """Same data: fp8_ls(lambda=100) step must produce an update of the same
    magnitude as fp32 (Eq. 6 — grads unscaled before the optimizer)."""
    cfg, params, opt, _, data_fn = _tiny_setup("fp32")
    batch = data_fn(0)

    def upd_norm(mode):
        pol = make_policy(mode, loss_scale=100.0)

        def loss_fn(p, b, pol_):
            return tlm.loss_fn(p, b["tokens"], b["labels"], cfg, pol_)

        step = make_train_step(loss_fn, optimizers.adamw(),
                               schedules.constant(1e-2), pol)
        new_params, _, m = jax.jit(step)(params, opt.init(params), batch,
                                         jnp.int32(0))
        delta = jax.tree_util.tree_map(lambda a, b_: a - b_, new_params, params)
        return float(optimizers.global_norm(delta)), float(m["loss"])

    n_ls, l_ls = upd_norm("fp8_ls")
    n_32, l_32 = upd_norm("fp32")
    assert abs(l_ls - l_32) / l_32 < 0.2           # loss reported unscaled
    assert 0.2 < n_ls / n_32 < 5.0                 # same order of magnitude


def test_wsd_schedule_shape():
    fn = schedules.wsd(1.0, warmup=10, stable=50, decay=20)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert abs(float(fn(40)) - 1.0) < 1e-6
    assert float(fn(100)) < 0.5


def test_step_decay_schedule():
    fn = schedules.step_decay(0.1, [100, 150], 0.1)
    assert abs(float(fn(50)) - 0.1) < 1e-6
    assert abs(float(fn(120)) - 0.01) < 1e-6
    assert abs(float(fn(200)) - 0.001) < 1e-6


def test_sgd_momentum_math():
    opt = optimizers.sgd_momentum(momentum=0.9)
    params = {"w": jnp.ones((4,))}
    st = opt.init(params)
    g = {"w": jnp.full((4,), 2.0)}
    p1, st = opt.update(g, st, params, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - 0.1 * 2.0)
    p2, st = opt.update(g, st, p1, 0.1)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               float(p1["w"][0]) - 0.1 * (0.9 * 2.0 + 2.0))


def test_clip_by_global_norm():
    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(optimizers.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_checkpoint_restart_bitexact(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly."""
    cfg, params, opt, step, data_fn = _tiny_setup("s2fp8")
    # uninterrupted 10 steps
    p, st = params, opt.init(params)
    jstep = jax.jit(step)
    for s in range(10):
        p, st, _ = jstep(p, st, data_fn(s), jnp.int32(s))
    ref = p

    # run 6 steps, checkpoint, "crash", restore, run 4 more
    ck = CheckpointManager(str(tmp_path), keep=2)
    p2, st2 = params, opt.init(params)
    for s in range(6):
        p2, st2, _ = jstep(p2, st2, data_fn(s), jnp.int32(s))
    ck.save(6, (p2, st2))
    del p2, st2
    (p3, st3), start = ck.restore((params, opt.init(params)))
    assert start == 6
    for s in range(start, 10):
        p3, st3, _ = jstep(p3, st3, data_fn(s), jnp.int32(s))
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(10.0)}
    for s in [1, 2, 3]:
        ck.save(s, tree)
    assert ck.latest_step() == 3
    dirs = sorted(os.listdir(tmp_path))
    assert "step_0000000001" not in dirs            # GC'd
    # a stale .tmp dir must be ignored by restore
    os.makedirs(tmp_path / "step_0000000099.tmp")
    assert ck.latest_step() == 3


def test_checkpoint_s2fp8_compression(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=1, compress=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 1e-5
    ck.save(1, {"w": x})
    restored, _ = ck.restore({"w": jnp.zeros((128, 128))})
    r = np.asarray(restored["w"])
    xn = np.asarray(x)
    nz = r != 0
    assert np.median(np.abs(r[nz] - xn[nz]) / np.abs(xn[nz])) < 0.05
    # payload on disk is ~1 byte/element
    d = tmp_path / "step_0000000001"
    payload = [f for f in os.listdir(d) if f.endswith("payload.npy")]
    assert payload


def test_data_determinism():
    t = synthetic.make_markov_table(0, 64)
    b1 = synthetic.lm_batch(0, 7, 4, 16, 64, t)
    b2 = synthetic.lm_batch(0, 7, 4, 16, 64, t)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic.lm_batch(0, 8, 4, 16, 64, t)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_trainloop_resume(tmp_path):
    cfg, params, opt, step, data_fn = _tiny_setup("fp32")
    ck = CheckpointManager(str(tmp_path))
    loop = TrainLoop(step, params, opt.init(params), data_fn,
                     ckpt_manager=ck, ckpt_every=5, log_every=0)
    loop.run(10)
    assert ck.latest_step() == 10
    loop2 = TrainLoop(step, params, opt.init(params), data_fn,
                      ckpt_manager=ck, ckpt_every=5, log_every=0)
    loop2.maybe_resume()
    assert loop2.start_step == 10
