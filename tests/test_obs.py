"""Numerics telemetry: per-site FP8 health metrics, sinks, s2fp8-doctor.

Covers the ISSUE-7 acceptance criteria:
  * health metrics ride the StatsBank refresh ``lax.cond`` — a
    telemetry-on banked train step runs the SAME number of reductions
    outside cond branches as the fp32 baseline + 1 (jaxpr-asserted; the
    zero-steady-state-reduction invariant is untouched);
  * the trainer drains TelemetryState host-side through ``io_callback``
    into pluggable sinks, covering every direction of a payload-GEMM
    node with correct staleness;
  * the TrainLoop watchdog trips on a deliberately slow step and the
    event lands in the sink;
  * a telemetry-enabled bank checkpoint round-trips bit-exactly
    (compress=True included — telemetry leaves are 0/1-D, kept raw);
  * 8-device mesh telemetry equals the 1-device run bitwise on the
    order-exact toy (subprocess, slow lane);
  * the doctor flags a saturating site (sat_frac > 0, e4m3 -> e5m2
    recommendation) and reports a healthy probe clean.
"""
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mesh_toy
from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.obs import doctor as obs_doctor
from repro.obs import metrics as obs_metrics
from repro.obs import sinks as obs_sinks
from repro.optim import optimizers, schedules
from repro.training import fault
from repro.training.trainer import TrainLoop, make_train_step

jax.config.update("jax_platform_name", "cpu")

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_TESTS = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# metric math: health_update via refresh_state
# ---------------------------------------------------------------------------

def test_refresh_computes_health_metrics():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 1e-3
    st = statsbank.init_site_state(telemetry=True)
    assert obs_metrics.has_telemetry(st)
    st1 = statsbank.refresh_state(x, st, jnp.float32(0.0), backend="ref",
                                  fmt="e5m2")
    # bootstrap refresh measures with the FRESH stats: no saturation, no
    # drift (nothing carried), healthy SNR; a few percent of low-tail
    # flush is intrinsic S2FP8 behavior on Gaussian data
    assert float(st1["sat_frac"]) == 0.0
    assert float(st1["drift_mu"]) == 0.0
    assert float(st1["drift_m"]) == 0.0
    assert float(st1["qsnr_db"]) > 10.0
    assert 0.0 <= float(st1["uflow_frac"]) < obs_doctor.UFLOW_THRESH
    assert float(st1["qmse"]) >= 0.0
    # second refresh fed a 2^12x hotter tensor: the metrics measure with
    # the CARRIED pair (what recent steps actually truncated with), so
    # saturation and moment drift must show — while the refreshed
    # (alpha, beta) themselves are the fresh, non-saturating ones
    st2 = statsbank.refresh_state(x * jnp.float32(2.0 ** 12), st1,
                                  jnp.float32(1.0), backend="ref",
                                  fmt="e5m2")
    assert float(st2["sat_frac"]) > 0.0
    assert float(st2["drift_mu"]) > 0.0
    assert float(st2["last"]) == 1.0


def test_ensure_and_strip_telemetry_roundtrip():
    plain = {"s": {"fwd": statsbank.init_site_state(),
                   "bwd": statsbank.init_site_state(length=3)}}
    wide = obs_metrics.ensure_telemetry(plain)
    for d in ("fwd", "bwd"):
        assert obs_metrics.has_telemetry(wide["s"][d])
    assert wide["s"]["bwd"]["sat_frac"].shape == (3,)
    # idempotent, and strip restores the five-leaf layout exactly
    assert obs_metrics.ensure_telemetry(wide)["s"]["fwd"].keys() == \
        wide["s"]["fwd"].keys()
    back = obs_metrics.strip_telemetry(wide)
    assert sorted(back["s"]["fwd"]) == sorted(statsbank.STATE_FIELDS)


def test_resolve_fmt():
    assert obs_metrics.resolve_fmt("e4m3", 15.0) == "e4m3"
    assert obs_metrics.resolve_fmt(None, 8.0) == "e4m3"
    assert obs_metrics.resolve_fmt(None, 15.0) == "e5m2"
    assert obs_metrics.resolve_fmt(None, 12.345) == "e5m2"


# ---------------------------------------------------------------------------
# acceptance: telemetry adds ZERO reductions outside lax.cond
# ---------------------------------------------------------------------------

def test_telemetry_zero_steady_state_reductions():
    params = mesh_toy.make_params()
    batch = mesh_toy.make_batch(0)
    opt = optimizers.adamw()
    sched = schedules.constant(1e-3)
    ost = opt.init(params)
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")

    def banked_jaxpr(cfg_s):
        bank = statsbank.init_bank(mesh_toy.loss_fn, params, batch, pol,
                                   cfg_s)
        return jax.make_jaxpr(
            make_train_step(mesh_toy.loss_fn, opt, sched, pol,
                            stats=cfg_s))(params, ost, bank, batch,
                                          jnp.int32(0))

    jx_fp32 = jax.make_jaxpr(
        make_train_step(mesh_toy.loss_fn, opt, sched,
                        make_policy("fp32")))(params, ost, batch,
                                              jnp.int32(0))
    jx_bank = banked_jaxpr(statsbank.StatsConfig(refresh_every=4))
    jx_tele = banked_jaxpr(statsbank.StatsConfig(refresh_every=4,
                                                 telemetry=True))

    n_fp32 = statsbank.count_reductions(jx_fp32, include_cond=False)
    n_bank = statsbank.count_reductions(jx_bank, include_cond=False)
    n_tele = statsbank.count_reductions(jx_tele, include_cond=False)
    # telemetry on == telemetry off outside cond branches: the fp32
    # baseline plus the single O(n_sites) bookkeeping min, nothing more
    assert n_tele == n_fp32 + 1, (n_tele, n_fp32)
    assert n_tele == n_bank, (n_tele, n_bank)
    # ... and the metric reductions DO exist, inside the cond branches
    n_bank_all = statsbank.count_reductions(jx_bank, include_cond=True)
    n_tele_all = statsbank.count_reductions(jx_tele, include_cond=True)
    assert n_tele_all > n_bank_all, (n_tele_all, n_bank_all)


# ---------------------------------------------------------------------------
# trainer drain: io_callback -> Telemetry -> MemorySink
# ---------------------------------------------------------------------------

def test_train_step_drains_telemetry_to_sink():
    sink = obs_sinks.MemorySink()
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    params = mesh_toy.make_params()
    opt = optimizers.adamw()
    cfg = statsbank.StatsConfig(refresh_every=2, telemetry=True)
    bank = statsbank.init_bank(mesh_toy.loss_fn, params,
                               mesh_toy.make_batch(0), pol, cfg)
    step = jax.jit(make_train_step(mesh_toy.loss_fn, opt,
                                   schedules.constant(1e-3), pol,
                                   stats=cfg,
                                   telemetry=obs.Telemetry(sink, every=1)))
    p, st = params, opt.init(params)
    for s in range(4):
        p, st, bank, m = step(p, st, bank, mesh_toy.make_batch(s),
                              jnp.int32(s))
    jax.block_until_ready((p, m))
    jax.effects_barrier()

    recs = sink.by_kind("site_health")
    assert recs, "telemetry drain emitted nothing"
    # every direction of the toy's single payload-GEMM node drains
    site = recs[0]["site"]
    assert {r["dir"] for r in recs if r["site"] == site} == \
        set(statsbank.GEMM_DIRS)
    # staleness tracks steps-since-refresh: refresh_every=2 => the step-3
    # snapshot is 1 step past the step-2 refresh
    last = [r for r in recs if r["step"] == 3]
    assert last and all(r["staleness"] == 1.0 for r in last), last
    for r in recs:
        assert set(obs_metrics.TELE_FIELDS) <= set(r), sorted(r)


def test_telemetry_requires_stats():
    opt = optimizers.adamw()
    with pytest.raises(ValueError, match="telemetry requires"):
        make_train_step(mesh_toy.loss_fn, opt, schedules.constant(1e-3),
                        make_policy("s2fp8"),
                        telemetry=obs.Telemetry(obs_sinks.NullSink()))
    with pytest.raises(ValueError):
        obs.Telemetry(obs_sinks.NullSink(), every=0)


# ---------------------------------------------------------------------------
# watchdog: unit + TrainLoop trip through a deliberately slow step
# ---------------------------------------------------------------------------

def test_watchdog_unit():
    with pytest.raises(ValueError):
        fault.Watchdog(factor=0.0)
    wd = fault.Watchdog(factor=2.0, min_history=4)
    # spikes before min_history accumulate silently
    assert wd.observe(0, 10.0) is None
    for s in range(1, 5):
        assert wd.observe(s, 0.1) is None
    ev = wd.observe(5, 0.5)
    assert ev is not None
    assert ev["step"] == 5 and ev["dt_s"] == 0.5
    assert ev["median_s"] == pytest.approx(0.1)
    assert wd.events == [ev]
    # back to baseline: no trip
    assert wd.observe(6, 0.1) is None


def test_trainloop_watchdog_flags_slow_step():
    from jax.experimental import io_callback
    SLOW_STEP = 10

    def host_pause(step):
        if int(step) == SLOW_STEP:
            time.sleep(0.3)
        return np.float32(0.0)

    def train_step(params, opt_state, batch, step):
        # the pause's output feeds the loss so block_until_ready in the
        # loop's span timing cannot complete before the sleep does
        z = io_callback(host_pause, jax.ShapeDtypeStruct((), jnp.float32),
                        step, ordered=True)
        return params, opt_state, {"loss": jnp.float32(1.0) + z,
                                   "lr": jnp.float32(1e-3)}

    sink = obs_sinks.MemorySink()
    loop = TrainLoop(train_step, {"w": jnp.zeros((4,))},
                     {"m": jnp.zeros((4,))},
                     lambda s: {"x": jnp.zeros((2,))},
                     log_every=0, watchdog_factor=3.0, sink=sink)
    loop.run(SLOW_STEP + 2)
    trips = [r for r in sink.by_kind("event") if r["event"] == "watchdog"]
    assert trips, sink.records
    assert trips[0]["step"] == SLOW_STEP
    assert trips[0]["dt_s"] > 3.0 * trips[0]["median_s"]


def test_trainloop_emits_spans_and_checkpoint_events(tmp_path):
    def train_step(params, opt_state, batch, step):
        return params, opt_state, {"loss": jnp.float32(1.0),
                                   "lr": jnp.float32(1e-3)}

    sink = obs_sinks.MemorySink()
    ck = CheckpointManager(str(tmp_path))
    loop = TrainLoop(train_step, {"w": jnp.zeros((4,))},
                     {"m": jnp.zeros((4,))},
                     lambda s: {"x": jnp.zeros((2,))},
                     ckpt_manager=ck, ckpt_every=2, log_every=1, sink=sink)
    loop.run(4)
    steps = sink.by_kind("train_step")
    assert [r["step"] for r in steps] == [0, 1, 2, 3]
    for r in steps:
        for k in ("loss", "lr", "data_ms", "step_ms", "ckpt_ms"):
            assert k in r, (k, r)
        assert r["step_ms"] >= 0.0
    saves = [r for r in sink.by_kind("event")
             if r["event"] == "checkpoint_saved"]
    assert [r["step"] for r in saves] == [2, 4]
    assert all("write_s" in r for r in saves)


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_jsonl_sink_writes_parseable_lines(tmp_path):
    path = str(tmp_path / "m.jsonl")
    s = obs_sinks.JsonlSink(path)
    s.emit({"kind": "train_step", "step": 0, "loss": np.float32(1.5)})
    s.emit({"kind": "site_health", "step": 0, "site": "a",
            "sat_frac": jnp.float32(0.25)})
    s.close()
    with open(path) as f:
        recs = [json.loads(l) for l in f]
    assert recs[0]["loss"] == 1.5 and isinstance(recs[0]["loss"], float)
    assert recs[1]["sat_frac"] == 0.25


def test_csv_sink_unions_headers(tmp_path):
    path = str(tmp_path / "m.csv")
    s = obs_sinks.CsvSink(path)
    s.emit({"kind": "train_step", "step": 0, "loss": 1.0})
    s.emit({"kind": "site_health", "step": 0, "site": "a", "sat_frac": 0.0})
    s.close()
    with open(path) as f:
        header = f.readline().strip().split(",")
    assert set(header) >= {"kind", "step", "loss", "site", "sat_frac"}


def test_console_sink_reproduces_legacy_lines():
    lines = []
    s = obs_sinks.ConsoleSink(lines.append)
    s.emit({"kind": "train_step", "step": 7, "loss": 1.2345, "lr": 3e-3,
            "step_ms": 12.0})
    s.emit({"kind": "event", "event": "watchdog", "step": 9, "dt_s": 1.0,
            "median_s": 0.1, "factor": 3.0})
    s.emit({"kind": "site_health", "step": 4, "site": "s", "dir": "a.fwd",
            "layer": None, "sat_frac": 0.5, "uflow_frac": 0.0,
            "qsnr_db": 20.0, "staleness": 2.0})
    assert lines[0] == "step     7 loss 1.2345 lr 3.00e-03 t 12ms"
    assert "straggler suspected" in lines[1]
    assert lines[2].startswith("[obs] step 4 s.a.fwd sat 0.500")


def test_make_sink_parses_specs(tmp_path):
    assert isinstance(obs.make_sink(None), obs_sinks.NullSink)
    assert isinstance(obs.make_sink("null"), obs_sinks.NullSink)
    assert isinstance(obs.make_sink("console"), obs_sinks.ConsoleSink)
    assert isinstance(obs.make_sink("memory"), obs_sinks.MemorySink)
    j = obs.make_sink(f"jsonl:{tmp_path}/a.jsonl")
    assert isinstance(j, obs_sinks.JsonlSink)
    j.close()
    assert isinstance(obs.make_sink(f"csv:{tmp_path}/a.csv"),
                      obs_sinks.CsvSink)
    with pytest.raises(ValueError, match="unknown metrics sink"):
        obs.make_sink("protobuf:/tmp/x")


def test_tee_sink_fans_out():
    a, b = obs_sinks.MemorySink(), obs_sinks.MemorySink()
    t = obs_sinks.TeeSink(a, b)
    t.emit({"kind": "event", "event": "x"})
    t.close()
    assert a.records == b.records and len(a.records) == 1


# ---------------------------------------------------------------------------
# telemetry bank checkpoint round-trip (compress=True keeps 0/1-D raw)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compress", [False, True])
def test_telemetry_bank_checkpoint_roundtrip(tmp_path, compress):
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    params = mesh_toy.make_params()
    opt = optimizers.adamw()
    cfg = statsbank.StatsConfig(refresh_every=2, telemetry=True)
    bank = statsbank.init_bank(mesh_toy.loss_fn, params,
                               mesh_toy.make_batch(0), pol, cfg)
    step = jax.jit(make_train_step(mesh_toy.loss_fn, opt,
                                   schedules.constant(1e-3), pol,
                                   stats=cfg))
    p, st = params, opt.init(params)
    for s in range(3):
        p, st, bank, _ = step(p, st, bank, mesh_toy.make_batch(s),
                              jnp.int32(s))

    ck = CheckpointManager(str(tmp_path), compress=compress)
    ck.save(3, (p, st, bank))
    template = jax.tree_util.tree_map(jnp.zeros_like, (p, st, bank))
    (rp, rst, rbank), _ = ck.restore(template)
    for a, b in zip(jax.tree_util.tree_leaves(bank),
                    jax.tree_util.tree_leaves(rbank)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    site = next(iter(rbank))
    assert obs_metrics.has_telemetry(rbank[site][next(iter(rbank[site]))])


# ---------------------------------------------------------------------------
# doctor: saturating site flagged, healthy probe clean
# ---------------------------------------------------------------------------

def _toy_loss(p, b, pol):
    return jnp.sum(pol.dot(b, p["w"]) ** 2), {}


def test_doctor_flags_saturating_site():
    pol = make_policy("s2fp8_e4m3", backend="ref", gemm_mode="fig4")
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 8),
                                     jnp.float32) * 0.1}
    batch = jax.random.normal(jax.random.PRNGKey(2), (8, 16), jnp.float32)
    cfg = statsbank.StatsConfig(refresh_every=16)
    bank = statsbank.init_bank(_toy_loss, params, batch, pol, cfg)

    # healthy probe: warm the cold bank on an in-range batch -> clean
    warm, loss = obs_doctor.probe_bank(_toy_loss, params, batch, pol,
                                       bank, cfg, step=0)
    rows = obs_doctor.site_report(warm, step=0, refresh_every=16)
    assert rows
    assert all(obs_doctor.is_clean(r) for r in rows), rows
    assert all(r["recommend"] == "e4m3" for r in rows)
    assert np.isfinite(loss)

    # probe the warm bank with a 2^12x hotter batch: the carried stats
    # must report saturation and the rec must flip e4m3 -> e5m2
    hot, _ = obs_doctor.probe_bank(_toy_loss, params,
                                   batch * jnp.float32(2.0 ** 12), pol,
                                   warm, cfg, step=1)
    rows = obs_doctor.site_report(hot, step=1, refresh_every=16)
    worst = rows[0]
    assert worst["sat_frac"] > 0.0, worst
    assert "SAT" in worst["flags"]
    assert worst["recommend"] == "e5m2"
    assert not obs_doctor.is_clean(worst)
    report = obs_doctor.format_report(rows, backend="ref", loss=1.0)
    assert "verdict: worst site" in report and "SAT" in report


def test_recommend_fmt_rule():
    base = {"sat_frac": 0.0, "uflow_frac": 0.0}
    assert obs_doctor.recommend_fmt(base)[0] == "e4m3"
    assert obs_doctor.recommend_fmt({**base, "sat_frac": 0.01})[0] == "e5m2"
    assert obs_doctor.recommend_fmt(
        {**base, "uflow_frac": obs_doctor.UFLOW_THRESH + 0.01})[0] == "e5m2"


def test_doctor_probes_checkpointless_cold_bank(tmp_path):
    # the CLI path with no checkpoint: cold bank -> COLD is informational,
    # report still clean; exercises the full run() wiring cheaply via the
    # library (the CLI smoke runs in CI as `s2fp8-doctor --smoke`)
    pol = make_policy("s2fp8_e4m3", backend="ref", gemm_mode="fig4")
    params = {"w": jnp.ones((4, 4), jnp.float32) * 0.5}
    batch = jnp.ones((4, 4), jnp.float32)
    cfg = statsbank.StatsConfig(refresh_every=8)
    bank = statsbank.init_bank(_toy_loss, params, batch, pol, cfg)
    probed, _ = obs_doctor.probe_bank(_toy_loss, params, batch, pol, bank,
                                      cfg, step=0)
    rows = obs_doctor.site_report(probed, step=0, refresh_every=8)
    assert rows and all(obs_doctor.is_clean(r) for r in rows)


# ---------------------------------------------------------------------------
# 8-device mesh telemetry == 1-device, bitwise (order-exact toy)
# ---------------------------------------------------------------------------

_TELE_MESH_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
import mesh_toy
from repro import obs
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.obs import telemetry as obs_telemetry
from repro.optim import optimizers, schedules
from repro.training.trainer import make_train_step

mesh = jax.make_mesh((8, 1), ("data", "model"))
s8, p8, o8, b8, _ = mesh_toy.setup(mesh=mesh, telemetry=True)
s1, p1, o1, b1, _ = mesh_toy.setup(mesh=None, telemetry=True)
r8 = mesh_toy.run(s8, p8, o8, b8, 4)
r1 = mesh_toy.run(s1, p1, o1, b1, 4)
t8 = obs_telemetry.telemetry_state(r8[2], 4)
t1 = obs_telemetry.telemetry_state(r1[2], 4)
l8 = jax.tree_util.tree_leaves_with_path(t8)
l1 = jax.tree_util.tree_leaves_with_path(t1)
out = {"n_sites": len(t8),
       "same_structure": [str(p) for p, _ in l8] == [str(p) for p, _ in l1],
       "bitwise": all(np.array_equal(np.asarray(a), np.asarray(b),
                                     equal_nan=True)
                      for (_, a), (_, b) in zip(l8, l1))}

# io_callback drain through the sharded step: the callback is pinned to
# one device (regression: an unplaced callback in an 8-device program
# trips XLA sharding propagation) and each step emits exactly once
sink = obs.MemorySink()
pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
params = mesh_toy.make_params()
opt = optimizers.adamw()
cfg = statsbank.StatsConfig(refresh_every=2, telemetry=True)
bank = statsbank.init_bank(mesh_toy.loss_fn, params, mesh_toy.make_batch(0),
                           pol, cfg)
step = jax.jit(make_train_step(mesh_toy.loss_fn, opt,
                               schedules.constant(1e-3), pol, stats=cfg,
                               mesh=mesh, telemetry=obs.Telemetry(sink)))
p, st = params, opt.init(params)
for s in range(3):
    p, st, bank, m = step(p, st, bank, mesh_toy.make_batch(s), jnp.int32(s))
jax.block_until_ready((p, m))
jax.effects_barrier()
recs = sink.by_kind("site_health")
per_key = {}
for r in recs:
    k = (r["step"], r["site"], r["dir"])
    per_key[k] = per_key.get(k, 0) + 1
out["drain_records"] = len(recs)
out["drain_steps"] = sorted({r["step"] for r in recs})
out["drain_once_per_step"] = all(v == 1 for v in per_key.values())
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_telemetry_matches_single_device_bitwise():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([_SRC, _TESTS])
    proc = subprocess.run([sys.executable, "-c", _TELE_MESH_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["n_sites"] >= 1
    assert out["same_structure"] is True
    assert out["bitwise"] is True, out
    # pinned io_callback drain: every step ships each (site, dir) record
    # exactly once despite the 8-device program
    assert out["drain_steps"] == [0, 1, 2]
    assert out["drain_records"] == 3 * 6
    assert out["drain_once_per_step"] is True
