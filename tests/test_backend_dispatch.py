"""Numerics-backend registry + shape-generalizing kernel dispatch.

Core acceptance property of the refactor: the pallas backend (interpret
mode off-TPU) is BITWISE-identical to the ref backend — on odd ranks,
ragged shapes, and degenerate tensors — because its default stats mode
shares the reference reduction and the fused kernel replays the exact
elementwise op sequence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as nbackend
from repro.core import s2fp8
from repro.core.policy import make_policy
from repro.kernels import dispatch, ops, ref

jax.config.update("jax_platform_name", "cpu")

ODD_SHAPES = [(257,), (130, 70), (3, 5, 7), (2, 3, 4, 5)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = nbackend.available_backends()
    assert "ref" in names and "pallas" in names and "pallas_fused" in names
    assert nbackend.get_backend("ref").name == "ref"
    assert nbackend.get_backend("pallas").name == "pallas"
    # "auto"/None resolve to the platform default (ref on CPU)
    assert nbackend.get_backend("auto").name == nbackend.default_backend_name()
    assert nbackend.get_backend(None).name == nbackend.default_backend_name()


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError):
        nbackend.get_backend("cuda")
    with pytest.raises(ValueError):
        nbackend.register_backend("ref", nbackend.RefBackend())


def test_policy_rejects_unknown_backend():
    with pytest.raises(ValueError):
        make_policy("s2fp8", backend="int4")


# ---------------------------------------------------------------------------
# ref vs pallas(interpret) equivalence — the acceptance criterion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", ODD_SHAPES)
@pytest.mark.parametrize("scale", [1e-8, 1.0, 1e8])
def test_truncate_bitwise_identical_odd_shapes(shape, scale):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * scale
    r = np.asarray(nbackend.get_backend("ref").truncate(x))
    p = np.asarray(nbackend.get_backend("pallas").truncate(x))
    np.testing.assert_array_equal(p, r)


@pytest.mark.parametrize("fmt", ["e5m2", "e4m3"])
def test_truncate_bitwise_identical_both_formats(fmt):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 48)) * 1e-4
    r = np.asarray(nbackend.get_backend("ref").truncate(x, fmt=fmt))
    p = np.asarray(nbackend.get_backend("pallas").truncate(x, fmt=fmt))
    np.testing.assert_array_equal(p, r)


def test_truncate_degenerate_tensors():
    pal = nbackend.get_backend("pallas")
    # all-zero: stays exactly zero
    z = np.asarray(pal.truncate(jnp.zeros((37, 5))))
    assert (z == 0).all()
    # constant-magnitude: pure shift, values survive to ~1%
    c = np.asarray(pal.truncate(jnp.full((33, 9), 3.14159)))
    np.testing.assert_allclose(c, 3.14159, rtol=1e-2)
    r = np.asarray(nbackend.get_backend("ref").truncate(jnp.full((33, 9), 3.14159)))
    np.testing.assert_array_equal(c, r)


def test_policy_s2fp8_pallas_bitwise_identical_to_ref():
    """Policy(mode='s2fp8') routed through the pallas backend: identical
    GEMM results and identical truncated cotangents, bit for bit."""
    a = jax.random.normal(jax.random.PRNGKey(2), (66, 34)) * 1e-7
    b = jax.random.normal(jax.random.PRNGKey(3), (34, 18)) * 1e-7
    pr = make_policy("s2fp8", backend="ref")
    pp = make_policy("s2fp8", backend="pallas")
    np.testing.assert_array_equal(np.asarray(pp.dot(a, b)),
                                  np.asarray(pr.dot(a, b)))
    cot = jax.random.normal(jax.random.PRNGKey(4), (66, 18)) * 1e-9
    _, vr = jax.vjp(lambda a_: pr.dot(a_, b), a)
    _, vp = jax.vjp(lambda a_: pp.dot(a_, b), a)
    np.testing.assert_array_equal(np.asarray(vp(cot)[0]),
                                  np.asarray(vr(cot)[0]))
    # and under jit
    f = jax.jit(lambda a_, b_: pp.dot(a_, b_))
    np.testing.assert_array_equal(np.asarray(f(a, b)),
                                  np.asarray(pr.dot(a, b)))


def test_fused_stats_mode_float_parity():
    """The two-phase in-kernel stats path: float-tolerance parity (the
    blocked reduction order differs from the monolithic one)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 96)) * 1e5
    r = np.asarray(nbackend.get_backend("ref").truncate(x))
    p = np.asarray(nbackend.get_backend("pallas_fused").truncate(x))
    # zero sets (flush-to-zero boundary) agree except at stats-rounding edges
    assert ((r == 0) == (p == 0)).mean() > 0.995
    nz = (r != 0) & (p != 0)
    np.testing.assert_allclose(p[nz], r[nz], rtol=1e-3)


# ---------------------------------------------------------------------------
# storage path: quant / dequant / qmatmul on ragged + odd-rank tensors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_quant_dequant_odd_shapes(shape):
    x = jax.random.normal(jax.random.PRNGKey(6), shape) * 1e-4
    pal = nbackend.get_backend("pallas")
    t = pal.quantize(x)
    assert t.payload.shape == x.shape
    tr = s2fp8.quantize(x)
    np.testing.assert_allclose(float(t.alpha), float(tr.alpha), rtol=1e-4)
    np.testing.assert_allclose(float(t.beta), float(tr.beta),
                               rtol=1e-4, atol=1e-3)
    dk = np.asarray(pal.dequantize(t))
    dr = np.asarray(s2fp8.dequantize(tr))
    mask = (dk != 0) & (dr != 0)
    np.testing.assert_allclose(dk[mask], dr[mask], rtol=0.2)


def test_qmatmul_non_divisible_shapes():
    a = jax.random.normal(jax.random.PRNGKey(7), (130, 70))
    b = jax.random.normal(jax.random.PRNGKey(8), (70, 33))
    pal = nbackend.get_backend("pallas")
    ta, tb = pal.quantize(a), pal.quantize(b)
    out = np.asarray(pal.qmatmul(ta, tb))
    assert out.shape == (130, 33)
    exp = np.asarray(ref.s2fp8_matmul_ref(ta.payload, ta.alpha, ta.beta,
                                          tb.payload, tb.alpha, tb.beta))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-5)


def test_ops_wrappers_any_rank():
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 5, 7)) * 1e-3
    # forced-pallas path must accept non-2-D now
    p, a, b = ops.s2fp8_quant(x, use_pallas=True)
    assert p.shape == x.shape
    d = ops.s2fp8_dequant(p, a, b, use_pallas=True)
    assert d.shape == x.shape
    t = ops.s2fp8_truncate(x, use_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(t), np.asarray(nbackend.get_backend("ref").truncate(x)))


def test_policy_qdot_payload_domain_gemm():
    a = jax.random.normal(jax.random.PRNGKey(14), (66, 40)) * 1e-6
    b = jax.random.normal(jax.random.PRNGKey(15), (40, 24)) * 1e-6
    out = np.asarray(make_policy("s2fp8", backend="pallas").qdot(a, b))
    exact = np.asarray(jnp.dot(a, b))
    assert np.corrcoef(out.ravel(), exact.ravel())[0, 1] > 0.99
    # non-s2fp8 modes fall back to dot
    f32 = np.asarray(make_policy("fp32").qdot(a, b))
    np.testing.assert_array_equal(f32, np.asarray(jnp.dot(a, b)))
    # e4m3 storage parity: same path, e4m3 payloads (tests/test_qdot_train.py
    # covers the format in depth)
    out4 = np.asarray(make_policy("s2fp8_e4m3", backend="pallas").qdot(a, b))
    assert np.corrcoef(out4.ravel(), exact.ravel())[0, 1] > 0.99


def test_blocked_2d_roundtrip_exact():
    for shape in ODD_SHAPES:
        x = jax.random.normal(jax.random.PRNGKey(10), shape)
        x2 = dispatch.as_blocked_2d(x)
        assert x2.ndim == 2
        assert x2.shape[0] % min(256, x2.shape[0]) == 0
        back = dispatch.from_blocked_2d(x2, x.shape)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_blocked_2d_non_dividing_block_width():
    """A block width that does not divide the flattened lane must not
    interleave padding mid-row — the lane widens instead, so the
    flatten-and-slice inverse stays exact and truncation matches the ref."""
    x = jax.random.normal(jax.random.PRNGKey(17), (3, 5, 701)) * 1e-4
    for block in [(256, 384), (8, 640), (1024, 512)]:
        x2 = dispatch.as_blocked_2d(x, block)
        back = dispatch.from_blocked_2d(x2, x.shape)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        out = dispatch.truncate_nd(x, block=block)
        ref_out = nbackend.get_backend("ref").truncate(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))


# ---------------------------------------------------------------------------
# delayed stats
# ---------------------------------------------------------------------------

def test_truncate_delayed_functional():
    be = nbackend.get_backend(None)
    x = jax.random.normal(jax.random.PRNGKey(11), (64, 32)) * 1e-5
    y0, stats = nbackend.truncate_delayed(x, None)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(be.truncate(x)))
    # reuse: same stats object threads through, output uses stale stats
    x2 = x * 1.01
    y1, stats1 = nbackend.truncate_delayed(x2, stats, refresh=False)
    assert stats1 is stats
    np.testing.assert_array_equal(
        np.asarray(y1), np.asarray(be.truncate(x2, stats=stats)))
    # refresh recomputes
    _, stats2 = nbackend.truncate_delayed(x2, stats, refresh=True)
    assert float(stats2[1]) != float(stats[1])


def test_delayed_stats_cache_refresh_cadence():
    cache = nbackend.DelayedStatsCache(backend="ref", refresh_every=4)
    x = jax.random.normal(jax.random.PRNGKey(12), (128,)) * 1e-6
    outs = [cache.truncate(x * (1 + 0.001 * i), "g", i) for i in range(9)]
    assert all(np.isfinite(np.asarray(o)).all() for o in outs)
    # steps 0..3 share the step-0 stats; step 4 refreshed
    assert cache._last_refresh["g"] == 8
    with pytest.raises(ValueError):
        nbackend.DelayedStatsCache(refresh_every=0)


def test_delayed_stats_saturate_not_overflow_on_narrow_distributions():
    """Narrow-distribution tensors get a huge alpha; stale stats after an
    upward drift would push the forward image past e5m2's max finite.
    The clamp must saturate (finite) rather than overflow to inf — on
    both backends, identically."""
    noise = 1.0 + 1e-3 * jax.random.normal(jax.random.PRNGKey(16), (64,))
    x = 3.0 * noise                                   # near-constant magnitude
    _, stats = nbackend.truncate_delayed(x, None)
    drifted = x * 1.02                                # 2% upward drift
    for name in ("ref", "pallas"):
        y, _ = nbackend.truncate_delayed(drifted, stats, refresh=False,
                                         backend=name)
        assert np.isfinite(np.asarray(y)).all(), name
    yr, _ = nbackend.truncate_delayed(drifted, stats, refresh=False,
                                      backend="ref")
    yp, _ = nbackend.truncate_delayed(drifted, stats, refresh=False,
                                      backend="pallas")
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))


def test_quantize_stale_stats_saturates_on_both_backends():
    """quantize(stats=...) with stale stats after upward drift must clamp
    the payload at e5m2 max finite (no inf) — identically on ref and
    pallas (the apply kernel mirrors the reference clamp)."""
    noise = 1.0 + 1e-3 * jax.random.normal(jax.random.PRNGKey(18), (64,))
    x = 3.0 * noise
    stats = nbackend.get_backend("ref").compute_stats(x)
    drifted = x * 1.02
    payloads = []
    for name in ("ref", "pallas"):
        t = nbackend.get_backend(name).quantize(drifted, stats=stats)
        p32 = np.asarray(t.payload).astype(np.float32)
        assert np.isfinite(p32).all(), name
        payloads.append(np.asarray(t.payload).view(np.uint8))
    np.testing.assert_array_equal(payloads[0], payloads[1])


def test_delayed_stats_accuracy_under_drift():
    """Stale-by-k stats on a slowly drifting tensor stay accurate — the
    premise that makes the amortization safe."""
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (512,)) * 1e-6
    _, stats = nbackend.truncate_delayed(x, None)
    drifted = x * 1.05                                # 5% scale drift
    y_stale, _ = nbackend.truncate_delayed(drifted, stats, refresh=False)
    xn, yn = np.asarray(drifted), np.asarray(y_stale)
    nz = yn != 0
    rel = np.abs(yn[nz] - xn[nz]) / np.abs(xn[nz])
    assert np.median(rel) < 0.06
