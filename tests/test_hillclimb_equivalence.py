"""Every §Perf optimization must be numerically equivalent to its baseline
(same math, different schedule) — these tests pin that invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.policy import make_policy
from repro.models import blocks

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)
POL = make_policy("fp32")


def test_mamba2_ssd_equals_step_scan():
    cfg = get_reduced_config("zamba2_1p2b").replace(activation_dtype="float32")
    p = blocks.init_block("mamba2", cfg, KEY)
    x = jax.random.normal(KEY, (2, 128, cfg.d_model)) * 0.1
    pos = jnp.arange(128)
    y0, _, _ = blocks.block_apply("mamba2", p, x, cfg, POL, pos, None, 0, "train")
    y1, _, _ = blocks.block_apply("mamba2", p, x, cfg.replace(ssm_impl="ssd"),
                                  POL, pos, None, 0, "train")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-5)


def test_mamba2_ssd_gradients_match():
    cfg = get_reduced_config("zamba2_1p2b").replace(activation_dtype="float32")
    p = blocks.init_block("mamba2", cfg, KEY)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model)) * 0.1
    pos = jnp.arange(64)

    def loss(impl):
        c = cfg.replace(ssm_impl=impl)
        return lambda xx: jnp.sum(
            blocks.block_apply("mamba2", p, xx, c, POL, pos, None, 0,
                               "train")[0] ** 2)

    g0 = jax.grad(loss("step"))(x)
    g1 = jax.grad(loss("ssd"))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0),
                               rtol=2e-3, atol=2e-4)


def test_mamba1_unroll8_exact():
    cfg = get_reduced_config("falcon_mamba_7b").replace(
        activation_dtype="float32")
    p = blocks.init_block("mamba1", cfg, KEY)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.1
    pos = jnp.arange(64)
    y0, _, _ = blocks.block_apply("mamba1", p, x, cfg, POL, pos, None, 0, "train")
    y1, _, _ = blocks.block_apply("mamba1", p, x,
                                  cfg.replace(ssm_impl="unroll8"),
                                  POL, pos, None, 0, "train")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_moe_grouped_equals_global_when_capacity_ample():
    cfg = get_reduced_config("deepseek_moe_16b").replace(
        activation_dtype="float32")
    p = blocks.init_block("moe", cfg, KEY)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.5
    pos = jnp.arange(64)
    y0, _, a0 = blocks.block_apply("moe", p, x, cfg, POL, pos, None, 0, "train")
    cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe, routing="grouped"))
    y1, _, a1 = blocks.block_apply("moe", p, x, cfg_g, POL, pos, None, 0, "train")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a1), float(a0), rtol=1e-5)


def test_bf16_output_dtype_close():
    """output_dtype=bf16 (collective lever) must stay within bf16 rounding
    of the f32-output policy on a GEMM chain."""
    pol32 = make_policy("fp32")
    pol16 = dataclasses.replace(pol32, output_dtype="bfloat16")
    a = jax.random.normal(KEY, (64, 128)) * 0.3
    w1 = jax.random.normal(jax.random.fold_in(KEY, 1), (128, 256)) * 0.1
    w2 = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 32)) * 0.1
    y32 = pol32.dot(pol32.dot(a, w1), w2)
    y16 = pol16.dot(pol16.dot(a, w1), w2)
    np.testing.assert_allclose(np.asarray(y16, np.float32), np.asarray(y32),
                               rtol=0.03, atol=0.03)
