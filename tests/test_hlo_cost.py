"""HLO static cost analyzer: trip-count multiplication, dot flops, bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import cost_of, parse_module

jax.config.update("jax_platform_name", "cpu")


def _compile(f, *structs):
    return jax.jit(f).lower(*structs).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                   jax.ShapeDtypeStruct((12, 64, 64), jnp.float32))
    c = cost_of(hlo)
    assert c.flops == pytest.approx(2 * 64 * 64 * 64 * 12, rel=0.01)


def test_plain_dot_flops_exact():
    def f(a, b):
        return a @ b

    hlo = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                   jax.ShapeDtypeStruct((256, 64), jnp.float32))
    c = cost_of(hlo)
    assert c.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    # traffic at least inputs + output once each
    min_bytes = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert c.bytes >= min_bytes * 0.9


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c2, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    hlo = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                   jax.ShapeDtypeStruct((5, 32, 32), jnp.float32))
    c = cost_of(hlo)
    assert c.flops == pytest.approx(2 * 32 ** 3 * 3 * 5, rel=0.01)


def test_entry_detected_on_real_module():
    def f(a):
        return jnp.sum(a * 2.0)

    hlo = _compile(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
    comps, entry, _ = parse_module(hlo)
    assert entry is not None
    assert comps[entry]


def test_grad_of_scan_counts_backward():
    def loss(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)
    hlo = _compile(g, jax.ShapeDtypeStruct((6, 32, 32), jnp.float32),
                   jax.ShapeDtypeStruct((32, 32), jnp.float32))
    c = cost_of(hlo)
    fwd = 2 * 32 ** 3 * 6
    # forward + 2 backward matmuls per layer => >= 3x forward-ish
    assert c.flops > 2.5 * fwd
