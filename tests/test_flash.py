"""flash custom-VJP attention (hillclimb #1) vs the naive oracle: values AND
gradients must match — this is the 'debug forward, keep the speedup'
guarantee for the §Perf work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.blocks import chunked_attention
from repro.models.flash import flash_attention

jax.config.update("jax_platform_name", "cpu")


def _inputs(sq=256, sk=256, b=1, kvh=2, g=2, d=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, kvh, g, sq, d))
    k = jax.random.normal(ks[1], (b, kvh, sk, d))
    v = jax.random.normal(ks[2], (b, kvh, sk, d))
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_forward_matches_naive(causal, window):
    q, k, v = _inputs()
    out = flash_attention(q, k, v, causal, window, 64, 64)
    exp = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64)])
def test_flash_gradients_match_naive(causal, window):
    q, k, v = _inputs(sq=128, sk=128)
    cot = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    def f_flash(q_, k_, v_):
        return jnp.vdot(flash_attention(q_, k_, v_, causal, window, 64, 64),
                        cot)

    def f_naive(q_, k_, v_):
        return jnp.vdot(chunked_attention(q_, k_, v_, causal=causal,
                                          window=window, q_chunk=64,
                                          kv_chunk=64), cot)

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-5, err_msg=name)


def test_flash_rectangular_decode_chunk():
    q, k, v = _inputs(sq=64, sk=256)
    out = flash_attention(q, k, v, True, None, 64, 64)
    # oracle via ref.attention_ref on flattened heads
    b, kvh, g, sq, d = q.shape
    qf = q.reshape(b, kvh * g, sq, d)
    kf = jnp.repeat(k, g, axis=1)
    vf = jnp.repeat(v, g, axis=1)
    exp = ref.attention_ref(qf, kf, vf, causal=True).reshape(out.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


def test_flash_in_model_grad_matches_naive():
    """End-to-end: a 1-layer LM with attn_impl flash vs naive, same grads."""
    from repro.configs import get_reduced_config
    from repro.core.policy import make_policy
    from repro.models import transformer as tlm

    base = get_reduced_config("stablelm_12b").replace(
        n_layers=1, remat=False, activation_dtype="float32")
    pol = make_policy("fp32")
    params = tlm.init_lm(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4096), 0, base.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (1, 4096), 0, base.vocab)

    outs = {}
    for impl in ["naive", "flash"]:
        cfg = base.replace(attn_impl=impl)
        loss, _ = tlm.loss_fn(params, toks, labels, cfg, pol)
        outs[impl] = float(loss)
    assert abs(outs["naive"] - outs["flash"]) < 1e-4 * abs(outs["naive"])
