"""Core S2FP8 format tests: Eq. 1–5 invariants + hypothesis property tests.

The property tests need ``hypothesis``; when it is absent they skip
cleanly (a single placeholder reports the skip) so the deterministic
tier-1 tests always collect and run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - env-dependent
    given = settings = st = None

from repro.core import fp8, s2fp8

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# deterministic unit tests
# ---------------------------------------------------------------------------

def test_fp8_matches_paper_table_a1():
    # max normal (1 - 2^-3) * 2^16 = 57344; min subnormal 2^-16; eps 2^-3
    assert fp8.E5M2_MAX == (1 - 2.0 ** -3) * 2 ** 16
    # (1 + 2^-3 is an RNE tie — rounds to even; use the exact grid point 1.25)
    x = jnp.array([57344.0, 2.0 ** -16, 1.25], jnp.float32)
    t = fp8.truncate_e5m2(x)
    np.testing.assert_allclose(np.asarray(t), np.asarray(x))
    # overflow -> inf (raw FP8's failure mode, deliberately preserved)
    assert np.isinf(float(fp8.truncate_e5m2(jnp.float32(1e6))))
    # underflow of tiny values -> 0
    assert float(fp8.truncate_e5m2(jnp.float32(1e-30))) == 0.0


def test_stats_satisfy_eq2():
    """alpha/beta must give log2|Y| zero-mean and max exactly 15 (Eq. 2)."""
    key = jax.random.PRNGKey(0)
    for scale in [1e-8, 1.0, 1e6]:
        x = jax.random.normal(key, (4096,)) * scale
        alpha, beta = s2fp8.compute_stats(x)
        y = s2fp8._forward_map(x, alpha, beta)
        logy = np.log2(np.abs(np.asarray(y[y != 0])))
        assert abs(logy.mean()) < 1e-2
        np.testing.assert_allclose(logy.max(), 15.0, atol=1e-3)


def test_eq4_alpha_beta_closed_form():
    x = jnp.array([0.5, 2.0, 8.0], jnp.float32)
    logx = np.log2(np.abs(np.asarray(x)))
    mu, m = logx.mean(), logx.max()
    alpha, beta = s2fp8.compute_stats(x)
    np.testing.assert_allclose(float(alpha), 15.0 / (m - mu), rtol=1e-5)
    np.testing.assert_allclose(float(beta), -float(alpha) * mu, rtol=1e-5)


def test_roundtrip_error_law():
    """X-domain log2 error <= e5m2 worst-case log error / alpha."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (8192,)) * 1e-6
    alpha, _ = s2fp8.compute_stats(x)
    t = np.asarray(s2fp8.truncate_value(x))
    xn = np.asarray(x)
    nz = t != 0
    logerr = np.abs(np.log2(np.abs(t[nz])) - np.log2(np.abs(xn[nz])))
    # worst case in Y-log domain is 1 (denormal region); typical is 2^-3
    assert logerr.max() <= 1.05 / float(alpha)


def test_out_of_range_tensors_survive():
    """The paper's headline: tensors far outside FP8 range survive S2FP8."""
    for scale in [1e-30, 1e-12, 1e12, 1e30]:
        x = jax.random.normal(jax.random.PRNGKey(2), (1024,)) * scale
        t = np.asarray(s2fp8.truncate_value(x))
        xn = np.asarray(x)
        nz = t != 0
        assert nz.mean() > 0.9                       # almost nothing flushed
        rel = np.abs(t[nz] - xn[nz]) / np.abs(xn[nz])
        assert np.median(rel) < 0.05
        # raw FP8 destroys the same tensor
        raw = np.asarray(fp8.truncate_e5m2(x))
        destroyed = (~np.isfinite(raw)) | (raw == 0)
        assert destroyed.mean() > 0.9


def test_zeros_and_signs():
    x = jnp.array([0.0, -0.0, 1.5, -1.5, 0.0], jnp.float32)
    t = np.asarray(s2fp8.truncate_value(x))
    assert (t[[0, 1, 4]] == 0).all()
    assert t[2] > 0 and t[3] < 0
    np.testing.assert_allclose(t[2], -t[3])


def test_degenerate_constant_tensor():
    x = jnp.full((128,), 3.14159, jnp.float32)
    t = np.asarray(s2fp8.truncate_value(x))
    np.testing.assert_allclose(t, 3.14159, rtol=1e-2)


def test_all_zero_tensor():
    t = s2fp8.truncate_value(jnp.zeros((64,)))
    assert (np.asarray(t) == 0).all()


def test_quantize_dequantize_storage():
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 128)) * 1e-4
    q = s2fp8.quantize(x)
    assert q.payload.dtype == jnp.float8_e5m2
    d = s2fp8.dequantize(q)
    direct = s2fp8.truncate_value(x)
    np.testing.assert_allclose(np.asarray(d), np.asarray(direct), rtol=1e-6)


def test_nbytes_payload_counts_stats_once():
    """Wire size = 1 byte per element + exactly 8 bytes for the single
    (alpha, beta) f32 pair — regardless of rank."""
    for shape in [(64, 32), (7,), (3, 4, 5)]:
        q = s2fp8.quantize(jnp.ones(shape))
        n_elems = int(np.prod(shape))
        assert q.nbytes_payload == n_elems + 8
        # the stats overhead is a fixed 8 bytes, not per-element or doubled
        assert q.nbytes_payload - n_elems == 8


def test_ste_gradient_identity():
    x = jax.random.normal(jax.random.PRNGKey(4), (64,))
    g = jax.grad(lambda v: jnp.sum(s2fp8.truncate_ste(v) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_bidir_gradient_is_truncated():
    x = jax.random.normal(jax.random.PRNGKey(5), (512,))
    cot = jax.random.normal(jax.random.PRNGKey(6), (512,)) * 1e-9
    _, vjp = jax.vjp(s2fp8.truncate_bidir, x)
    (g,) = vjp(cot)
    expect = s2fp8.truncate_value(cot)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis property tests (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

if st is None:
    def test_property_suite_requires_hypothesis():
        """Placeholder: reports the property suite as skipped."""
        pytest.importorskip("hypothesis")


_F32_BIG = 1.0000000200408773e+20     # exactly representable in f32
finite_arrays = st.lists(
    st.floats(min_value=-_F32_BIG, max_value=_F32_BIG, allow_nan=False,
              allow_infinity=False, width=32),
    min_size=2, max_size=256) if st is not None else None


if st is not None:
    @settings(max_examples=60, deadline=None)
    @given(finite_arrays)
    def test_prop_roundtrip_finite_and_sign_preserving(vals):
        x = jnp.asarray(vals, jnp.float32)
        t = np.asarray(s2fp8.truncate_value(x))
        assert np.isfinite(t).all()                   # S2FP8 never overflows
        xn = np.asarray(x)
        nz = (t != 0) & (xn != 0)
        assert (np.sign(t[nz]) == np.sign(xn[nz])).all()
        # magnitudes never exceed the tensor max (max maps to exactly 2^15 in Y)
        if nz.any():
            assert np.abs(t).max() <= np.abs(xn).max() * 1.2

    @settings(max_examples=60, deadline=None)
    @given(finite_arrays, st.floats(min_value=-30, max_value=30))
    def test_prop_scale_covariance(vals, log_scale):
        """S2FP8 is (approximately) scale-covariant: T(c*x) ~ c*T(x) for c=2^k.

        Power-of-two scaling shifts mu and m equally -> identical alpha,
        shifted beta -> identical quantization grid in the scaled domain.
        """
        c = float(2.0 ** round(log_scale))
        x = jnp.asarray(vals, jnp.float32)
        # guard in f32 (the model's arithmetic): scaling must not push any
        # element into f32 overflow or the subnormal flush region — those are
        # f32 edge effects, not properties of the S2FP8 format.
        xc32 = np.asarray(x, np.float32) * np.float32(c)
        if not np.isfinite(xc32).all():
            return
        nz = np.asarray(x) != 0
        if (np.abs(xc32[nz]) < 1e-30).any() or (np.abs(xc32[nz]) > 1e30).any():
            return
        t1 = np.asarray(s2fp8.truncate_value(x)) * c
        t2 = np.asarray(s2fp8.truncate_value(x * c))
        mask = np.isfinite(t1) & (np.abs(t1) > 0) & (t2 != 0)
        np.testing.assert_allclose(t1[mask], t2[mask], rtol=1e-3)

    @settings(max_examples=40, deadline=None)
    @given(finite_arrays)
    def test_prop_idempotent(vals):
        """Truncating an already-truncated tensor changes (almost) nothing.

        Exact idempotence does not hold (stats move once flushed values drop
        out), but surviving values must stay within one quantization step.
        """
        x = jnp.asarray(vals, jnp.float32)
        t1 = s2fp8.truncate_value(x)
        t2 = np.asarray(s2fp8.truncate_value(t1))
        t1 = np.asarray(t1)
        nz = (t1 != 0) & (t2 != 0)
        if nz.any():
            alpha, _ = s2fp8.compute_stats(t1)
            logerr = np.abs(np.log2(np.abs(t2[nz])) - np.log2(np.abs(t1[nz])))
            assert logerr.max() <= 1.1 / max(float(alpha), 1e-3)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1e-12, 1e-4, 1.0, 1e4, 1e12]))
    def test_prop_relative_error_bounded_for_gaussians(seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * scale
        t = np.asarray(s2fp8.truncate_value(x))
        xn = np.asarray(x)
        nz = t != 0
        rel = np.abs(t[nz] - xn[nz]) / np.abs(xn[nz])
        assert np.median(rel) < 0.05
