"""Resilience layer suite: StepGuard, snapshot ring, escalation ladder,
hardened checkpoint I/O, and the watchdog fixes.

Covers the ISSUE 8 acceptance criteria that live below the chaos matrix
(tests/test_chaos.py runs the matrix itself):

  * Watchdog: bounded deque (no unbounded ``times`` growth), true
    even-window median, min_history clamp;
  * StepGuard verdict units (nonfinite / spike / sat / forced; EMA only
    integrates accepted steps; warmup arming) and the fused [2, N] bank
    probe (ONE reduce);
  * a rejected step leaves params/opt_state/StatsBank/guard carry
    bit-identical to pre-step, under jit (fast) and under an 8-device
    mesh (slow subprocess, order-exact tests/mesh_toy.py setup);
  * jaxpr budget: the guarded banked steady-state step runs exactly the
    fp32 baseline's reductions + 1 bookkeeping min outside lax.cond —
    with and without telemetry + the saturation sentinel, meshless and
    sharded (the PR 5/7 invariant, unchanged by the guard);
  * CheckpointManager hardening: manifest validation (truncate / bitflip
    / missing manifest all fail closed), quarantine + fallback to the
    newest VALID step with a ``checkpoint_quarantined`` event, explicit
    steps raise, transient-I/O retry with backoff;
  * TrainLoop ``maybe_resume`` (the ``--resume auto`` path) with the
    newest checkpoint deliberately corrupted resumes from the previous
    valid step;
  * watchdog escalation: N consecutive trips push a proactive snapshot
    and emit ``watchdog_escalated``.
"""
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import mesh_toy
from repro.checkpoint.manager import CheckpointManager
from repro.core import statsbank
from repro.core.policy import make_policy
from repro.obs import sinks as obs_sinks
from repro.optim import optimizers, schedules
from repro.training import chaos as chaos_mod
from repro.training import fault
from repro.training import guard as guard_mod
from repro.training.trainer import TrainLoop, make_train_step

jax.config.update("jax_platform_name", "cpu")

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_TESTS = os.path.dirname(__file__)


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([_SRC, _TESTS])
    return env


def _assert_trees_bitwise(a, b, msg=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (pa, xa), (_, xb) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"{msg} leaf {jax.tree_util.keystr(pa)}")


# ---------------------------------------------------------------------------
# Watchdog: bounded deque + even-window median (the satellite fixes)
# ---------------------------------------------------------------------------

def test_watchdog_times_bounded_at_window():
    wd = fault.Watchdog(factor=3.0, window=8, min_history=4)
    for s in range(100):
        wd.observe(s, 0.1)
    assert len(wd.times) == 8        # a million-step run must not grow this


def test_watchdog_even_window_median_averages_middle_pair():
    # trailing times {0.1, 0.1, 0.3, 0.3}: true median 0.2; the old
    # upper-middle bug would read 0.3.  dt=0.5 discriminates: it exceeds
    # 2 x 0.2 but NOT 2 x 0.3.
    wd = fault.Watchdog(factor=2.0, window=4, min_history=4)
    for s, dt in enumerate([0.1, 0.1, 0.3, 0.3]):
        assert wd.observe(s, dt) is None
    ev = wd.observe(4, 0.5)
    assert ev is not None, "even-window median must average the middle pair"
    assert ev["median_s"] == pytest.approx(0.2)


def test_watchdog_min_history_clamped_to_window():
    # min_history > window could never accumulate in the bounded deque —
    # the detector would be permanently disarmed
    wd = fault.Watchdog(factor=2.0, window=4, min_history=100)
    assert wd.min_history == 4
    for s in range(4):
        wd.observe(s, 0.1)
    assert wd.observe(4, 10.0) is not None


def test_watchdog_validation():
    with pytest.raises(ValueError):
        fault.Watchdog(factor=0.0)
    with pytest.raises(ValueError):
        fault.Watchdog(window=0)


# ---------------------------------------------------------------------------
# StepGuard verdict units
# ---------------------------------------------------------------------------

def test_guard_config_validation():
    with pytest.raises(ValueError, match="spike_factor"):
        guard_mod.GuardConfig(spike_factor=1.0)
    with pytest.raises(ValueError, match="ema_decay"):
        guard_mod.GuardConfig(ema_decay=1.0)


def test_guard_accepts_healthy_step_and_seeds_ema():
    cfg = guard_mod.GuardConfig()
    st = guard_mod.init_state()
    flags, st1 = guard_mod.evaluate(cfg, st, jnp.float32(1.0),
                                    jnp.float32(2.0))
    assert bool(flags["ok"]) and bool(flags["ok_bank"])
    assert not any(bool(flags[c]) for c in ("nonfinite", "spike", "forced"))
    assert float(st1["steps"]) == 1.0
    assert float(st1["gnorm_ema"]) == 2.0      # first accepted step seeds


def test_guard_trips_on_nonfinite_and_freezes_carry():
    cfg = guard_mod.GuardConfig()
    st = {"gnorm_ema": jnp.float32(3.0), "steps": jnp.float32(5.0)}
    for loss, gn in ((jnp.float32(np.nan), jnp.float32(1.0)),
                     (jnp.float32(np.inf), jnp.float32(1.0)),
                     (jnp.float32(1.0), jnp.float32(np.nan)),
                     (jnp.float32(1.0), jnp.float32(np.inf))):
        flags, st1 = guard_mod.evaluate(cfg, st, loss, gn)
        assert not bool(flags["ok"]) and not bool(flags["ok_bank"])
        assert bool(flags["nonfinite"])
        # the rejected step "didn't happen": EMA and counter untouched
        assert float(st1["gnorm_ema"]) == 3.0
        assert float(st1["steps"]) == 5.0


def test_guard_spike_requires_warmup():
    cfg = guard_mod.GuardConfig(spike_factor=10.0, warmup=8)
    hot = jnp.float32(50.0)
    cold = {"gnorm_ema": jnp.float32(1.0), "steps": jnp.float32(3.0)}
    flags, st1 = guard_mod.evaluate(cfg, cold, jnp.float32(1.0), hot)
    assert bool(flags["ok"]), "spike sentinel must stay disarmed in warmup"
    armed = {"gnorm_ema": jnp.float32(1.0), "steps": jnp.float32(8.0)}
    flags, st1 = guard_mod.evaluate(cfg, armed, jnp.float32(1.0), hot)
    assert not bool(flags["ok"]) and bool(flags["spike"])
    assert float(st1["gnorm_ema"]) == 1.0      # rejected: EMA frozen


def test_guard_ema_integrates_accepted_steps():
    cfg = guard_mod.GuardConfig(ema_decay=0.5)
    st = {"gnorm_ema": jnp.float32(2.0), "steps": jnp.float32(1.0)}
    flags, st1 = guard_mod.evaluate(cfg, st, jnp.float32(1.0),
                                    jnp.float32(4.0))
    assert bool(flags["ok"])
    assert float(st1["gnorm_ema"]) == pytest.approx(3.0)   # 0.5*2 + 0.5*4
    assert float(st1["steps"]) == 2.0


def test_guard_saturation_rejects_update_but_accepts_bank():
    cfg = guard_mod.GuardConfig(sat_threshold=0.5)
    st = guard_mod.init_state()
    flags, _ = guard_mod.evaluate(cfg, st, jnp.float32(1.0),
                                  jnp.float32(1.0),
                                  sat_margin=jnp.float32(-0.1))
    assert not bool(flags["ok"]) and bool(flags["sat"])
    # the refresh that measured the saturation is the remedy — keep it
    assert bool(flags["ok_bank"])


def test_guard_forced_reject():
    cfg = guard_mod.GuardConfig()
    st = guard_mod.init_state()
    flags, _ = guard_mod.evaluate(cfg, st, jnp.float32(1.0),
                                  jnp.float32(1.0),
                                  force_reject=jnp.bool_(True))
    assert not bool(flags["ok"]) and not bool(flags["ok_bank"])
    assert bool(flags["forced"])


def test_flag_metrics_excludes_ok_bank():
    flags = {"ok": jnp.bool_(True), "ok_bank": jnp.bool_(True),
             "nonfinite": jnp.bool_(False), "spike": jnp.bool_(False),
             "sat": jnp.bool_(False), "forced": jnp.bool_(False)}
    m = guard_mod.flag_metrics(flags)
    assert "guard_ok" in m and "guard_ok_bank" not in m
    assert all(v.dtype == jnp.float32 for v in m.values())


# ---------------------------------------------------------------------------
# the fused [2, N] bank probe
# ---------------------------------------------------------------------------

def _probe_banks():
    input_bank = {
        "a": {"fwd": {"last": jnp.float32(5.0), "sat_frac": jnp.float32(0.0)},
              "bwd": {"last": jnp.float32(-1.0),
                      "sat_frac": jnp.float32(0.0)}}}
    new_bank = {
        "a": {"fwd": {"last": jnp.float32(5.0), "sat_frac": jnp.float32(0.1)},
              "bwd": {"last": jnp.float32(6.0),
                      "sat_frac": jnp.float32(0.6)}}}
    return input_bank, new_bank


def test_bank_probe_values():
    input_bank, new_bank = _probe_banks()
    cold, margin = guard_mod.bank_probe(input_bank, new_bank, 0.5)
    assert float(cold) == -1.0               # cold row reads the INPUT bank
    assert float(margin) == pytest.approx(-0.1)   # 0.5 - max(sat_frac)
    # sentinel off: margin None, cold probe degrades to the plain min
    cold, margin = guard_mod.bank_probe(input_bank, new_bank, 0.0)
    assert float(cold) == -1.0 and margin is None


def test_bank_probe_pads_ragged_rows():
    # sat leaves on only ONE direction: the rows have different lengths
    # and must pad with +inf (which can never win a min)
    input_bank = {"a": {"fwd": {"last": jnp.float32(2.0)},
                        "bwd": {"last": jnp.float32(3.0)}}}
    new_bank = {"a": {"fwd": {"last": jnp.float32(2.0),
                              "sat_frac": jnp.float32(0.9)},
                      "bwd": {"last": jnp.float32(3.0)}}}
    cold, margin = guard_mod.bank_probe(input_bank, new_bank, 0.5)
    assert float(cold) == 2.0
    assert float(margin) == pytest.approx(-0.4)


def test_bank_probe_is_one_reduction():
    input_bank, new_bank = _probe_banks()
    jx = jax.make_jaxpr(
        lambda a, b: guard_mod.bank_probe(a, b, 0.5))(input_bank, new_bank)
    assert statsbank.count_reductions(jx) == 1


def test_saturation_leaves_none_without_telemetry():
    bank = {"a": {"fwd": {"last": jnp.float32(1.0)}}}
    assert guard_mod.saturation_leaves(bank) is None


def test_force_refresh_only_touches_bwd_carrying_sites():
    bank = {"gemm": {"x_fwd": {"last": jnp.float32(5.0)},
                     "dy_bwd": {"last": jnp.float32(5.0)}},
            "readonly": {"x_fwd": {"last": jnp.float32(7.0)}}}
    out = statsbank.force_refresh(bank)
    assert float(out["gemm"]["x_fwd"]["last"]) == -1.0
    assert float(out["gemm"]["dy_bwd"]["last"]) == -1.0
    # merge_updates carries read-only sites' INPUT forward: a -1 there
    # would never clear
    assert float(out["readonly"]["x_fwd"]["last"]) == 7.0


# ---------------------------------------------------------------------------
# SnapshotRing
# ---------------------------------------------------------------------------

def _snap_tree():
    rng = np.random.RandomState(0)
    return {"w": jnp.asarray(rng.randn(128, 64).astype(np.float32)),
            "b": jnp.asarray(rng.randn(16).astype(np.float32)),
            "count": jnp.int32(7)}


def test_snapshot_ring_validation():
    with pytest.raises(ValueError):
        guard_mod.SnapshotRing(size=0)


def test_snapshot_ring_bounded_depth_and_latest():
    ring = guard_mod.SnapshotRing(size=3)
    tree = _snap_tree()
    for s in range(6):
        ring.push(s, tree)
    assert len(ring) == 3
    step, _ = ring.latest()
    assert step == 5
    assert guard_mod.SnapshotRing(size=2).latest() is None


def test_snapshot_ring_uncompressed_roundtrip_bitwise():
    ring = guard_mod.SnapshotRing(size=2)
    tree = _snap_tree()
    ring.push(4, tree)
    _, back = ring.latest()
    _assert_trees_bitwise(back, tree, "ring")


def test_snapshot_ring_compressed_lossy_but_close():
    ring = guard_mod.SnapshotRing(size=2, compress=True)
    tree = _snap_tree()
    ring.push(4, tree)
    _, back = ring.latest()
    # the big 2-D f32 leaf took the S2FP8 codec: lossy but tight
    assert not np.array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    err = np.abs(np.asarray(back["w"]) - np.asarray(tree["w"]))
    assert np.median(err / (np.abs(np.asarray(tree["w"])) + 1e-6)) < 0.1
    # small / integer leaves stay raw -> bit-exact
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(tree["b"]))
    assert int(back["count"]) == 7


# ---------------------------------------------------------------------------
# rejected step is bitwise-invisible (jit, fast lane)
# ---------------------------------------------------------------------------

def _chaos_batch(s, reject_at=-1, nan_at=-1, inf_at=-1):
    b = dict(mesh_toy.make_batch(s))
    b["_chaos"] = {"nan_grad": jnp.int32(nan_at),
                   "inf_loss": jnp.int32(inf_at),
                   "reject": jnp.int32(reject_at)}
    return b


@pytest.mark.parametrize("injector", ["reject", "nan_grad", "inf_loss"])
def test_rejected_step_bitwise_under_jit(injector):
    step, params, opt_state, bank, _ = mesh_toy.setup(
        guard=guard_mod.GuardConfig())
    gs = guard_mod.init_state()
    for s in range(3):
        params, opt_state, bank, gs, m = step(
            params, opt_state, bank, gs, _chaos_batch(s), jnp.int32(s))
        assert float(m["guard_ok"]) == 1.0
    pre = jax.device_get((params, opt_state, bank, gs))
    kw = {{"reject": "reject_at", "nan_grad": "nan_at",
           "inf_loss": "inf_at"}[injector]: 3}
    p2, o2, b2, g2, m = step(params, opt_state, bank, gs,
                             _chaos_batch(3, **kw), jnp.int32(3))
    assert float(m["guard_ok"]) == 0.0
    cause = "forced" if injector == "reject" else "nonfinite"
    assert float(m[f"guard_{cause}"]) == 1.0
    _assert_trees_bitwise(jax.device_get((p2, o2, b2, g2)), pre,
                          f"rejected-{injector}")


# ---------------------------------------------------------------------------
# jaxpr budget: guard adds ZERO reductions outside lax.cond
# ---------------------------------------------------------------------------

def _toy_jaxpr(mesh, policy, stats_cfg, guard=None, with_chaos=False):
    opt = optimizers.adamw()
    params = mesh_toy.make_params()
    args = [params, opt.init(params)]
    if stats_cfg is not None:
        args.append(statsbank.init_bank(mesh_toy.loss_fn, params,
                                        mesh_toy.make_batch(0), policy,
                                        stats_cfg))
    if guard is not None:
        args.append(guard_mod.init_state())
    batch = mesh_toy.make_batch(0)
    if with_chaos:
        batch = dict(batch)
        batch["_chaos"] = {n: jnp.int32(-1) for n in chaos_mod.IN_TRACE}
    args += [batch, jnp.int32(1)]
    step = make_train_step(mesh_toy.loss_fn, opt, schedules.constant(1e-3),
                           policy, stats=stats_cfg, mesh=mesh, guard=guard)
    return jax.make_jaxpr(step)(*args)


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["meshless", "mesh1"])
def test_guarded_steady_state_reduction_budget(sharded):
    """The PR 5/7 invariant with the guard armed: banked + guarded (+
    chaos operands) steady state == fp32 baseline + 1 bookkeeping min
    outside lax.cond.  The guard evaluates on scalars the step already
    reduces, and the chaos injectors are elementwise `where`s."""
    mesh = jax.make_mesh((1, 1), ("data", "model")) if sharded else None
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64)
    n_fp32 = statsbank.count_reductions(
        _toy_jaxpr(mesh, make_policy("fp32"), None), include_cond=False)
    n_guarded = statsbank.count_reductions(
        _toy_jaxpr(mesh, pol, scfg, guard=guard_mod.GuardConfig(),
                   with_chaos=True), include_cond=False)
    assert n_guarded == n_fp32 + 1, (n_guarded, n_fp32)


def test_guarded_saturation_sentinel_keeps_budget():
    """With telemetry + the saturation sentinel the probe widens to the
    fused [2, N] stack — still exactly ONE non-cond reduction on top of
    the fp32 baseline."""
    pol = make_policy("s2fp8_e4m3", gemm_mode="payload")
    scfg = statsbank.StatsConfig(refresh_every=64, telemetry=True)
    n_fp32 = statsbank.count_reductions(
        _toy_jaxpr(None, make_policy("fp32"), None), include_cond=False)
    n_sat = statsbank.count_reductions(
        _toy_jaxpr(None, pol, scfg,
                   guard=guard_mod.GuardConfig(sat_threshold=0.5),
                   with_chaos=True), include_cond=False)
    assert n_sat == n_fp32 + 1, (n_sat, n_fp32)


def test_guard_without_bank_adds_no_reductions():
    """A bankless guarded fp32 step reuses the baseline's loss/grad_norm
    scalars outright — not even the bookkeeping min exists."""
    n_fp32 = statsbank.count_reductions(
        _toy_jaxpr(None, make_policy("fp32"), None), include_cond=False)
    n_guarded = statsbank.count_reductions(
        _toy_jaxpr(None, make_policy("fp32"), None,
                   guard=guard_mod.GuardConfig(), with_chaos=True),
        include_cond=False)
    assert n_guarded == n_fp32, (n_guarded, n_fp32)


# ---------------------------------------------------------------------------
# 8-device mesh: rejected step bitwise (slow subprocess)
# ---------------------------------------------------------------------------

_MESH8_REJECT_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
import mesh_toy
from repro.training import guard as guard_mod

mesh = jax.make_mesh((8, 1), ("data", "model"))
step, params, opt_state, bank, _ = mesh_toy.setup(
    mesh=mesh, guard=guard_mod.GuardConfig())
gs = guard_mod.init_state()

def chaos_batch(s, reject_at=-1, nan_at=-1):
    b = dict(mesh_toy.make_batch(s))
    b["_chaos"] = {"nan_grad": jnp.int32(nan_at),
                   "inf_loss": jnp.int32(-1),
                   "reject": jnp.int32(reject_at)}
    return b

for s in range(3):
    params, opt_state, bank, gs, m = step(
        params, opt_state, bank, gs, chaos_batch(s), jnp.int32(s))

pre = jax.device_get((params, opt_state, bank, gs))

def bitwise(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))

out = {}
p2, o2, b2, g2, m = step(params, opt_state, bank, gs,
                         chaos_batch(3, reject_at=3), jnp.int32(3))
out["reject_bitwise"] = bitwise(jax.device_get((p2, o2, b2, g2)), pre)
out["reject_ok"] = float(m["guard_ok"])

p3, o3, b3, g3, m = step(params, opt_state, bank, gs,
                         chaos_batch(3, nan_at=3), jnp.int32(3))
out["nan_bitwise"] = bitwise(jax.device_get((p3, o3, b3, g3)), pre)
out["nan_ok"] = float(m["guard_ok"])
out["nan_cause"] = float(m["guard_nonfinite"])
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh8_rejected_step_bitwise():
    proc = subprocess.run([sys.executable, "-c", _MESH8_REJECT_SCRIPT],
                          env=_subprocess_env(), capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["reject_bitwise"] is True, out
    assert out["nan_bitwise"] is True, out
    assert out["reject_ok"] == 0.0 and out["nan_ok"] == 0.0, out
    assert out["nan_cause"] == 1.0, out


# ---------------------------------------------------------------------------
# hardened checkpoint I/O
# ---------------------------------------------------------------------------

def _tree(seed):
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(rng.randn(8, 4).astype(np.float32)),
            "step": jnp.int32(seed)}


def _damage(step_dir, flavor):
    if flavor == "manifest":
        os.remove(os.path.join(step_dir, "MANIFEST.json"))
        return
    leaf = os.path.join(step_dir, sorted(
        n for n in os.listdir(step_dir) if n.endswith(".npy"))[0])
    if flavor == "bitflip":
        with open(leaf, "r+b") as f:
            f.seek(-1, 2)
            byte = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([byte[0] ^ 0xFF]))
    else:                                   # truncate
        with open(leaf, "r+b") as f:
            f.truncate(os.path.getsize(leaf) // 2)


@pytest.mark.parametrize("flavor,reason", [
    ("truncate", "size mismatch"),
    ("bitflip", "checksum mismatch"),
    ("manifest", "missing manifest"),
])
def test_restore_quarantines_corrupt_and_falls_back(tmp_path, flavor,
                                                    reason):
    events = []
    ck = CheckpointManager(str(tmp_path), event_fn=events.append)
    ck.save(1, _tree(1))
    ck.save(2, _tree(2))
    assert ck.validate(2) == (True, "ok")
    _damage(ck._step_dir(2), flavor)
    ok, why = ck.validate(2)
    assert not ok and reason in why, (ok, why)
    restored, step = ck.restore(_tree(0))
    assert step == 1
    _assert_trees_bitwise(restored, _tree(1), "fallback")
    q = [e for e in events if e.get("event") == "checkpoint_quarantined"]
    assert len(q) == 1 and q[0]["step"] == 2 and reason in q[0]["reason"]
    assert os.path.isdir(str(tmp_path / "step_0000000002.quarantined"))
    # the quarantined dir is invisible to every scan
    assert ck.latest_step() == 1


def test_restore_explicit_corrupt_step_raises(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(3, _tree(3))
    _damage(ck._step_dir(3), "truncate")
    with pytest.raises(ValueError, match="failed validation"):
        ck.restore(_tree(0), step=3)


def test_restore_all_corrupt_raises_filenotfound(tmp_path):
    events = []
    ck = CheckpointManager(str(tmp_path), event_fn=events.append)
    ck.save(1, _tree(1))
    ck.save(2, _tree(2))
    _damage(ck._step_dir(1), "truncate")
    _damage(ck._step_dir(2), "manifest")
    with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
        ck.restore(_tree(0))
    assert len([e for e in events
                if e.get("event") == "checkpoint_quarantined"]) == 2


def test_step_of_parser_ignores_strays(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(5, _tree(5))
    # strays that used to crash int() parses in latest_step/_gc
    os.makedirs(str(tmp_path / "step_0000000001.quarantined"))
    os.makedirs(str(tmp_path / "step_abc"))
    (tmp_path / "notes.txt").write_text("x")
    assert ck.latest_step() == 5
    ck._gc()
    restored, step = ck.restore(_tree(0))
    assert step == 5


def test_save_retries_transient_oserror(tmp_path, monkeypatch):
    ck = CheckpointManager(str(tmp_path), retries=3, backoff_s=0.0)
    calls = {"n": 0}
    real_save = np.save

    def flaky_save(path, arr, *a, **kw):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient")
        return real_save(path, arr, *a, **kw)

    monkeypatch.setattr(np, "save", flaky_save)
    ck.save(1, _tree(1))
    assert calls["n"] >= 3
    assert ck.validate(1) == (True, "ok")
    restored, step = ck.restore(_tree(0))
    assert step == 1


def test_save_retry_exhaustion_reraises(tmp_path, monkeypatch):
    ck = CheckpointManager(str(tmp_path), retries=2, backoff_s=0.0)

    def always_fail(*a, **kw):
        raise OSError("disk on fire")

    monkeypatch.setattr(np, "save", always_fail)
    with pytest.raises(OSError, match="disk on fire"):
        ck.save(1, _tree(1))


def test_read_retries_transient_oserror(tmp_path, monkeypatch):
    ck = CheckpointManager(str(tmp_path), retries=3, backoff_s=0.0)
    ck.save(1, _tree(1))
    calls = {"n": 0}
    real_load = np.load

    def flaky_load(path, *a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("transient")
        return real_load(path, *a, **kw)

    monkeypatch.setattr(np, "load", flaky_load)
    restored, step = ck.restore(_tree(0))
    assert step == 1
    _assert_trees_bitwise(restored, _tree(1), "retry-read")


# ---------------------------------------------------------------------------
# TrainLoop: --resume auto with a corrupted newest checkpoint (satellite)
# ---------------------------------------------------------------------------

def _toy_loop(ckpt_dir, sink, **kw):
    step, params, opt_state, bank, _ = mesh_toy.setup()
    ck = CheckpointManager(ckpt_dir, event_fn=sink.emit)
    loop = TrainLoop(step, params, opt_state,
                     lambda s: mesh_toy.make_batch(s),
                     ckpt_manager=ck, stats_bank=bank, sink=sink,
                     log_every=0, **kw)
    return loop, ck


def test_resume_auto_skips_corrupt_newest(tmp_path):
    d = str(tmp_path)
    sink = obs_sinks.MemorySink()
    loop, ck = _toy_loop(d, sink, ckpt_every=2)
    loop.run(6)                              # saves at steps 2, 4, 6
    assert ck.latest_step() == 6
    _damage(ck._step_dir(6), "truncate")

    sink2 = obs_sinks.MemorySink()
    loop2, _ = _toy_loop(d, sink2)
    loop2.maybe_resume()
    assert loop2.start_step == 4
    q = [r for r in sink2.by_kind("event")
         if r["event"] == "checkpoint_quarantined"]
    assert len(q) == 1 and q[0]["step"] == 6

    # the resumed state is exactly the clean run's state entering step 4
    step, params, opt_state, bank, _ = mesh_toy.setup()
    ref = mesh_toy.run(step, params, opt_state, bank, 4)
    _assert_trees_bitwise(
        (loop2.params, loop2.opt_state, loop2.stats_bank), ref[:3],
        "resume-after-quarantine")


# ---------------------------------------------------------------------------
# watchdog escalation into the ladder (satellite)
# ---------------------------------------------------------------------------

def test_watchdog_escalation_snapshots_and_emits():
    from jax.experimental import io_callback
    SLOW = (10, 11)                          # two consecutive stragglers

    def host_pause(step):
        if int(step) in SLOW:
            time.sleep(0.25)
        return np.float32(0.0)

    def train_step(params, opt_state, batch, step):
        z = io_callback(host_pause, jax.ShapeDtypeStruct((), jnp.float32),
                        step, ordered=True)
        return params, opt_state, {"loss": jnp.float32(1.0) + z,
                                   "lr": jnp.float32(1e-3)}

    sink = obs_sinks.MemorySink()
    loop = TrainLoop(train_step, {"w": jnp.zeros((4,))},
                     {"m": jnp.zeros((4,))},
                     lambda s: {"x": jnp.zeros((2,))},
                     log_every=0, watchdog_factor=3.0, sink=sink,
                     snapshot_every=1000, watchdog_escalate_after=2)
    loop.run(13)
    trips = [r for r in sink.by_kind("event") if r["event"] == "watchdog"]
    assert {10, 11} <= {r["step"] for r in trips}, sink.records
    esc = [r for r in sink.by_kind("event")
           if r["event"] == "watchdog_escalated"]
    assert len(esc) == 1, sink.records
    assert esc[0]["trips"] == 2 and esc[0]["snapshot"] is True
    assert len(loop.ring) == 1               # the proactive snapshot
    assert loop.ring.latest()[0] == esc[0]["step"] + 1
